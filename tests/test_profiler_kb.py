"""Offline knowledge base + ladder construction (profiler.py): the paper's
Table III calibration machinery, previously untested."""

import pytest

from repro.configs.base import DECODE, PREFILL, TRAIN, ShapeConfig
from repro.core import profiler as PF
from repro.core.classifier import Category, Classification, FACTOR_SHUF


# --- ladder_shapes edge cases ------------------------------------------------

def test_ladder_ascending_and_capped_at_target():
    shape = ShapeConfig("t", TRAIN, 4_096, 256)
    ladder = PF.ladder_shapes(shape, n_points=3, base_seq=512)
    seqs = [s.seq_len for s in ladder]
    assert seqs == [512, 1024, 2048]
    assert all(s.kind == TRAIN for s in ladder)


def test_ladder_tiny_target_collapses_and_dedupes():
    """A target smaller than the base rung collapses the ladder to one
    unique point (every rung clamps to the target seq)."""
    shape = ShapeConfig("t", TRAIN, 256, 8)
    ladder = PF.ladder_shapes(shape, n_points=3, base_seq=512)
    assert [s.seq_len for s in ladder] == [256]


def test_ladder_min_seq_flooring():
    """Prefix-embed archs need seq > n_prefix: base_seq doubles past the
    floor before the ladder starts."""
    shape = ShapeConfig("t", PREFILL, 32_768, 32)
    ladder = PF.ladder_shapes(shape, n_points=3, base_seq=512, min_seq=512)
    assert [s.seq_len for s in ladder] == [1024, 2048, 4096]
    # floor far above base: first rung still clears it
    ladder = PF.ladder_shapes(shape, n_points=2, base_seq=512, min_seq=3000)
    assert ladder[0].seq_len == 4096


def test_ladder_decode_clamps_context():
    """Decode rungs never profile below a 1024-token cache."""
    shape = ShapeConfig("d", DECODE, 32_768, 128)
    ladder = PF.ladder_shapes(shape, n_points=3, base_seq=128)
    assert [s.seq_len for s in ladder] == [1024]  # 128/256/512 all clamp+dedupe
    ladder = PF.ladder_shapes(shape, n_points=3, base_seq=1024)
    assert [s.seq_len for s in ladder] == [1024, 2048, 4096]


def test_ladder_names_are_distinct():
    shape = ShapeConfig("t", TRAIN, 4_096, 256)
    names = [s.name for s in PF.ladder_shapes(shape, n_points=3)]
    assert len(set(names)) == len(names)


# --- calibrated_factors ------------------------------------------------------

def _kb_entry(cat, alpha):
    return {"category": cat.value, "alpha": alpha, "inc": 1.0,
            "slope": alpha, "intercept": 0.0, "factor": FACTOR_SHUF[cat]}


def test_calibrated_factors_empty_kb_is_paper_table():
    out = PF.calibrated_factors({})
    assert out == {c.value: f for c, f in FACTOR_SHUF.items()}


def test_calibrated_factors_envelope_with_margin():
    kb = {"a::train": _kb_entry(Category.EXPANDING_MEDIUM, 10.0),
          "b::train": _kb_entry(Category.EXPANDING_MEDIUM, 4.0)}
    out = PF.calibrated_factors(kb)
    # max observed alpha (10) + 10% margin beats the paper's 3
    assert out[Category.EXPANDING_MEDIUM.value] == pytest.approx(11.0)
    # unseen categories keep the paper values
    assert out[Category.SHRINKING.value] == FACTOR_SHUF[Category.SHRINKING]


def test_calibrated_factors_never_below_paper():
    kb = {"a::train": _kb_entry(Category.EXPANDING_RAPID, 0.01)}
    out = PF.calibrated_factors(kb)
    assert out[Category.EXPANDING_RAPID.value] == \
        FACTOR_SHUF[Category.EXPANDING_RAPID]


# --- knowledge base round-trip ----------------------------------------------

def _cls(cat=Category.MEDIUM, alpha=0.8):
    return Classification(category=cat, alpha=alpha, inc=1.2, slope=0.75,
                          intercept=123.0)


def test_build_save_load_roundtrip(tmp_path):
    entries = {"h2o::train": _cls(Category.EXPANDING_MEDIUM, 2.5),
               "xlstm::decode": _cls(Category.SHRINKING, 0.2)}
    kb = PF.build_knowledge_base(entries)
    assert kb["h2o::train"]["factor"] == \
        FACTOR_SHUF[Category.EXPANDING_MEDIUM]
    path = str(tmp_path / "sub" / "kb.json")   # exercises makedirs
    PF.save_knowledge_base(path, kb)
    loaded = PF.load_knowledge_base(path)
    assert loaded == kb
    # the loaded KB feeds calibration directly (alpha 2.5 → envelope 2.75
    # stays floored at the paper's factor 3)
    factors = PF.calibrated_factors(loaded)
    assert factors[Category.EXPANDING_MEDIUM.value] == pytest.approx(3.0)


def test_save_knowledge_base_bare_filename(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    PF.save_knowledge_base("kb.json", {"k": {"category": "Medium",
                                             "alpha": 1.0}})
    assert PF.load_knowledge_base("kb.json")["k"]["alpha"] == 1.0
