"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional test dep)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import classifier as C
from repro.models import layers
from repro.optim import compress
from repro.data.pipeline import DataConfig, TokenPipeline

S = settings(max_examples=25, deadline=None)


@S
@given(st.floats(0, 100, allow_nan=False), st.floats(0, 100, allow_nan=False))
def test_classifier_total_function(alpha, inc):
    cat = C.classify(alpha, inc)
    assert cat in C.Category
    # Table I boundaries
    if alpha >= 1.0:
        assert cat in (C.Category.EXPANDING_RAPID, C.Category.EXPANDING_MEDIUM)
    elif alpha <= 0.5:
        assert cat == C.Category.SHRINKING
    else:
        assert cat == C.Category.MEDIUM


@S
@given(st.integers(1, 6), st.integers(2, 64))
def test_rmsnorm_scale_invariance(seed, d):
    """rmsnorm(c·x) ≈ rmsnorm(x) for c > 0 (zero-scale params). Exact only
    up to the eps regularizer, so inputs are kept well-scaled."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, d)) + 2.0
    scale = jnp.zeros((d,))
    a = layers.rmsnorm(scale, x)
    b = layers.rmsnorm(scale, 7.3 * x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                               rtol=1e-4)


@S
@given(st.integers(1, 5), st.integers(1, 8))
def test_rope_preserves_norm(seed, hd_half):
    hd = 2 * hd_half
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 4, 2, hd))
    pos = jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32)[None], (1, 4))
    y = layers.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5, atol=1e-5)


@S
@given(st.integers(0, 2**31 - 1), st.integers(1, 400))
def test_quantize_int8_bounded_error(seed, scale_int):
    """Dequantized value within one quantization step of the input."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (64,)) * (scale_int / 10.0)
    q, s = compress.quantize_int8(x, key)
    err = np.abs(np.asarray(compress.dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) + 1e-6


def test_quantize_int8_unbiased():
    """Stochastic rounding: E[dequant] == x (mean over many keys)."""
    x = jnp.asarray([0.301, -0.777, 0.123, 0.499]) * 0.01
    acc = np.zeros(4)
    n = 400
    for i in range(n):
        q, s = compress.quantize_int8(x, jax.random.PRNGKey(i))
        acc += np.asarray(compress.dequantize_int8(q, s))
    np.testing.assert_allclose(acc / n, np.asarray(x), atol=2e-4)


@S
@given(st.integers(0, 1000), st.integers(1, 4), st.integers(2, 4))
def test_pipeline_determinism(step, host, n_hosts_pow):
    n_hosts = 2 ** n_hosts_pow
    host = host % n_hosts
    dc = DataConfig(vocab_size=101, seq_len=8, global_batch=16, seed=1)
    a = TokenPipeline(dc, n_hosts=n_hosts, host_id=host).batch_at(step)
    b = TokenPipeline(dc, n_hosts=n_hosts, host_id=host).batch_at(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0
    assert a["tokens"].max() < 101


@S
@given(st.integers(0, 2**31 - 1))
def test_targets_are_next_tokens(seed):
    dc = DataConfig(vocab_size=101, seq_len=32, global_batch=2,
                    seed=seed % 1000, markov_p=0.5)
    b = TokenPipeline(dc).batch_at(0)
    # within a row, targets[i] must equal tokens[i+1]
    np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])


@S
@given(st.integers(2, 40), st.integers(1, 39))
def test_ring_cache_slot_bijection(L, span):
    """Any L consecutive positions map to distinct ring slots."""
    start = span
    pos = np.arange(start, start + L)
    slots = pos % L
    assert len(set(slots.tolist())) == L
