"""The pluggable measurement backend (core.measure): simulator physics,
profile cache, and the full WSMC pipeline (profile ladder -> classify ->
predict -> wsmc_plan -> oracle_plan) running end-to-end with ZERO XLA
compiles. Everything here is hermetic and fast — the compile backend is
exercised by the slow tier (test_parity_slow.py)."""
import dataclasses

import pytest

from repro import hw as HW
from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.configs.base import TRAIN, ShapeConfig, param_count
from repro.core import measure as MM
from repro.core import planner as PL
from repro.core import predictor as PR
from repro.core import profiler as PF
from repro.core.classifier import classify_profiles

MESH = {"data": 16, "model": 16}


def sim(mesh=None, cache=None):
    return MM.SimulatedMeasurer(mesh or MESH, cache=cache)


# --- simulator physics ------------------------------------------------------

def test_resident_at_least_sharded_params():
    cfg = get_config("h2o-danube-1.8b")
    p = sim().measure(cfg, SHAPES["train_4k"])
    shards = MESH["data"] * MESH["model"]
    assert p.argument_bytes >= param_count(cfg) * PR.BYTES_PARAM / shards


def test_train_remat_ordering():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    temps = [sim().measure(cfg, shape, PR.MemoryPlan(remat=r)).transient_bytes
             for r in ("none", "dots", "full")]
    assert temps[0] > temps[1] > temps[2]


def test_train_microbatching_shrinks_transients():
    cfg = get_config("mixtral-8x7b")
    shape = SHAPES["train_4k"]
    t1 = sim().measure(cfg, shape, PR.MemoryPlan(microbatches=1))
    t8 = sim().measure(cfg, shape, PR.MemoryPlan(microbatches=8))
    assert t8.transient_bytes < t1.transient_bytes
    # residents grow (grad accumulator appears) while transients shrink
    assert t8.argument_bytes > t1.argument_bytes


def test_optimizer_knob_changes_resident_only():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    f32 = sim().measure(cfg, shape, PR.MemoryPlan(optimizer="adamw_f32"))
    af = sim().measure(cfg, shape, PR.MemoryPlan(optimizer="adafactor"))
    assert f32.argument_bytes > af.argument_bytes
    assert f32.transient_bytes == pytest.approx(af.transient_bytes)


def test_decode_resident_includes_cache_and_grows_with_context():
    cfg = get_config("mistral-nemo-12b")
    short = dataclasses.replace(SHAPES["decode_32k"], seq_len=4_096)
    long = SHAPES["decode_32k"]
    ps, pl = sim().measure(cfg, short), sim().measure(cfg, long)
    assert pl.argument_bytes > ps.argument_bytes
    cache = PR.cache_bytes_per_device(cfg, long, MM.BASELINE_PLAN, MESH)
    assert cache > 0
    assert pl.argument_bytes >= cache


def test_sharding_scales_down_with_mesh():
    cfg = get_config("gemma3-12b")
    shape = SHAPES["train_4k"]
    small = sim({"data": 4, "model": 2}).measure(cfg, shape)
    big = sim({"data": 16, "model": 16}).measure(cfg, shape)
    assert big.argument_bytes < small.argument_bytes
    assert big.transient_bytes < small.transient_bytes


def test_attention_transient_superlinear_recurrent_linear():
    """Full attention's score term grows superlinearly with seq; a pure
    recurrent arch stays ~linear — the Table II discrimination the
    classifier needs."""
    def stage_ratio(arch):
        cfg = get_config(arch)
        m = sim()
        a = m.measure(cfg, ShapeConfig("a", TRAIN, 1024, 256))
        b = m.measure(cfg, ShapeConfig("b", TRAIN, 8192, 256))
        return (b.stage_transient_bytes / a.stage_transient_bytes)
    # inputs grew 8x: attention transient grows strictly faster
    assert stage_ratio("h2o-danube-1.8b") > stage_ratio("xlstm-1.3b")
    assert stage_ratio("h2o-danube-1.8b") > 8.0


# --- profile cache ----------------------------------------------------------

def test_cache_roundtrip_and_hit(tmp_path):
    path = str(tmp_path / "profiles.json")
    cache = MM.ProfileCache(path)
    m = sim(cache=cache)
    cfg = get_config("h2o-danube-1.8b")
    p1 = m.measure(cfg, SHAPES["train_4k"])
    assert cache.misses == 1 and cache.hits == 0
    p2 = m.measure(cfg, SHAPES["train_4k"])
    assert cache.hits == 1
    assert p2 == p1
    # a fresh cache object reloads from disk
    cache2 = MM.ProfileCache(path)
    assert len(cache2) == 1
    m2 = sim(cache=cache2)
    assert m2.measure(cfg, SHAPES["train_4k"]) == p1
    assert cache2.hits == 1


def test_cache_key_separates_backends_plans_meshes():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    base = MM.profile_key("simulate", cfg, shape, MM.BASELINE_PLAN, MESH)
    assert MM.profile_key("compile", cfg, shape, MM.BASELINE_PLAN,
                          MESH) != base
    assert MM.profile_key("simulate", cfg, shape,
                          PR.MemoryPlan(remat="full"), MESH) != base
    assert MM.profile_key("simulate", cfg, shape, MM.BASELINE_PLAN,
                          {"data": 4, "model": 2}) != base


def test_cache_survives_corrupt_file(tmp_path):
    path = tmp_path / "profiles.json"
    path.write_text("{not json")
    cache = MM.ProfileCache(str(path))
    assert len(cache) == 0
    m = sim(cache=cache)
    m.measure(get_config("h2o-danube-1.8b"), SHAPES["train_4k"])
    assert len(MM.ProfileCache(str(path))) == 1


def test_measurer_factory():
    m = MM.measurer_for("simulate", MESH)
    assert isinstance(m, MM.SimulatedMeasurer)
    with pytest.raises(ValueError):
        MM.measurer_for("quantum", MESH)


# --- the full WSMC pipeline, compile-free ------------------------------------

def _no_compile(monkeypatch):
    """Trip an assertion if anything reaches the AOT build/compile path."""
    import repro.launch.compile as LC

    def boom(*a, **k):
        raise AssertionError("XLA compile attempted in hermetic test")
    monkeypatch.setattr(LC, "build", boom)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_pipeline_end_to_end_zero_compiles(arch, monkeypatch):
    _no_compile(monkeypatch)
    m = sim()
    cfg = get_config(arch)
    for shape_name in ("train_4k", "decode_32k"):
        shape = SHAPES[shape_name]
        if not shape_applicable(cfg, shape)[0]:
            continue
        ladder = PF.profile_ladder(cfg, shape, None, n_points=3,
                                   measurer=m)
        assert 1 <= len(ladder) <= 3
        cls = classify_profiles(ladder)
        pred = PR.predict(cfg, shape, MM.BASELINE_PLAN, cls, MESH)
        assert pred.capacity_bytes > 0
        dec = PL.wsmc_plan(cfg, shape, cls, MESH)
        assert dec.policy in ("wsmc", "wsmc_overflow")
        plan, peak, n = PL.oracle_plan(cfg, shape, measurer=m,
                                       max_candidates=8)
        assert peak > 0 and n >= 1


def test_oracle_needs_measure_or_measurer():
    cfg = get_config("h2o-danube-1.8b")
    with pytest.raises(TypeError):
        PL.oracle_plan(cfg, SHAPES["train_4k"])


def test_oracle_simulator_prefers_fitting_plan(monkeypatch):
    """With a miniature HBM the oracle must walk past non-fitting fast
    plans — same decision structure as the compile-backed search."""
    _no_compile(monkeypatch)
    hbm = dataclasses.replace(HW.TPU_V5E, hbm_bytes=2 * 2**30)
    cfg = get_config("mixtral-8x7b")
    shape = SHAPES["train_4k"]
    plan, peak, n = PL.oracle_plan(cfg, shape, measurer=sim(), hw=hbm)
    budget = hbm.hbm_bytes / HW.CAPACITY_HEADROOM - hbm.reserved_bytes
    m = sim()
    # the returned plan is the best the lattice offers under this budget
    if peak <= budget:
        assert n >= 1
    else:
        cands = PL.candidate_plans(cfg, shape)
        best = min(m.measure_peak(cfg, shape, p) for p in cands)
        assert peak == pytest.approx(best)


def test_classifier_sees_category_spread(monkeypatch):
    """Across archs × kinds the simulator produces more than one paper
    category (the knowledge base would be useless otherwise)."""
    _no_compile(monkeypatch)
    m = sim()
    cats = set()
    for arch in ("h2o-danube-1.8b", "xlstm-1.3b", "gemma3-12b"):
        cfg = get_config(arch)
        for shape_name in ("train_4k", "decode_32k"):
            cls = PF.classify_workload(cfg, SHAPES[shape_name], None,
                                       measurer=m)
            cats.add(cls.category)
    assert len(cats) >= 2


def test_dryrun_cell_simulate_backend(tmp_path, monkeypatch):
    """launch.dryrun.run_cell end-to-end under the simulator: plan chosen,
    both meshes measured, no compile, no jax mesh construction."""
    _no_compile(monkeypatch)
    from repro.launch import dryrun as DR
    cache = MM.ProfileCache(str(tmp_path / "p.json"))
    measurers = {name: MM.SimulatedMeasurer(shape, cache=cache)
                 for name, shape in DR.MESH_SHAPES.items()}
    kb = {}
    res = DR.run_cell("h2o-danube-1.8b", SHAPES["train_4k"], measurers, kb,
                      do_roofline=True)
    assert res["status"] == "ok"
    assert res["backend"] == "simulate"
    assert "roofline" not in res          # compile-only analysis
    assert res["mesh_single"]["temp_bytes"] > 0
    assert res["mesh_multi"]["n_devices"] == 512
    assert kb                             # knowledge base got an entry
    assert len(cache) > 0
