"""Attention backends: blocked == naive across mask modes; ring-cache decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import BlockSpec
from repro.models import attention as A

# XLA compiles dominate the runtime => slow tier
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(7)


def _inputs(b=2, s=48, K=2, G=2, hd=16, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, K, G, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, K, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, K, hd), dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return q, k, v, pos


@pytest.mark.parametrize("blk", [
    BlockSpec(window=None), BlockSpec(window=16), BlockSpec(chunk=16),
])
@pytest.mark.parametrize("s", [48, 33])
def test_blocked_matches_naive(blk, s):
    q, k, v, pos = _inputs(s=s)
    st = A.AttnSettings(backend="blocked", q_block=16, kv_block=16)
    ref = A._naive(q, k, v, pos, pos, blk)
    out = A._seq_attention(q, k, v, pos, pos, blk, st)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_blocked_grads_match_naive():
    q, k, v, pos = _inputs(s=32)
    blk = BlockSpec(window=None)
    st = A.AttnSettings(backend="blocked", q_block=8, kv_block=8)

    def loss(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v, pos, pos, blk)
                                       if fn is A._naive
                                       else fn(q, k, v, pos, pos, blk, st))
    g_ref = jax.grad(loss(A._naive), argnums=(0, 1, 2))(q, k, v)
    g_out = jax.grad(loss(A._seq_attention), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_out, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("blk,L", [
    (BlockSpec(window=None), 48),     # global: full cache
    (BlockSpec(window=16), 16),       # sliding: ring of window size
    (BlockSpec(chunk=16), 16),        # chunked: ring of chunk size
])
def test_cache_len(blk, L):
    assert blk.cache_len(48) == L


def test_ring_cache_decode_matches_sdpa():
    """Fill a ring cache step-by-step; each decode must equal full attention
    over the visible window."""
    b, K, G, hd, S = 1, 1, 2, 8, 24
    blk = BlockSpec(window=8)
    ks = jax.random.split(KEY, 3)
    kf = jax.random.normal(ks[0], (b, S, K, hd))
    vf = jax.random.normal(ks[1], (b, S, K, hd))
    qf = jax.random.normal(ks[2], (b, S, K, G, hd))
    L = blk.cache_len(S)
    cache = {"k": jnp.zeros((b, L, K, hd)), "v": jnp.zeros((b, L, K, hd)),
             "pos": jnp.full((b, L), -1, jnp.int32)}
    pos_full = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (b, S))
    for t in range(S):
        slot = jnp.array([t % L])
        bidx = jnp.arange(b)
        cache = {"k": cache["k"].at[bidx, slot].set(kf[:, t]),
                 "v": cache["v"].at[bidx, slot].set(vf[:, t]),
                 "pos": cache["pos"].at[bidx, slot].set(jnp.array([t]))}
        o = A._decode_attend(qf[:, t:t + 1], cache, blk,
                             jnp.array([t], jnp.int32))
        ref = A._sdpa(qf[:, t:t + 1], kf[:, :t + 1], vf[:, :t + 1],
                      A._mask(pos_full[:, t:t + 1], pos_full[:, :t + 1], blk))
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_causality_blocked():
    """Perturbing future tokens must not change past outputs."""
    q, k, v, pos = _inputs(s=32)
    blk = BlockSpec(window=None)
    st = A.AttnSettings(backend="blocked", q_block=8, kv_block=8)
    out1 = A._seq_attention(q, k, v, pos, pos, blk, st)
    k2 = k.at[:, 20:].add(7.0)
    v2 = v.at[:, 20:].add(-3.0)
    out2 = A._seq_attention(q, k2, v2, pos, pos, blk, st)
    np.testing.assert_allclose(np.asarray(out1[:, :20]),
                               np.asarray(out2[:, :20]), atol=1e-6)
