"""Capacity bending, hermetic tier: per-block KV quantization codecs
(int8 / nibble-packed int4 with per-row absmax scales), block-granular
retention on the jax-free allocator and scripted engine (evicted blocks
reusable, never double-freed, shared prefix blocks immune), the bending
knobs of `serving_space`, and `plan_serving`'s minimum-agreement gate on
the quality/capacity frontier. Token parity of the REAL bent executor
against `greedy_generate` lives in the slow tier and the serving
benchmark's measured agreement column."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import DECODE, ShapeConfig
from repro.core import measure as MM
from repro.core import predictor as PR
from repro.core import profiler as PF
from repro.models import attention as ATT
from repro.search import execplan as XP
from repro.search import space as SP
from repro.serving import (BlockAllocator, Engine, Request,
                           ScriptedExecutor)

CFG = get_config("mistral-nemo-12b")
SHAPE = ShapeConfig("bend_t", DECODE, 4096, 8)
GIB = 2**30
VOCAB = 97


@pytest.fixture(scope="module")
def cls():
    sim = MM.SimulatedMeasurer({"data": 8})
    return PF.classify_workload(CFG, SHAPE, None, n_points=2, base_seq=64,
                                measurer=sim)


# --- quantization codec ------------------------------------------------------

@pytest.mark.parametrize("kind", ["int8", "int4"])
def test_quantize_roundtrip_error_bound(kind):
    """|dequant - x| <= scale/2 per element (absmax rounding), and the
    packed layout has the advertised width (int4: two codes per byte)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(scale=4.0, size=(5, 8, 3, 16)), jnp.float32)
    q, scale = ATT.quantize_kv(x, kind)
    assert scale.shape == x.shape[:-1]
    assert q.shape[-1] == (16 if kind == "int8" else 8)
    dq = ATT.dequantize_kv(q, scale, kind, dtype=jnp.float32)
    err = np.abs(np.asarray(dq) - np.asarray(x))
    bound = np.asarray(scale)[..., None] / 2 + 1e-5
    assert (err <= bound).all(), float((err - bound).max())


def test_quantize_zero_rows_exact():
    x = jnp.zeros((2, 3, 4, 8), jnp.float32)
    for kind in ("int8", "int4"):
        q, scale = ATT.quantize_kv(x, kind)
        dq = ATT.dequantize_kv(q, scale, kind, dtype=jnp.float32)
        assert np.asarray(dq).max() == 0.0 and np.asarray(scale).max() == 0.0


def test_quantize_roundtrip_error_bound_property():
    """Hypothesis pin: the per-row bound holds for arbitrary magnitudes
    (tiny, huge, mixed-sign), both codecs, any even head width."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed (optional test dep)")
    given = hypothesis.given
    st = hypothesis.strategies

    @hypothesis.settings(max_examples=40, deadline=None)
    @given(st.sampled_from(["int8", "int4"]),
           st.integers(1, 6),                      # rows
           st.sampled_from([2, 4, 6, 16]),         # head width (even)
           st.floats(1e-4, 1e4),                   # magnitude
           st.integers(0, 2**31 - 1))
    def run(kind, rows, hd, mag, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.uniform(-mag, mag, size=(rows, hd)), jnp.float32)
        q, scale = ATT.quantize_kv(x, kind)
        dq = ATT.dequantize_kv(q, scale, kind, dtype=jnp.float32)
        err = np.abs(np.asarray(dq) - np.asarray(x))
        s = np.asarray(scale)[..., None]
        assert (err <= s / 2 + 1e-6 * mag + 1e-30).all()

    run()


def test_quant_kind_is_self_describing():
    """The pool's dtype IS the codec: no side-channel flag to desync."""
    pool = {"kb": jnp.zeros((2, 4, 1, 8), jnp.bfloat16)}
    assert ATT.paged_quant_kind(pool) == "none"
    pool8 = {"kb": jnp.zeros((2, 4, 1, 8), jnp.int8), "ks": 0}
    assert ATT.paged_quant_kind(pool8) == "int8"
    pool4 = {"kb": jnp.zeros((2, 4, 1, 4), jnp.uint8), "ks": 0}
    assert ATT.paged_quant_kind(pool4) == "int4"


# --- predictor: quantized block bytes ---------------------------------------

def test_kv_block_bytes_shrink_with_quant():
    plans = {q: PR.MemoryPlan(kv_block_size=64, kv_quant=q)
             for q in ("none", "int8", "int4")}
    mesh = {"data": 4, "model": 1}
    bb = {q: PR.kv_block_bytes_per_device(CFG, SHAPE, p, mesh)
          for q, p in plans.items()}
    assert bb["none"] > bb["int8"] > bb["int4"]
    # scale stripes keep int8 above a naive /2 (and int4 above /4)
    assert bb["int8"] > bb["none"] / 2
    assert bb["int4"] > bb["none"] / 4


def test_quantized_blocks_raise_capacity(cls):
    mesh = {"data": 4, "model": 1}
    caps = {}
    for q in ("none", "int8"):
        plan = PR.MemoryPlan(kv_block_size=64, kv_quant=q)
        caps[q] = PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh,
                                            hbm_budget=12 * GIB)
    assert caps["int8"] > caps["none"]


# --- allocator: retention eviction ------------------------------------------

def test_free_block_returns_block_for_reuse():
    a = BlockAllocator(3, block_size=2)
    a.reserve(0, 3)
    ids = [a.alloc(0) for _ in range(3)]     # pool fully drained
    a.free_block(0, ids[1])
    assert a.in_use == 2
    assert a.alloc(0) == ids[1]              # the dropped block comes back
    a.free(0)
    assert a.free_blocks == 3


def test_free_block_double_free_raises():
    a = BlockAllocator(4, block_size=2)
    a.reserve(0, 2)
    bid = a.alloc(0)
    a.free_block(0, bid)
    with pytest.raises(RuntimeError, match="double free"):
        a.free_block(0, bid)
    with pytest.raises(RuntimeError, match="owns no blocks"):
        a.free_block(7, bid)                 # rid never reserved


def test_free_block_rejects_shared_prefix_blocks():
    """Prefix blocks are refcounted, never request-owned: retention must
    not be able to pull them out from under other sharers."""
    a = BlockAllocator(6, block_size=2)
    pfx = a.create_prefix("sys", 2)
    a.reserve(0, 2)
    a.alloc(0)
    with pytest.raises(RuntimeError, match="shared prefix"):
        a.free_block(0, pfx[0])


# --- engine: block-granular retention ---------------------------------------

def _req(rid, prompt_len=4, max_new=4, prefix=None):
    prompt = tuple((3 + rid * 5 + i) % (VOCAB - 2) + 2
                   for i in range(prompt_len))
    if prefix is not None:
        prompt = tuple(prefix) + prompt
    return Request(rid=rid, arrival=0, prompt=prompt, max_new=max_new,
                   prefix_id=(0 if prefix is not None else None),
                   prefix_len=(len(prefix) if prefix is not None else 0))


def _tokens(report):
    return {c.rid: list(c.tokens) for c in report.completions}


def test_retention_drops_blocks_and_caps_footprint():
    trace = [_req(r, prompt_len=4, max_new=16) for r in range(3)]

    def run(retain, pool=40):
        alloc = BlockAllocator(pool, 4)
        rep = Engine(ScriptedExecutor(VOCAB), n_slots=3, allocator=alloc,
                     kv_retain=retain).run(trace)
        assert alloc.free_blocks == pool     # everything returned, once
        return rep

    plain, kept = run(0), run(1)
    assert _tokens(kept) == _tokens(plain)   # scheduling is undisturbed
    assert plain.block_drops == 0
    assert kept.block_drops > 0
    assert kept.peak_blocks < plain.peak_blocks
    assert "block_drops=" in kept.describe()


def test_retention_admits_more_under_tight_pool():
    """The bend pays rent: a pool too small for three exact 5-block
    sequences serves them concurrently once cold blocks are dropped."""
    from repro.serving import length_stats
    trace = [_req(r, prompt_len=4, max_new=16) for r in range(3)]
    pool = 9                                  # 3 lanes x (retain 1 + tail)
    stats = length_stats(trace)

    def run(retain):
        # expected-mode reservations: retention caps each lane's expected
        # own-block demand at retain+1, so admission sees the bend
        rep = Engine(ScriptedExecutor(VOCAB), n_slots=3,
                     allocator=BlockAllocator(pool, 4,
                                              reservation="expected"),
                     stats=stats, sigma_k=0.0,
                     kv_retain=retain).run(trace, max_ticks=20_000)
        return rep

    kept = run(1)
    plain = run(0)
    assert _tokens(kept) == _tokens(plain)
    assert kept.max_concurrent > plain.max_concurrent


def test_retention_never_drops_shared_prefix_blocks():
    prefix = tuple(2 + (i * 11) % (VOCAB - 2) for i in range(8))
    trace = [_req(r, prompt_len=4, max_new=12, prefix=prefix)
             for r in range(4)]
    block, pool = 4, 24
    alloc = BlockAllocator(pool, block)
    report = Engine(ScriptedExecutor(VOCAB), n_slots=4, allocator=alloc,
                    chunk_prefill=block, prefix_share=True,
                    kv_retain=1).run(trace)
    assert len(report.completions) == len(trace)
    assert report.block_drops > 0
    # no leak: everything is free or sitting in the reclaimable prefix cache
    assert alloc.available_blocks == pool
    roomy = Engine(ScriptedExecutor(VOCAB), n_slots=4,
                   allocator=BlockAllocator(64, block),
                   chunk_prefill=block, prefix_share=True).run(trace)
    assert _tokens(report) == _tokens(roomy)


def test_engine_retention_requires_allocator():
    with pytest.raises(ValueError, match="kv_retain"):
        Engine(ScriptedExecutor(VOCAB), n_slots=2, kv_retain=1)


# --- search space: bending knobs and legality -------------------------------

def test_serving_space_bending_knobs():
    space = SP.serving_space(CFG, SHAPE, max_devices=1, data=(1,), model=(1,),
                             kv_blocks=(64,), kv_quants=("none", "int8"),
                             kv_retains=(0, 4))
    combos = {(c.plan.kv_quant, c.plan.kv_retain)
              for c in space.candidates(CFG, SHAPE)}
    assert combos == {("none", 0), ("none", 4), ("int8", 0), ("int8", 4)}


def test_bending_needs_paged_pool():
    space = SP.serving_space(CFG, SHAPE, max_devices=1, data=(1,), model=(1,),
                             kv_blocks=(0,),           # ring
                             kv_quants=("none", "int8"), kv_retains=(0, 4))
    combos = {(c.plan.kv_quant, c.plan.kv_retain)
              for c in space.candidates(CFG, SHAPE)}
    assert combos == {("none", 0)}           # quant/retain filtered on ring


def test_int4_needs_even_head_dim():
    odd = dataclasses.replace(CFG, head_dim=63)
    space = SP.serving_space(odd, SHAPE, max_devices=1, data=(1,), model=(1,),
                             kv_blocks=(64,), kv_quants=("int8", "int4"))
    quants = {c.plan.kv_quant for c in space.candidates(odd, SHAPE)}
    assert quants == {"int8"}


def test_memory_plan_validates_bend():
    with pytest.raises(ValueError):
        PR.MemoryPlan(kv_quant="fp7")
    with pytest.raises(ValueError):
        PR.MemoryPlan(kv_retain=-1)


# --- planner: the quality/capacity frontier ---------------------------------

def test_plan_serving_agreement_gate(cls):
    lens = [60] * 7 + [2000]
    kw = dict(n_devices=4, cls=cls, hbm_budget=12 * GIB, kv="paged",
              seq_lens=lens, kv_quants=("none", "int8", "int4"),
              kv_retains=(0, 4))
    _, free = XP.plan_serving(CFG, SHAPE, **kw)
    _, exact = XP.plan_serving(CFG, SHAPE, min_agreement=1.0, **kw)
    _, gated = XP.plan_serving(CFG, SHAPE, min_agreement=0.99, **kw)
    # unconstrained search bends; the gate walks back along the frontier
    assert free.execution.plan.kv_quant != "none"
    assert free.capacity >= gated.capacity >= exact.capacity
    assert exact.execution.plan.kv_quant == "none"
    assert exact.execution.plan.kv_retain == 0
    assert exact.agreement == 1.0
    assert gated.agreement >= 0.99
    assert gated.execution.plan.kv_quant == "int8"
    assert "agreement>=" in gated.describe()


def test_plan_serving_gate_unreachable_raises(cls):
    with pytest.raises(ValueError, match="min_agreement"):
        XP.plan_serving(CFG, SHAPE, n_devices=4, cls=cls,
                        hbm_budget=12 * GIB, kv="paged", seq_lens=(2000,),
                        kv_quants=("int4",), kv_retains=(0,),
                        min_agreement=0.999)


def test_predicted_agreement_priors():
    p8 = PR.MemoryPlan(kv_block_size=64, kv_quant="int8")
    p4r = PR.MemoryPlan(kv_block_size=64, kv_quant="int4", kv_retain=3)
    assert XP.predicted_agreement(PR.MemoryPlan(kv_block_size=64), 10) == 1.0
    assert XP.predicted_agreement(p8, 10) == XP.QUANT_AGREEMENT["int8"]
    # retention prior only bites when the cap binds (retain+1 < blocks)
    assert XP.predicted_agreement(p4r, 4) == XP.QUANT_AGREEMENT["int4"]
    assert XP.predicted_agreement(p4r, 10) < XP.QUANT_AGREEMENT["int4"]
