import os
import sys

# Tests see the default single CPU device; mesh-dependent tests spawn
# subprocesses with their own XLA_FLAGS (dry-run rule: never set the device
# count globally).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 560) -> str:
    """Run `code` in a fresh python with N fake devices; returns stdout."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout
