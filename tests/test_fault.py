"""Fault tolerance: straggler watchdog, failure-injection restart,
preemption checkpoint, deterministic data under re-mesh."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import ModelSettings, init_params
from repro.models.attention import AttnSettings
from repro.optim import optimizers as opt
from repro.runtime import fault as F
from repro.runtime.train_step import TrainStepConfig, make_train_step

# XLA compiles dominate the runtime => slow tier
pytestmark = pytest.mark.slow

SETTINGS = ModelSettings(attn=AttnSettings(backend="blocked", q_block=16,
                                           kv_block=16))


def _setup(tmp_path, interval=2):
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainStepConfig(remat="none", microbatches=1,
                           optimizer=opt.OptimizerConfig(lr=1e-3),
                           settings=SETTINGS, warmup_steps=1, total_steps=20)
    step = jax.jit(make_train_step(cfg, tcfg))
    opt_state = opt.init_state(tcfg.optimizer, params)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4, seed=3))
    mgr = F.CheckpointManager(str(tmp_path / "ck"), interval=interval,
                              async_=False)
    return cfg, params, opt_state, step, pipe, mgr


def test_watchdog_flags_stragglers():
    wd = F.Watchdog(threshold=2.0, window=4)
    rep = None
    for s in range(6):
        times = {0: 1.0, 1: 1.1, 2: 1.0, 3: 5.0}   # host 3 is slow
        rep = wd.record(s, times) or rep
    assert rep is not None
    assert list(rep.slow_hosts) == [3]


def test_watchdog_quiet_when_uniform():
    wd = F.Watchdog()
    for s in range(6):
        assert wd.record(s, {0: 1.0, 1: 1.05}) is None


def test_injected_failure_then_restart_resumes(tmp_path):
    cfg, params, opt_state, step, pipe, mgr = _setup(tmp_path)
    with pytest.raises(RuntimeError, match="injected failure"):
        F.run_train_loop(train_step=step, params=params, opt_state=opt_state,
                         pipeline=pipe, n_steps=10, ckpt_mgr=mgr,
                         fail_at=5)
    # restart: restore latest checkpoint and continue to completion
    last = mgr.latest_step()
    assert last is not None and 0 < last <= 5
    tree = {"params": params, "opt": opt_state}
    tree, manifest = mgr.restore(tree)
    p, o, done, hist = F.run_train_loop(
        train_step=step, params=tree["params"], opt_state=tree["opt"],
        pipeline=pipe, n_steps=10, ckpt_mgr=mgr, start_step=last)
    assert done == 10
    assert len(hist) == 10 - last


def test_restart_bitwise_matches_uninterrupted(tmp_path):
    """Checkpoint/restart must not change the training trajectory."""
    cfg, params, opt_state, step, pipe, mgr = _setup(tmp_path, interval=3)
    p1, o1, _, h1 = F.run_train_loop(train_step=step, params=params,
                                     opt_state=opt_state, pipeline=pipe,
                                     n_steps=6)
    # interrupted run: 0..3 with checkpoint, restore, 3..6
    p2, o2, _, _ = F.run_train_loop(train_step=step, params=params,
                                    opt_state=opt_state, pipeline=pipe,
                                    n_steps=3, ckpt_mgr=mgr)
    tree, manifest = mgr.restore({"params": params, "opt": opt_state})
    start = manifest["extra"]["step"]
    p2, o2, _, _ = F.run_train_loop(train_step=step, params=tree["params"],
                                    opt_state=tree["opt"], pipeline=pipe,
                                    n_steps=6, start_step=start)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_preemption_saves_and_exits(tmp_path):
    cfg, params, opt_state, step, pipe, mgr = _setup(tmp_path, interval=100)
    guard = F.PreemptionGuard()
    calls = {"n": 0}

    def on_metrics(s, m):
        calls["n"] += 1
        if s == 2:
            guard.trigger()

    p, o, done, hist = F.run_train_loop(
        train_step=step, params=params, opt_state=opt_state, pipeline=pipe,
        n_steps=50, ckpt_mgr=mgr, guard=guard, on_metrics=on_metrics)
    assert done == 3                      # stopped right after trigger
    assert mgr.latest_step() == 3         # forced preemption checkpoint


def test_data_deterministic_across_remesh():
    """Global batch content is identical regardless of host partitioning —
    the property elastic restart relies on."""
    dc = DataConfig(vocab_size=97, seq_len=16, global_batch=8, seed=5)
    full = TokenPipeline(dc, n_hosts=1, host_id=0).batch_at(4)
    parts = [TokenPipeline(dc, n_hosts=4, host_id=h).batch_at(4)
             for h in range(4)]
    stacked = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(full["tokens"], stacked)
