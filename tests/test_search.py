"""The unified plan-search subsystem (repro.search): lattice enumeration and
constraints, decision parity of `fastest_first` with the pre-refactor inline
`wsmc_plan` loop across the whole config registry, the `staged`
simulate→compile screening strategy (never returns a plan the simulator says
doesn't fit; O(k) verify calls), greedy coordinate descent, and the
simulator's new pipe/EP mesh dimensions. Everything here is hermetic except
the one compile-backed staged-vs-exhaustive pin (slow tier)."""
import dataclasses

import pytest

from repro import hw as HW
from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import TRAIN, ShapeConfig
from repro.core import measure as MM
from repro.core import planner as PL
from repro.core import predictor as PR
from repro.core.classifier import Category, Classification
from repro.search import space as SP
from repro.search import strategies as ST

MESH = {"data": 16, "model": 16}


def _cls(cat=Category.MEDIUM, alpha=0.8, inc=1.0):
    return Classification(category=cat, alpha=alpha, inc=inc, slope=alpha,
                          intercept=0.0)


def _hbm(gib):
    return dataclasses.replace(HW.TPU_V5E, hbm_bytes=int(gib * 2**30))


# --- the reference implementation --------------------------------------------
# A verbatim copy of the pre-refactor inline planner loops; the new API must
# reproduce these decisions exactly (acceptance criterion).

def _seed_candidate_plans(cfg, shape, model_size=16):
    kv = "heads" if cfg.n_kv_heads % model_size == 0 else "seq"
    if shape.kind != TRAIN:
        return [PR.MemoryPlan(remat="none", microbatches=1,
                              optimizer="adamw_f32", kv_shard=kv)]
    micros = [m for m in (1, 2, 4, 8, 16, 32, 64)
              if shape.global_batch % m == 0]
    cands = [PR.MemoryPlan(remat=r, microbatches=m, optimizer=o, kv_shard=kv)
             for r in ("none", "dots", "full") for m in micros
             for o in ("adamw_f32", "adamw_bf16", "adafactor")]
    return sorted(cands, key=lambda p: p.step_time_penalty())


def _seed_wsmc_plan(cfg, shape, cls, mesh_shape, hw=HW.TPU_V5E):
    dp = mesh_shape.get("pod", 1) * mesh_shape.get("data", 1)
    model_size = mesh_shape.get("model", 16)

    def _divisible(p):
        per_micro = shape.global_batch // p.microbatches
        if shape.kind == TRAIN:
            return per_micro % dp == 0
        return per_micro % dp == 0 or per_micro < dp

    all_cands = _seed_candidate_plans(cfg, shape, model_size)
    cands = [p for p in all_cands if _divisible(p)] or all_cands[-1:]
    for i, plan in enumerate(cands):
        pred = PR.predict(cfg, shape, plan, cls, mesh_shape, "paper", hw)
        if pred.fits:
            return plan, "wsmc", i + 1
    return cands[-1], "wsmc_overflow", len(cands)


# --- package hygiene ---------------------------------------------------------

def test_import_search_standalone():
    """`import repro.search` must work on its own (no prior repro.core
    import) — regression for the planner↔search import cycle."""
    import os
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, PYTHONPATH=src)
    out = subprocess.run(
        [sys.executable, "-c",
         "import repro.search; import repro.core; repro.core.wsmc_plan"],
        capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr


# --- lattice enumeration / constraints ---------------------------------------

def test_paper_space_matches_seed_lattice():
    for arch in ("h2o-danube-1.8b", "mixtral-8x7b", "musicgen-medium"):
        cfg = get_config(arch)
        for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
            shape = SHAPES[shape_name]
            space = SP.paper_space(cfg, shape, MESH)
            got = [c.plan for c in space.candidates(cfg, shape)]
            assert got == _seed_candidate_plans(cfg, shape)


def test_candidate_plans_wrapper_matches_seed():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    assert PL.candidate_plans(cfg, shape) == _seed_candidate_plans(cfg, shape)


def test_mesh_space_respects_constraints():
    cfg = get_config("h2o-danube-1.8b")          # 24 layers, batch 256
    shape = SHAPES["train_4k"]
    space = SP.mesh_space(cfg, shape, max_devices=64, data=(4, 8, 16),
                          model=(2, 4), pipe=(1, 2))
    cands = space.candidates(cfg, shape)
    assert cands
    for c in cands:
        ms = c.mesh_shape
        n = ms["data"] * ms["model"] * ms["pipe"]
        assert n <= 64
        assert shape.global_batch % c.plan.microbatches == 0
        per = shape.global_batch // c.plan.microbatches
        assert per % ms["data"] == 0
        if ms["pipe"] > 1:
            assert cfg.n_layers % ms["pipe"] == 0
            assert c.plan.microbatches >= ms["pipe"]
        if c.plan.kv_shard == "heads":
            assert cfg.n_kv_heads % ms["model"] == 0
    # the pipe axis is genuinely searchable (24 layers divide pipe=2)
    assert any(c.mesh_shape["pipe"] == 2 for c in cands)
    # 16x4x2 = 128 devices would bust the budget: never enumerated
    assert not any(c.mesh_shape["data"] * c.mesh_shape["model"]
                   * c.mesh_shape["pipe"] > 64 for c in cands)


def test_mesh_space_pipe_needs_layer_divisibility():
    cfg = get_config("gemma3-12b")               # 48 layers
    odd = dataclasses.replace(cfg, n_layers=47, unit=(), tail=())
    shape = SHAPES["train_4k"]
    space = SP.mesh_space(odd, shape, max_devices=64, data=(8,), model=(2,),
                          pipe=(1, 2))
    assert all(c.mesh_shape["pipe"] == 1
               for c in space.candidates(odd, shape))


def test_mesh_space_no_pipe_for_serving():
    cfg = get_config("h2o-danube-1.8b")
    space = SP.mesh_space(cfg, SHAPES["decode_32k"], max_devices=64,
                          data=(8,), model=(2,), pipe=(1, 2))
    cands = space.candidates(cfg, SHAPES["decode_32k"])
    assert cands
    assert all(c.mesh_shape["pipe"] == 1 for c in cands)


def test_point_validates_knobs_and_values():
    cfg = get_config("h2o-danube-1.8b")
    space = SP.hillclimb_space()
    with pytest.raises(KeyError):
        space.point(cfg, warp_drive=True)
    with pytest.raises(ValueError):
        space.point(cfg, remat="everything")
    cand = space.point(cfg, remat="dots", ep=True)
    assert cand.plan.remat == "dots"
    assert cand.extra("ep") is True
    # unassigned knobs take the baseline (first value)
    assert cand.plan.microbatches == 1
    assert cand.extra("embed_onehot") is True


def test_subspace_pins_values():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    space = SP.paper_space(cfg, shape, MESH)
    sub = space.subspace(remat="full", optimizer=("adafactor",))
    cands = sub.candidates(cfg, shape)
    assert cands
    assert all(c.plan.remat == "full" and c.plan.optimizer == "adafactor"
               for c in cands)
    with pytest.raises(KeyError):
        space.subspace(nope=1)
    with pytest.raises(ValueError):
        space.subspace(remat="everything")


def test_candidate_overrides_buckets():
    space = SP.hillclimb_space()
    cfg = get_config("mixtral-8x7b")
    cand = space.point(cfg, ep=True, moe_group=512, q_block=1024,
                       gather_weights=True, fsdp=False)
    over = SP.candidate_overrides(cand)
    assert over["strategy"] == {"ep": True, "fsdp": False}
    assert over["settings"]["moe_group"] == 512
    assert over["attn"]["q_block"] == 1024
    assert over["attn"]["gather_weights"] is True
    # ep=None means "keep the default_strategy choice" — dropped
    base = SP.candidate_overrides(space.point(cfg))
    assert "ep" not in base["strategy"]


# --- fastest_first: decision parity with the seed planner --------------------

@pytest.mark.parametrize("arch", ARCH_IDS)
def test_fastest_first_matches_seed_wsmc(arch):
    """The acceptance pin: across the whole registry × shapes × categories ×
    budgets, the new walk reproduces the old inline wsmc_plan decisions
    (plan, policy, and the number of candidates considered)."""
    cfg = get_config(arch)
    classes = [_cls(Category.MEDIUM, 0.8, 1.0),
               _cls(Category.EXPANDING_RAPID, 4.0, 3.0)]
    for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
        shape = SHAPES[shape_name]
        for mesh in (MESH, {"pod": 2, "data": 16, "model": 16}):
            for cls in classes:
                for hw in (HW.TPU_V5E, _hbm(0.5)):
                    want = _seed_wsmc_plan(cfg, shape, cls, mesh, hw)
                    dec = PL.wsmc_plan(cfg, shape, cls, mesh, hw=hw)
                    got = (dec.plan, dec.policy, dec.considered)
                    assert got == want, (arch, shape_name, mesh,
                                         cls.category, hw.hbm_bytes)


def test_oracle_plan_wrapper_parity():
    """oracle_plan delegates to exhaustive_verified and keeps its contract:
    fastest-first verification, early exit, overflow = least-bad plan."""
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    budget = ST.plan_budget(HW.TPU_V5E)
    calls = []

    def fake_measure(plan):
        calls.append(plan)
        return budget * (0.5 if plan.remat == "full" else 10.0)

    plan, peak, n = PL.oracle_plan(cfg, shape, fake_measure)
    assert plan.remat == "full"
    assert n == len(calls) and n > 1
    # the walk is fastest-first: everything measured before the winner is
    # strictly faster
    assert all(p.step_time_penalty() <= plan.step_time_penalty()
               for p in calls)


def test_fastest_first_plans_the_mesh():
    """Mesh shape is a planned *output* on a mesh_space — the ROADMAP door
    to elastic scaling."""
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    space = SP.mesh_space(cfg, shape, max_devices=256)
    res = ST.fastest_first(space, cfg, shape, _cls())
    assert res.policy == "wsmc"
    assert res.prediction.fits
    assert res.mesh_shape          # the decision carries its mesh
    n = 1
    for v in res.mesh_shape.values():
        n *= v
    assert n <= 256


# --- staged ------------------------------------------------------------------

class CountingMeasurer(MM.SimulatedMeasurer):
    """Simulator that counts backend invocations — the stand-in for the
    compile backend in the O(k)-verifications pin."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.n_measures = 0

    def _measure(self, *args, **kwargs):
        self.n_measures += 1
        return super()._measure(*args, **kwargs)


def test_staged_verifies_at_most_k():
    """Acceptance pin: staged finds a fitting train plan while invoking the
    verify backend at most k (≤ 5) times — vs O(lattice) for the oracle."""
    cfg = get_config("mixtral-8x7b")
    shape = SHAPES["train_4k"]
    space = SP.paper_space(cfg, shape, MESH)
    verifier = CountingMeasurer(MESH)
    res = ST.staged(space, cfg, shape, screener=MM.SimulatedMeasurer(MESH),
                    verifier=verifier, k=5)
    assert res.policy == "staged"
    assert verifier.n_measures <= 5
    assert res.measured == verifier.n_measures
    assert res.peak_bytes <= ST.plan_budget(HW.TPU_V5E)
    # the screen covered the whole lattice, the verifier only the shortlist
    assert res.considered == len(space.candidates(cfg, shape))
    assert res.considered > res.measured


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "mixtral-8x7b",
                                  "xlstm-1.3b"])
@pytest.mark.parametrize("gib", [0.25, 2.0, 16.0])
def test_staged_never_returns_unfitting_when_fitting_exists(arch, gib):
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    hw = _hbm(gib)
    space = SP.paper_space(cfg, shape, MESH)
    sim = MM.SimulatedMeasurer(MESH)
    res = ST.staged(space, cfg, shape, screener=sim, verifier=sim, k=5,
                    hw=hw)
    budget = ST.plan_budget(hw)
    any_fits = any(sim.measure_peak(cfg, shape, c.plan) <= budget
                   for c in space.candidates(cfg, shape))
    got_peak = sim.measure_peak(cfg, shape, res.plan)
    if any_fits:
        assert res.policy == "staged"
        assert got_peak <= budget
    else:
        assert res.policy == "staged_overflow"


def test_staged_agrees_with_exhaustive_simulated():
    """Same verifier => same decision, at a fraction of the verify calls."""
    cfg = get_config("mixtral-8x7b")
    shape = SHAPES["train_4k"]
    hw = _hbm(2.0)
    space = SP.paper_space(cfg, shape, MESH)
    sim = MM.SimulatedMeasurer(MESH)
    st = ST.staged(space, cfg, shape, screener=sim, verifier=sim, k=5, hw=hw)
    ex = ST.exhaustive_verified(space, cfg, shape, measurer=sim, hw=hw)
    assert st.plan == ex.plan
    assert st.measured <= ex.measured


@pytest.mark.slow
def test_staged_agrees_with_exhaustive_compile():
    """Slow-tier pin: with the real compile backend as verifier, staged
    reaches the oracle's decision in ≤ k compiles."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_config("h2o-danube-1.8b").reduced()
    shape = ShapeConfig("t", TRAIN, 128, 4)
    space = SP.paper_space(cfg, shape, {"data": 1, "model": 1})
    st = ST.staged(space, cfg, shape,
                   screener=MM.SimulatedMeasurer({"data": 1, "model": 1}),
                   verifier=MM.CompileMeasurer(mesh), k=5)
    ex = ST.exhaustive_verified(space, cfg, shape,
                                measurer=MM.CompileMeasurer(mesh))
    assert st.plan == ex.plan
    assert st.measured <= 5
    assert st.peak_bytes == pytest.approx(ex.peak_bytes)


# --- greedy coordinate descent ----------------------------------------------

def test_greedy_coordinate_reaches_feasibility():
    cfg = get_config("mixtral-8x7b")
    shape = SHAPES["train_4k"]
    # 4 GiB: the baseline (remat none, micro 1, adamw_f32) is far over
    # budget but the lattice contains fitting plans (best ~1.96 GiB peak)
    hw = _hbm(4.0)
    space = SP.hillclimb_space(MESH)
    scorer = ST.CandidateScorer(measurer=MM.SimulatedMeasurer(MESH))
    score = ST.feasibility_score(scorer, cfg, shape, hw)

    start = space.point(cfg)
    res = ST.greedy_coordinate(space, cfg, shape, score=score, start=start,
                               scorer=scorer)
    assert res.policy == "greedy"
    assert res.measured == scorer.calls > 0
    assert score(res.candidate) <= score(start)
    # the baseline doesn't fit 2 GiB but the space contains plans that do —
    # greedy must land on one, examining far fewer points than the lattice
    assert score(start)[0] == 1
    assert score(res.candidate)[0] == 0
    assert res.considered < len(space)


def test_greedy_respects_constraints():
    """Moves that violate a constraint (microbatches not dividing the
    batch) are never taken."""
    cfg = get_config("h2o-danube-1.8b")
    shape = ShapeConfig("odd", TRAIN, 512, 6)     # batch 6: micro 4, 8 invalid
    space = SP.hillclimb_space(MESH)
    seen = []

    def score(cand):
        seen.append(cand)
        return cand.step_time_penalty()

    res = ST.greedy_coordinate(space, cfg, shape, score=score)
    assert all(shape.global_batch % c.plan.microbatches == 0 for c in seen)
    assert shape.global_batch % res.plan.microbatches == 0


# --- plan_for façade ---------------------------------------------------------

def test_plan_for_strategies_agree_on_fitting():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    sim = MM.SimulatedMeasurer(MESH)
    budget = ST.plan_budget(HW.TPU_V5E)
    for strategy in ("staged", "exhaustive", "greedy"):
        res = ST.plan_for(cfg, shape, None, MESH, strategy=strategy,
                          measurer=sim)
        assert sim.measure_peak(cfg, shape, res.plan) <= budget, strategy
    res = ST.plan_for(cfg, shape, _cls(), MESH, strategy="fastest")
    assert res.policy in ("wsmc", "wsmc_overflow")


def test_plan_for_unknown_strategy():
    cfg = get_config("h2o-danube-1.8b")
    with pytest.raises(KeyError):
        ST.plan_for(cfg, SHAPES["train_4k"], None, MESH, strategy="magic")


# --- pipe / EP mesh dimensions (simulator + predictor) -----------------------

def test_mesh_factors_pipe_shards_weights():
    shards, dp, model = PR.mesh_factors({"data": 4, "model": 2, "pipe": 2})
    assert shards == 16 and dp == 4 and model == 2
    # pipe absent => unchanged legacy behaviour
    assert PR.mesh_factors({"data": 4, "model": 2}) == (8, 4, 2)


def test_simulator_pipe_axis_shards_residents():
    cfg = get_config("h2o-danube-1.8b")          # 24 layers
    shape = SHAPES["train_4k"]
    plan = PR.MemoryPlan(microbatches=8)
    flat = MM.SimulatedMeasurer({"data": 4, "model": 2}).measure(
        cfg, shape, plan)
    piped = MM.SimulatedMeasurer({"data": 4, "model": 2, "pipe": 2}).measure(
        cfg, shape, plan)
    assert piped.argument_bytes < flat.argument_bytes
    assert piped.transient_bytes <= flat.transient_bytes


def test_simulator_pipe_decode_cache_split():
    cfg = get_config("mistral-nemo-12b")
    shape = SHAPES["decode_32k"]
    plan = PR.MemoryPlan(kv_shard="seq")
    c1 = PR.cache_bytes_per_device(cfg, shape, plan, {"data": 4, "model": 2})
    c2 = PR.cache_bytes_per_device(cfg, shape, plan,
                                   {"data": 4, "model": 2, "pipe": 2})
    assert c2 == pytest.approx(c1 / 2)


def test_simulator_ep_adds_alltoall_buffers():
    cfg = get_config("mixtral-8x7b")
    shape = SHAPES["train_4k"]
    t_tp = MM.SimulatedMeasurer(MESH).measure(cfg, shape).transient_bytes
    t_ep = MM.SimulatedMeasurer(MESH, ep=True).measure(
        cfg, shape).transient_bytes
    assert t_ep > t_tp
    # dense archs are EP-indifferent
    dense = get_config("h2o-danube-1.8b")
    d_tp = MM.SimulatedMeasurer(MESH).measure(dense, shape).transient_bytes
    d_ep = MM.SimulatedMeasurer(MESH, ep=True).measure(
        dense, shape).transient_bytes
    assert d_ep == pytest.approx(d_tp)


def test_ep_discriminates_profile_cache_key(tmp_path):
    cache = MM.ProfileCache(str(tmp_path / "p.json"))
    cfg = get_config("mixtral-8x7b")
    shape = SHAPES["train_4k"]
    MM.SimulatedMeasurer(MESH, cache=cache).measure(cfg, shape)
    MM.SimulatedMeasurer(MESH, cache=cache, ep=True).measure(cfg, shape)
    assert len(cache) == 2


def test_ep_none_resolves_like_default_strategy():
    """ep=None means "the default_strategy auto-rule decides": for a MoE
    arch whose expert count tiles the model axis the launch layer will run
    EP, so scoring must model EP too (and distinguish it from ep=False)."""
    cfg = get_config("llama4-scout-17b-a16e")          # 16 experts
    shape = SHAPES["train_4k"]
    auto = SP.Candidate(plan=PR.MemoryPlan())          # ep unset -> auto
    off = SP.Candidate(plan=PR.MemoryPlan(), extras=(("ep", False),))
    assert ST.resolved_ep(cfg, auto, MESH) is True     # 16 % 16 == 0
    assert ST.resolved_ep(cfg, off, MESH) is False
    assert ST.measure_key(auto, cfg, MESH) != ST.measure_key(off, cfg, MESH)
    scorer = ST.CandidateScorer(measurer=MM.SimulatedMeasurer(MESH))
    assert scorer.peak(cfg, shape, auto) > scorer.peak(cfg, shape, off)
    # dense arch: auto resolves to no-EP
    dense = get_config("h2o-danube-1.8b")
    assert ST.resolved_ep(dense, auto, MESH) is False


def test_scorer_builds_per_candidate_simulators():
    """The CandidateScorer resolves each candidate's own mesh/EP — what lets
    one strategy search across meshes."""
    cfg = get_config("mixtral-8x7b")
    shape = SHAPES["train_4k"]
    scorer = ST.CandidateScorer(measurer=MM.SimulatedMeasurer(MESH))
    small = SP.Candidate(plan=PR.MemoryPlan(),
                         mesh=(("data", 2), ("model", 2)))
    big = SP.Candidate(plan=PR.MemoryPlan(),
                       mesh=(("data", 16), ("model", 16)))
    assert scorer.peak(cfg, shape, small) > scorer.peak(cfg, shape, big)
    ep = SP.Candidate(plan=PR.MemoryPlan(), mesh=big.mesh,
                      extras=(("ep", True),))
    assert scorer.peak(cfg, shape, ep) > scorer.peak(cfg, shape, big)
    # 4 peak() calls but only 3 distinct measure keys — the repeated `big`
    # is a memo hit, not a backend invocation
    assert scorer.calls == 3
