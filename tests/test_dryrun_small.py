"""Miniature dry-run: the production flow (plan -> lower -> compile ->
memory/cost/roofline) on an 8-device mesh with reduced configs, covering
all three step kinds and the multi-'pod' axis."""
import pytest

from conftest import run_in_subprocess

# subprocess + XLA compiles => slow tier
pytestmark = pytest.mark.slow


def test_dryrun_flow_all_kinds():
    code = """
import dataclasses, jax
from repro.configs import get_config
from repro.configs.base import ShapeConfig, TRAIN, PREFILL, DECODE
from repro.launch.mesh import make_mesh
from repro.launch import compile as LC
from repro.core import profiler as PF, planner as PL
from repro.core.classifier import classify_profiles
from repro.roofline import analysis as RA
from repro.models.model import ModelSettings

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_config("gemma3-12b").reduced()
for shape in [ShapeConfig("t", TRAIN, 64, 8), ShapeConfig("p", PREFILL, 64, 8),
              ShapeConfig("d", DECODE, 64, 8)]:
    profiles = PF.profile_ladder(cfg, shape, mesh, n_points=2, base_seq=32)
    cls = classify_profiles(profiles)
    dec = PL.wsmc_plan(cfg, shape, cls, dict(mesh.shape))
    tcfg = PF._tcfg_for(dec.plan)
    strategy = PF.strategy_for(cfg, dec.plan, mesh)
    bundle = LC.build(cfg, shape, mesh, strategy=strategy, tcfg=tcfg)
    compiled = bundle.compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0
    cost = RA.component_cost(compiled)
    assert cost.flops > 0
    print("KIND_OK", shape.kind, cls.category.value,
          int(ma.temp_size_in_bytes))
print("DRYRUN_SMALL_OK")
"""
    out = run_in_subprocess(code, devices=8)
    assert "DRYRUN_SMALL_OK" in out
    assert out.count("KIND_OK") == 3


def test_roofline_depth_extrapolation():
    code = """
import dataclasses
from repro.configs import get_config
from repro.configs.base import ShapeConfig, TRAIN
from repro.launch.mesh import make_mesh
from repro.launch import compile as LC
from repro.core import profiler as PF
from repro.core.predictor import MemoryPlan
from repro.roofline import analysis as RA
from repro.models.model import ModelSettings

mesh = make_mesh((2, 2), ("data", "model"))
cfg = get_config("h2o-danube-1.8b").reduced()
shape = ShapeConfig("t", TRAIN, 64, 8)
plan = MemoryPlan()
costs = []
for n_units in (1, 2):
    dcfg = dataclasses.replace(cfg, n_layers=n_units * len(cfg.unit)
                               + len(cfg.tail))
    tc = PF._tcfg_for(plan, settings=ModelSettings(scan_layers=False))
    bundle = LC.build(dcfg, shape, mesh,
                      strategy=PF.strategy_for(dcfg, plan, mesh),
                      tcfg=tc, settings=ModelSettings(scan_layers=False))
    costs.append(RA.component_cost(bundle.compile()))
assert costs[1].flops > costs[0].flops          # deeper costs more
total = RA.extrapolate(costs[0], costs[1], 4)
assert total.flops > costs[1].flops             # 4 units > 2 units
rep = RA.report(cfg, shape, "test", 4, total)
assert rep.t_comp > 0 and rep.bottleneck in ("compute", "memory",
                                             "collective")
print("ROOFLINE_OK", rep.bottleneck)
"""
    out = run_in_subprocess(code, devices=8)
    assert "ROOFLINE_OK" in out


def test_hlo_collective_parser():
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.roofline import hlo as HLO

mesh = make_mesh((4, 2), ("data", "model"))
def f(x, w):
    return jnp.sum(jnp.einsum("bd,df->bf", x, w))
with mesh:
    lowered = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P("data", "model")),
        NamedSharding(mesh, P("model", None)))).lower(
        jax.ShapeDtypeStruct((256, 512), jnp.bfloat16),
        jax.ShapeDtypeStruct((512, 1024), jnp.bfloat16))
    ops = HLO.parse_collectives(lowered.compile().as_text())
kinds = {o.kind for o in ops}
assert "all-reduce" in kinds, kinds
ar = [o for o in ops if o.kind == "all-reduce"][0]
assert ar.group_size == 2 and ar.wire_bytes > 0
print("PARSER_OK", sorted(kinds))
"""
    out = run_in_subprocess(code, devices=8)
    assert "PARSER_OK" in out
