"""End-to-end planned-mesh training (acceptance): `--mesh auto` on 8 fake
host devices selects AND executes a planned mesh, and a forced pipe>=2 run
reaches the same final loss as the pipe=1 run. Subprocess + XLA compiles
=> slow tier."""
import re

import pytest

from conftest import run_in_subprocess

pytestmark = pytest.mark.slow

COMMON = ("--arch h2o-danube-1.8b --reduced --depth 4 --seq 32 --batch 8 "
          "--steps 5 --log-every 1 --remat none --microbatches 4 "
          "--optimizer adamw_f32")


def _train(extra: str) -> str:
    code = f"""
from repro.launch.train import main
raise SystemExit(main({(COMMON + ' ' + extra).split()!r}))
"""
    return run_in_subprocess(code, devices=8)


def _final_loss(out: str) -> float:
    m = re.search(r"final loss ([0-9.]+)", out)
    assert m, out
    return float(m.group(1))


def test_mesh_auto_selects_and_executes():
    code = """
from repro.launch.train import main
raise SystemExit(main(
    "--arch h2o-danube-1.8b --reduced --depth 4 --seq 32 --batch 8 "
    "--steps 3 --log-every 1 --mesh auto".split()))
"""
    out = run_in_subprocess(code, devices=8)
    # the planned mesh is printed, built and actually stepped
    assert "WSMC[auto]" in out and "mesh=" in out
    assert "done: 3 steps" in out
    assert "final loss" in out


def test_forced_pipe_matches_flat_final_loss():
    out_flat = _train("--mesh data:2")
    out_pipe = _train("--mesh data:2,pipe:2")
    assert "schedule=scan" in out_flat
    assert "schedule=pipeline_1f1b" in out_pipe
    l_flat = _final_loss(out_flat)
    l_pipe = _final_loss(out_pipe)
    assert l_pipe == pytest.approx(l_flat, rel=2e-2), (l_pipe, l_flat)
