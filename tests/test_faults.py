"""Fault-tolerance tier, hermetic: seeded chaos injection (transient
executor/allocator faults, mid-run pool shrinks, cancellations, lane
stalls) against the engine's retry/backoff, deadline, degradation-ladder
and audit machinery, plus token-identical snapshot/restore — all on the
scripted executor, ZERO XLA compiles.

The load-bearing guarantees pinned here:
  * a >=500-tick chaos run drains with ZERO leaked blocks and every
    request accounted for (completed or cause-tagged cancelled);
  * every request the chaos run COMPLETES carries exactly the token
    stream a fault-free replay produces (faults delay or cancel work,
    never corrupt it);
  * a mid-run snapshot serializes to JSON and resumes on a FRESH
    allocator/executor token-identically.
"""
import pytest

from repro.serving import (BlockAllocator, ChaosAllocator, ChaosExecutor,
                           Engine, EngineFault, EngineSnapshot, FaultPlan,
                           LadderConfig, LedgerCorruption, OnlineLengthStats,
                           Request, ScriptedExecutor, TransientExecutorError,
                           leak_check, length_stats, survivor_mismatches,
                           synthetic_trace)

VOCAB = 97
N_BLOCKS, KV_BLOCK, N_SLOTS = 48, 4, 8


def _trace(n=48, seed=3, interarrival=12.0):
    return synthetic_trace(n, vocab_size=VOCAB, seed=seed,
                           prompt_lens=(4, 8, 16), gen_lens=(4, 8, 16),
                           mean_interarrival=interarrival,
                           slo_classes=(0, 1, 2))


def _engine(*, plan=None, stats=None, deadline=0, ladder=None, audit="off",
            n_blocks=N_BLOCKS, max_exec_retries=6):
    if plan is not None:
        alloc = ChaosAllocator(n_blocks, KV_BLOCK, "expected", plan=plan)
        execu = ChaosExecutor(ScriptedExecutor(VOCAB), plan)
    else:
        alloc = BlockAllocator(n_blocks, KV_BLOCK, reservation="expected")
        execu = ScriptedExecutor(VOCAB)
    eng = Engine(execu, n_slots=N_SLOTS, allocator=alloc, chunk_prefill=4,
                 prefill_budget=8, stats=stats, faults=plan,
                 deadline=deadline, ladder=ladder, audit=audit,
                 max_exec_retries=max_exec_retries)
    return eng, alloc


def _clean_run(trace, **kw):
    eng, _ = _engine(stats=length_stats(trace), **kw)
    return eng.run(trace, max_ticks=50_000)


# --- the chaos acceptance run ------------------------------------------------

def test_chaos_acceptance_500_ticks():
    """The headline guarantee: a long seeded chaos run (exec + alloc
    faults, a 25% mid-run pool shrink, chaos cancels, lane stalls, a
    deadline, strict every-tick audit, the full degradation ladder)
    drains without deadlock, leaks nothing, accounts for every request,
    and every completion is token-identical to the fault-free replay."""
    trace = _trace()
    clean = _clean_run(trace)
    plan = FaultPlan.generate(11, ticks=512, n_requests=len(trace),
                              n_lanes=N_SLOTS, exec_rate=0.05,
                              alloc_rate=0.05, n_shrinks=1,
                              shrink_frac=0.25, n_cancels=3, n_stalls=2)
    eng, alloc = _engine(plan=plan,
                         stats=OnlineLengthStats(base=length_stats(trace)),
                         deadline=800,
                         ladder=LadderConfig(patience=3, high=0.9),
                         audit="strict")
    rep = eng.run(trace, max_ticks=50_000)

    assert rep.ticks >= 500
    assert rep.shrunk_blocks > 0          # the shrink actually landed
    assert rep.exec_faults + rep.alloc_faults > 0
    assert rep.audit_failures == 0 and rep.audits == rep.ticks
    assert rep.ticks == rep.decode_ticks + rep.admit_ticks + rep.idle_ticks
    assert len(rep.completions) + len(rep.cancellations) == len(trace)
    assert leak_check(alloc) == []
    assert survivor_mismatches(rep, clean) == []


def test_chaos_runs_are_seed_deterministic():
    trace = _trace(n=24)
    plan = FaultPlan.generate(5, ticks=256, n_requests=24, n_lanes=N_SLOTS,
                              exec_rate=0.08, alloc_rate=0.08,
                              n_cancels=2, n_stalls=1)
    reps = []
    for _ in range(2):
        eng, _ = _engine(plan=plan, stats=length_stats(trace))
        reps.append(eng.run(trace, max_ticks=50_000))
    a, b = reps
    assert [(c.rid, c.tokens) for c in a.completions] \
        == [(c.rid, c.tokens) for c in b.completions]
    assert [(c.rid, c.reason) for c in a.cancellations] \
        == [(c.rid, c.reason) for c in b.cancellations]
    assert (a.ticks, a.exec_faults, a.alloc_faults) \
        == (b.ticks, b.exec_faults, b.alloc_faults)


def test_fault_plan_generate_validates():
    with pytest.raises(ValueError, match="ticks"):
        FaultPlan.generate(0, ticks=2)
    with pytest.raises(ValueError, match="rates"):
        FaultPlan.generate(0, exec_rate=1.0)
    with pytest.raises(ValueError, match="shrink_frac"):
        FaultPlan.generate(0, shrink_frac=1.5)


# --- individual fault responses ----------------------------------------------

def test_transient_exec_faults_retry_with_backoff():
    trace = _trace(n=16, interarrival=1.0)
    clean = _clean_run(trace)
    plan = FaultPlan(seed=2, exec_rate=0.25)
    eng, alloc = _engine(plan=plan, stats=length_stats(trace))
    rep = eng.run(trace, max_ticks=50_000)
    assert rep.exec_faults > 0 and rep.backoff_ticks > 0
    assert len(rep.completions) == len(trace)
    assert survivor_mismatches(rep, clean) == []
    assert leak_check(alloc) == []


def test_exec_fault_storm_raises_engine_fault():
    """A PERMANENTLY failing executor must surface as `EngineFault`
    after the bounded retries, not spin forever."""
    class _AlwaysFail(ScriptedExecutor):
        def prefill_batch(self, slots, prompts, tables=None):
            raise TransientExecutorError("wedged device")

    alloc = BlockAllocator(N_BLOCKS, KV_BLOCK, reservation="expected")
    eng = Engine(_AlwaysFail(VOCAB), n_slots=2, allocator=alloc,
                 max_exec_retries=3)
    with pytest.raises(EngineFault, match="max_exec_retries=3"):
        eng.run([Request(rid=0, arrival=0, prompt=(5, 6, 7), max_new=4)],
                max_ticks=500)


def test_chaos_cancel_tags_reason():
    plan = FaultPlan(seed=0, cancels=((2, 0),))
    eng, alloc = _engine(plan=plan)
    rep = eng.run([Request(rid=0, arrival=0, prompt=(3, 4), max_new=30),
                   Request(rid=1, arrival=0, prompt=(5, 6), max_new=4)],
                  max_ticks=5_000)
    assert [(c.rid, c.reason) for c in rep.cancellations] == [(0, "chaos")]
    assert [c.rid for c in rep.completions] == [1]
    assert leak_check(alloc) == []


def test_deadline_cancels_cleanly():
    trace = [Request(rid=i, arrival=0, prompt=(3 + i, 4), max_new=40)
             for i in range(3)]
    eng, alloc = _engine(deadline=4)
    rep = eng.run(trace, max_ticks=5_000)
    assert len(rep.completions) == 0
    assert sorted(c.rid for c in rep.cancellations) == [0, 1, 2]
    assert {c.reason for c in rep.cancellations} == {"deadline"}
    assert leak_check(alloc) == []


def test_stall_delays_but_never_corrupts():
    trace = _trace(n=12, interarrival=1.0)
    clean = _clean_run(trace)
    plan = FaultPlan(seed=0, stalls=((3, 0, 6), (5, 2, 4)))
    eng, alloc = _engine(plan=plan, stats=length_stats(trace))
    rep = eng.run(trace, max_ticks=50_000)
    assert len(rep.completions) == len(trace)
    assert survivor_mismatches(rep, clean) == []
    assert leak_check(alloc) == []


# --- the degradation ladder --------------------------------------------------

def test_shrink_drives_ladder_then_recovers():
    """A 50% mid-run shrink overcommits the pool; the ladder must climb
    (cause-tagged events), work the pressure off via SLO-ordered
    eviction, then de-escalate back to normal — with every request still
    accounted for and the shrunken ledger whole."""
    trace = _trace(n=32, interarrival=1.0)
    plan = FaultPlan(seed=0, shrinks=((6, 0.5),))
    eng, alloc = _engine(plan=plan, stats=length_stats(trace),
                         ladder=LadderConfig(patience=1, high=0.9),
                         audit="strict")
    rep = eng.run(trace, max_ticks=50_000)
    deg = rep.degradation
    assert rep.shrunk_blocks > 0
    assert deg["max_rung"] >= 1 and deg["events"]
    assert all({"tick", "rung", "name", "cause"} <= set(e)
               for e in deg["events"])
    assert deg["final_rung"] == 0        # pressure worked off by the end
    assert len(rep.completions) + len(rep.cancellations) == len(trace)
    assert rep.audit_failures == 0
    assert leak_check(alloc) == []


def test_ladder_bend_gated_by_min_agreement():
    """Rung 2 (kv bend) only applies retention when its agreement prior
    clears the configured floor — the planner's quality gate holds even
    under duress."""
    class _St:
        rung = 2

    def eff(ladder):
        eng, _ = _engine(ladder=ladder)
        return eng._eff_retain(_St())

    gated = LadderConfig(bend_retain=2, bend_agreement=0.90,
                         min_agreement=0.95)
    open_ = LadderConfig(bend_retain=2, bend_agreement=0.96,
                         min_agreement=0.95)
    assert eff(gated) == 0               # prior below floor: no bending
    assert eff(open_) == 2


def test_ladder_tightens_prefill_budget():
    class _St:
        rung = 1

    eng, _ = _engine(ladder=LadderConfig())
    assert eng._eff_budget(_St()) == 4   # halved 8, floored at the chunk

    class _St0:
        rung = 0

    assert eng._eff_budget(_St0()) == 8


# --- audit modes -------------------------------------------------------------

def test_audit_count_mode_tallies_clean_ticks():
    trace = _trace(n=8, interarrival=1.0)
    eng, _ = _engine(stats=length_stats(trace), audit="count")
    rep = eng.run(trace, max_ticks=50_000)
    assert rep.audits == rep.ticks and rep.audit_failures == 0


def test_audit_strict_raises_on_corruption():
    """Sabotage the ledger mid-run (steal a free block out from under
    the allocator) and the strict auditor must fail the very next tick
    with a cause-tagged `LedgerCorruption`."""
    class _Sabotage(ScriptedExecutor):
        def __init__(self, alloc):
            super().__init__(VOCAB)
            self._alloc = alloc

        def decode(self, tokens, positions, tables=None, lanes=None):
            if self._alloc._free:
                self._alloc._free.popleft()      # corrupt: block vanishes
            return super().decode(tokens, positions, tables=tables,
                                  lanes=lanes)

    alloc = BlockAllocator(N_BLOCKS, KV_BLOCK, reservation="expected")
    eng = Engine(_Sabotage(alloc), n_slots=2, allocator=alloc,
                 audit="strict")
    with pytest.raises(LedgerCorruption, match="tick"):
        eng.run([Request(rid=0, arrival=0, prompt=(3, 4), max_new=8)],
                max_ticks=500)


# --- snapshot / restore ------------------------------------------------------

def test_snapshot_requires_suspended_run():
    eng, _ = _engine()
    with pytest.raises(RuntimeError, match="no run to snapshot"):
        eng.snapshot()


def test_resume_requires_fresh_allocator():
    trace = _trace(n=8, interarrival=1.0)
    eng, _ = _engine(stats=length_stats(trace))
    eng.run(trace, max_ticks=50_000, stop_tick=6)
    snap = eng.snapshot()
    used, _ = _engine(stats=length_stats(trace))
    used.run(trace, max_ticks=50_000, stop_tick=6)
    with pytest.raises(ValueError, match="FRESH allocator"):
        used.resume(snap)


def test_snapshot_json_roundtrip():
    trace = _trace(n=12, interarrival=1.0)
    eng, _ = _engine(stats=OnlineLengthStats(base=length_stats(trace)),
                     ladder=LadderConfig())
    eng.run(trace, max_ticks=50_000, stop_tick=8)
    snap = eng.snapshot()
    back = EngineSnapshot.from_json(snap.to_json())
    # tuples round-trip as lists; the canonical JSON form is the pin
    assert back.to_json() == snap.to_json()
    assert (back.tick, back.queue, back.counters) \
        == (snap.tick, snap.queue, snap.counters)


def test_snapshot_resume_token_identical():
    """Suspend a CHAOS run mid-flight, serialize through JSON, resume on
    a completely fresh fault-free engine (new executor, new allocator):
    the union of completions must match the fault-free replay exactly,
    and the restored ledger must drain whole."""
    trace = _trace(n=24, interarrival=2.0)
    clean = _clean_run(trace)
    plan = FaultPlan.generate(11, ticks=128, n_requests=24,
                              n_lanes=N_SLOTS, exec_rate=0.05,
                              alloc_rate=0.05, n_cancels=2, n_stalls=1)
    eng, _ = _engine(plan=plan,
                     stats=OnlineLengthStats(base=length_stats(trace)),
                     ladder=LadderConfig(), audit="strict")
    eng.run(trace, max_ticks=50_000, stop_tick=40)
    snap = EngineSnapshot.from_json(eng.snapshot().to_json())

    fresh, alloc = _engine(
        stats=OnlineLengthStats(base=length_stats(trace)),
        ladder=LadderConfig(), audit="strict")
    rep = fresh.resume(snap, max_ticks=50_000)
    assert len(rep.completions) + len(rep.cancellations) == len(trace)
    assert survivor_mismatches(rep, clean) == []
    assert leak_check(alloc) == []


def test_snapshot_resume_onto_smaller_pool():
    """Restoring onto a SMALLER fresh pool (the budget moved while the
    engine was down) still drains: requests the new pool could never
    admit are cause-tagged `capacity`, everything else completes with
    the same tokens."""
    trace = _trace(n=16, interarrival=1.0)
    clean = _clean_run(trace)
    eng, _ = _engine(stats=length_stats(trace))
    eng.run(trace, max_ticks=50_000, stop_tick=10)
    snap = eng.snapshot()

    fresh, alloc = _engine(stats=length_stats(trace), n_blocks=10)
    rep = fresh.resume(snap, max_ticks=50_000)
    assert len(rep.completions) + len(rep.cancellations) == len(trace)
    assert survivor_mismatches(rep, clean) == []
    assert leak_check(alloc) == []


def test_resume_restart_equivalence_when_nothing_started():
    """A snapshot taken before any work happened resumes into exactly
    the run a fresh engine would produce."""
    trace = _trace(n=10, interarrival=1.0)
    eng, _ = _engine(stats=length_stats(trace))
    eng.run(trace, max_ticks=50_000, stop_tick=0)
    snap = eng.snapshot()
    fresh, _ = _engine(stats=length_stats(trace))
    rep = fresh.resume(snap, max_ticks=50_000)
    base = _clean_run(trace)
    assert [(c.rid, c.tokens) for c in rep.completions] \
        == [(c.rid, c.tokens) for c in base.completions]


# --- online length stats (satellite: live sigma_k) ---------------------------

def test_online_stats_seed_then_track():
    base = length_stats(_trace(n=32, interarrival=1.0))
    ols = OnlineLengthStats(base=base, alpha=0.5)
    # unobserved bucket falls back to the profile
    assert ols.expected_written(8) == base.expected_written(8)
    ols.observe(8, 30)
    ols.observe(8, 30)
    # EW mean moves toward what is actually being served
    assert ols.expected_written(8) > base.expected_written(8)
    assert ols.sigma(8) >= 0.0
    s = ols.summary()
    assert s["observations"] == 2 and 8 in s["by_prompt"]


def test_online_stats_state_roundtrip():
    ols = OnlineLengthStats(alpha=0.25)
    for w in (10, 14, 12, 20):
        ols.observe(4, w)
    other = OnlineLengthStats(alpha=0.25)
    other.load_state(ols.state_dict())
    assert other.expected_written(4, k=1.0) == ols.expected_written(4, k=1.0)
    assert other.summary() == ols.summary()


def test_report_carries_observed_lengths():
    trace = _trace(n=12, interarrival=1.0)
    eng, _ = _engine(stats=OnlineLengthStats(base=length_stats(trace)))
    rep = eng.run(trace, max_ticks=50_000)
    obs = rep.observed_lengths
    assert obs["observations"] == len(trace)
    assert obs["sigma_written"] >= 0.0
