"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# XLA compiles dominate the runtime => slow tier
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(3)


def _attn_inputs(b, s, K, G, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, K, G, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, K, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, K, hd), dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return q, k, v, pos


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("s,K,G,hd,window,chunk,qb,kb", [
    (128, 2, 2, 32, None, None, 64, 64),
    (128, 1, 4, 16, 48, None, 32, 32),
    (256, 2, 1, 64, None, 64, 64, 64),
    (64, 4, 1, 8, 16, None, 64, 64),     # single q block
])
def test_flash_attention_sweep(dtype, atol, s, K, G, hd, window, chunk, qb,
                               kb):
    q, k, v, pos = _attn_inputs(2, s, K, G, hd, dtype)
    out = ops.flash_attention(q, k, v, pos, pos, window=window, chunk=chunk,
                              backend="interpret", q_block=qb, kv_block=kb)
    exp = ref.flash_attention_ref(q, k, v, pos, pos, window=window,
                                  chunk=chunk)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=atol,
                               rtol=atol)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("L,K,G,hd,window,kvb", [
    (128, 2, 2, 32, None, 32),
    (128, 1, 4, 16, 40, 64),
    (96, 8, 1, 8, None, 48),
])
def test_decode_attention_sweep(dtype, atol, L, K, G, hd, window, kvb):
    b = 3
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, K, G, hd), dtype)
    kc = jax.random.normal(ks[1], (b, L, K, hd), dtype)
    vc = jax.random.normal(ks[2], (b, L, K, hd), dtype)
    cpos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (b, L))
    positions = jnp.array([L - 1, L // 2, 7], jnp.int32)
    out = ops.decode_attention(q, kc, vc, cpos, positions, window=window,
                               backend="interpret", kv_block=kvb)
    exp = ref.decode_attention_ref(q, kc, vc, cpos, positions, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=atol,
                               rtol=atol)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("bs,K,G,hd,window", [
    (16, 2, 2, 32, None),
    (8, 1, 4, 16, 40),
    (32, 4, 1, 8, None),
])
def test_paged_decode_attention_sweep(dtype, atol, bs, K, G, hd, window):
    """The block-table kernel vs a dense ring reference: scatter each
    lane's blocks into a contiguous cache, run the plain decode oracle."""
    b, m_blocks, n_blocks = 3, 4, 9
    rng = np.random.default_rng(0)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, K, G, hd), dtype)
    kp = jax.random.normal(ks[1], (n_blocks, bs, K, hd), dtype)
    vp = jax.random.normal(ks[2], (n_blocks, bs, K, hd), dtype)
    # each lane owns a random disjoint set of blocks (0 is scratch);
    # lane i's positions run up to its decode cursor, later slots stay -1
    perm = rng.permutation(np.arange(1, n_blocks)).tolist()
    positions = np.array([3 * bs + bs // 2, bs - 1, 2 * bs], np.int32)
    tables = np.full((b, m_blocks), -1, np.int32)
    pool_pos = np.full((n_blocks, bs), -1, np.int32)
    for i in range(b):
        for j in range(-(-int(positions[i] + 1) // bs)):
            phys = perm.pop()
            tables[i, j] = phys
            for o in range(bs):
                if j * bs + o <= positions[i]:
                    pool_pos[phys, o] = j * bs + o
    out = ops.paged_decode_attention(
        q, kp, vp, jnp.asarray(pool_pos), jnp.asarray(tables),
        jnp.asarray(positions), window=window, backend="interpret")
    # dense reference: gather the blocks into [b, m_blocks*bs, ...]
    safe = np.where(tables >= 0, tables, 0)
    kd = jnp.asarray(np.asarray(kp)[safe].reshape(b, m_blocks * bs, K, hd))
    vd = jnp.asarray(np.asarray(vp)[safe].reshape(b, m_blocks * bs, K, hd))
    cpos = np.where(tables[..., None] >= 0, pool_pos[safe], -1)
    cpos = jnp.asarray(cpos.reshape(b, m_blocks * bs))
    exp = ref.decode_attention_ref(q, kd, vd, cpos, jnp.asarray(positions),
                                   window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=atol,
                               rtol=atol)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("bs,K,G,hd,window", [
    (8, 2, 2, 16, None),
    (8, 1, 4, 16, 12),
])
def test_paged_decode_attention_trimmed_tables(dtype, atol, bs, K, G, hd,
                                               window):
    """The kernel's grid KV extent is the TABLE width: trimming tables to
    the blocks actually allocated (lane compaction does, per tick) must
    not change the output, and UNEVEN per-lane allocation (one lane deep,
    the rest shallow) must match the dense oracle at both widths."""
    b, m_blocks, n_blocks = 3, 6, 12
    rng = np.random.default_rng(3)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, K, G, hd), dtype)
    kp = jax.random.normal(ks[1], (n_blocks, bs, K, hd), dtype)
    vp = jax.random.normal(ks[2], (n_blocks, bs, K, hd), dtype)
    # lane 0 reaches 4 blocks; lanes 1-2 sit in their first block
    positions = np.array([3 * bs + 1, bs // 2, bs - 1], np.int32)
    perm = rng.permutation(np.arange(1, n_blocks)).tolist()
    tables = np.full((b, m_blocks), -1, np.int32)
    pool_pos = np.full((n_blocks, bs), -1, np.int32)
    for i in range(b):
        for j in range(-(-int(positions[i] + 1) // bs)):
            phys = perm.pop()
            tables[i, j] = phys
            for o in range(bs):
                if j * bs + o <= positions[i]:
                    pool_pos[phys, o] = j * bs + o
    # trimmed width = widest allocated row (4), well under m_blocks (6)
    trim = int((tables >= 0).sum(axis=1).max())
    assert trim < m_blocks
    full = ops.paged_decode_attention(
        q, kp, vp, jnp.asarray(pool_pos), jnp.asarray(tables),
        jnp.asarray(positions), window=window, backend="interpret")
    trimmed = ops.paged_decode_attention(
        q, kp, vp, jnp.asarray(pool_pos), jnp.asarray(tables[:, :trim]),
        jnp.asarray(positions), window=window, backend="interpret")
    np.testing.assert_allclose(np.asarray(trimmed, np.float32),
                               np.asarray(full, np.float32), atol=atol,
                               rtol=atol)
    safe = np.where(tables >= 0, tables, 0)
    kd = jnp.asarray(np.asarray(kp)[safe].reshape(b, m_blocks * bs, K, hd))
    vd = jnp.asarray(np.asarray(vp)[safe].reshape(b, m_blocks * bs, K, hd))
    cpos = np.where(tables[..., None] >= 0, pool_pos[safe], -1)
    cpos = jnp.asarray(cpos.reshape(b, m_blocks * bs))
    exp = ref.decode_attention_ref(q, kd, vd, cpos, jnp.asarray(positions),
                                   window=window)
    np.testing.assert_allclose(np.asarray(trimmed, np.float32),
                               np.asarray(exp, np.float32), atol=atol,
                               rtol=atol)


@pytest.mark.parametrize("backend", ["interpret", "blocked"])
@pytest.mark.parametrize("s,h,dk,dv,chunk", [
    (128, 2, 16, 16, 32),
    (64, 1, 8, 24, 64),     # dk != dv
    (96, 4, 32, 32, 32),
])
def test_mlstm_scan_sweep(backend, s, h, dk, dv, chunk):
    b = 2
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, s, h, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, dv))
    ig = jax.random.normal(ks[3], (b, s, h))
    fg = jax.random.normal(ks[4], (b, s, h)) + 2.0
    out, (C, n, m) = ops.mlstm_scan(q, k, v, ig, fg, chunk=chunk,
                                    backend=backend)
    exp, (Cr, nr, mr) = ops.mlstm_scan(q, k, v, ig, fg, backend="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(C), np.asarray(Cr), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), atol=1e-5,
                               rtol=1e-5)


def test_mlstm_decode_step_matches_ref():
    b, h, dk, dv, S = 2, 2, 8, 8, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, S, h, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, S, h, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, S, h, dv))
    ig = jax.random.normal(ks[3], (b, S, h))
    fg = jax.random.normal(ks[4], (b, S, h)) + 1.0
    exp, _ = ops.mlstm_scan(q, k, v, ig, fg, backend="ref")
    C = jnp.zeros((b, h, dk, dv))
    n = jnp.zeros((b, h, dk))
    m = jnp.full((b, h, 1), -jnp.inf)
    outs = []
    state = (C, n, m)
    for t in range(S):
        o, state = ops.mlstm_decode_step(q[:, t], k[:, t], v[:, t],
                                         ig[:, t], fg[:, t], state)
        outs.append(o)
    out = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4,
                               rtol=1e-4)


def test_flash_kernel_bf16_io_f32_math():
    """Kernel must not lose the online-softmax accuracy to bf16 accumulation."""
    q, k, v, pos = _attn_inputs(1, 128, 1, 1, 32, jnp.bfloat16)
    out = ops.flash_attention(q, k, v, pos, pos, backend="interpret",
                              q_block=32, kv_block=32)
    exp = ref.flash_attention_ref(q.astype(jnp.float32),
                                  k.astype(jnp.float32),
                                  v.astype(jnp.float32), pos, pos)
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - exp))) < 0.03


@pytest.mark.parametrize("window,chunk", [(None, None), (48, None),
                                          (None, 64)])
def test_flash_attention_bwd_matches_ref_grads(window, chunk):
    """Pallas dQ/dK/dV kernels vs autodiff through the jnp oracle."""
    b, s, K, G, hd = 1, 128, 2, 2, 16
    q, k, v, pos = _attn_inputs(b, s, K, G, hd, jnp.float32)

    def loss_kernel(q, k, v):
        out = ops.flash_attention_trainable(
            q, k, v, pos, pos, window=window, chunk=chunk,
            q_block=32, kv_block=32, interpret=True)
        return jnp.sum(out * out)

    def loss_ref(q, k, v):
        out = ref.flash_attention_ref(q, k, v, pos, pos, window=window,
                                      chunk=chunk)
        return jnp.sum(out * out)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=2e-4, rtol=2e-4)


def test_flash_fwd_lse_matches_ref():
    b, s, K, G, hd = 2, 64, 1, 2, 16
    q, k, v, pos = _attn_inputs(b, s, K, G, hd, jnp.float32)
    from repro.kernels.flash_attention import flash_attention_fwd
    qh = q.reshape(b, s, K * G, hd)
    out, lse = flash_attention_fwd(qh, k, v, pos, pos, q_block=32,
                                   kv_block=32, interpret=True,
                                   return_lse=True)
    # reference lse
    import numpy as _np
    scale = 1.0 / _np.sqrt(hd)
    s_ = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale
    mask = (pos[:, None, :] <= pos[:, :, None])[:, None, None]
    s_ = jnp.where(jnp.moveaxis(mask, 3, 3), s_, -1e30)
    lse_ref = jax.scipy.special.logsumexp(s_, axis=-1)  # [b,K,G,s]
    lse_ref = jnp.moveaxis(lse_ref.reshape(b, K * G, s), 1, 2)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused paged flash-prefill
# ---------------------------------------------------------------------------

def _prefill_case(seed, b, bs, m_blocks, n_blocks, C, K, G, hd, dtype,
                  quant, starts, n_valid):
    """Build one chunked-prefill scenario: per-lane history written through
    the jnp oracle writer (positions 0..starts[i]-1), block tables covering
    history + chunk, and a chunk at starts[i]..starts[i]+n_valid[i]-1 with
    trailing padding rows (-1). Returns (q, kn, vn, cache, tables, pos)."""
    from repro.models.attention import _paged_write_chunk, quantize_kv
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, C, K, G, hd), dtype)
    kn = jax.random.normal(ks[1], (b, C, K, hd), dtype)
    vn = jax.random.normal(ks[2], (b, C, K, hd), dtype)
    pool_dtype = {"none": dtype, "int8": jnp.int8, "int4": jnp.uint8}[quant]
    hd_s = hd // 2 if quant == "int4" else hd
    cache = {
        "kb": jnp.zeros((n_blocks, bs, K, hd_s), pool_dtype),
        "vb": jnp.zeros((n_blocks, bs, K, hd_s), pool_dtype),
        "pos": jnp.full((n_blocks, bs), -1, jnp.int32),
    }
    if quant != "none":
        cache["ks"] = jnp.zeros((n_blocks, bs, K), jnp.float32)
        cache["vs"] = jnp.zeros((n_blocks, bs, K), jnp.float32)
    # tables cover ceil((start + n_valid) / bs) logical blocks per lane,
    # drawn from a shared permutation of the non-scratch physical blocks
    perm = rng.permutation(np.arange(1, n_blocks)).tolist()
    tables = np.full((b, m_blocks), -1, np.int32)
    for i in range(b):
        need = -(-(starts[i] + n_valid[i]) // bs)
        for j in range(need):
            tables[i, j] = perm.pop()
    tables = jnp.asarray(tables)
    # history through the oracle writer: the kernel must merge ON TOP of
    # previously written (possibly quantized) blocks without disturbing
    # them
    H = max(starts)
    if H:
        kh = jax.random.normal(ks[3], (b, H, K, hd), dtype)
        vh = jax.random.normal(ks[4], (b, H, K, hd), dtype)
        hpos = jnp.asarray([[p if p < st else -1 for p in range(H)]
                            for st in starts], jnp.int32)
        cache = _paged_write_chunk(cache, tables, kh, vh, hpos)
    pos = jnp.asarray([[st + c if c < nv else -1 for c in range(C)]
                       for st, nv in zip(starts, n_valid)], jnp.int32)
    return q, kn, vn, cache, tables, pos


def _run_chunk_append(q, kn, vn, cache, tables, pos, backend, window=None):
    from repro.configs.base import BlockSpec
    from repro.models import attention as A
    return A._chunk_append(q, kn, vn, cache, BlockSpec(window=window), pos,
                           tables, A.AttnSettings(backend=backend))


def _assert_pools_match(got, want, quant):
    """Pool leaves must agree EXCLUDING scratch block 0: the jnp oracle
    parks padding rows there while the kernel predicates the merge off —
    both are dead writes the mask can never surface. Codes and positions
    are bit-exact; the f32 scale stripes get 1-ULP slack because XLA may
    compile `max|x| / qmax` as a reciprocal multiply in one jit context
    and a true division in the other."""
    for key in ("kb", "vb", "pos"):
        np.testing.assert_array_equal(
            np.asarray(got[key])[1:].astype(np.float32),
            np.asarray(want[key])[1:].astype(np.float32), err_msg=key)
    if quant != "none":
        for key in ("ks", "vs"):
            np.testing.assert_allclose(np.asarray(got[key])[1:],
                                       np.asarray(want[key])[1:],
                                       rtol=1e-6, err_msg=key)


@pytest.mark.parametrize("dtype,quant,atol", [
    (jnp.float32, "none", 2e-5),
    (jnp.bfloat16, "none", 3e-2),
    (jnp.float32, "int8", 2e-2),
    (jnp.float32, "int4", 8e-2),
])
@pytest.mark.parametrize("window", [None, 6])
def test_paged_prefill_attention_sweep(dtype, quant, atol, window):
    """The fused write+attend kernel vs the dense jnp oracle (scatter
    through the table, gather the virtual ring, dense SDPA): odd chunk
    lengths with padding rows, a fresh lane, multi-block chunks landing on
    half-full history blocks, and int8/int4 quantize-on-write — outputs
    agree and the pools agree EXACTLY (same codes, scales, positions)."""
    b, bs, m_blocks, n_blocks, C, K, G, hd = 3, 4, 6, 14, 7, 2, 2, 16
    starts, n_valid = (9, 0, 4), (5, 7, 3)   # odd + full + short chunks
    q, kn, vn, cache, tables, pos = _prefill_case(
        11, b, bs, m_blocks, n_blocks, C, K, G, hd, dtype, quant,
        starts, n_valid)
    o_ref, cache_ref = _run_chunk_append(q, kn, vn, cache, tables, pos,
                                         "naive", window=window)
    o_ker, cache_ker = _run_chunk_append(q, kn, vn, cache, tables, pos,
                                         "pallas", window=window)
    _assert_pools_match(cache_ker, cache_ref, quant)
    valid = np.asarray(pos) >= 0
    np.testing.assert_allclose(
        np.asarray(o_ker, np.float32)[valid],
        np.asarray(o_ref, np.float32)[valid], atol=atol, rtol=atol)


@pytest.mark.parametrize("quant", ["none", "int8"])
def test_paged_prefill_attention_trimmed_tables(quant):
    """Like paged decode, the grid's KV extent is the table width: tables
    trimmed to the widest allocated row must produce the same outputs and
    the same pool as the full-width call."""
    b, bs, m_blocks, n_blocks, C, K, G, hd = 3, 4, 8, 14, 4, 1, 4, 16
    starts, n_valid = (8, 0, 4), (4, 4, 4)
    q, kn, vn, cache, tables, pos = _prefill_case(
        5, b, bs, m_blocks, n_blocks, C, K, G, hd, jnp.float32, quant,
        starts, n_valid)
    trim = int((np.asarray(tables) >= 0).sum(axis=1).max())
    assert trim < m_blocks
    o_full, cache_full = _run_chunk_append(q, kn, vn, cache, tables, pos,
                                           "pallas")
    o_trim, cache_trim = _run_chunk_append(q, kn, vn, cache,
                                           tables[:, :trim], pos, "pallas")
    _assert_pools_match(cache_trim, cache_full, quant)
    np.testing.assert_allclose(np.asarray(o_trim, np.float32),
                               np.asarray(o_full, np.float32),
                               atol=2e-5, rtol=2e-5)
    o_ref, _ = _run_chunk_append(q, kn, vn, cache, tables, pos, "naive")
    atol = 2e-5 if quant == "none" else 2e-2
    np.testing.assert_allclose(np.asarray(o_trim, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=atol, rtol=atol)
