"""Recurrent mixers: parallel forms match sequential references; decode
streaming matches sequence processing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref as kref
from repro.models import recurrent as R

# XLA compiles dominate the runtime => slow tier
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(11)


def test_rglru_associative_scan_matches_sequential():
    b, s, w = 2, 64, 16
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, w))
    rg = jax.random.normal(ks[1], (b, s, w))
    ig = jax.random.normal(ks[2], (b, s, w))
    a_param = jax.random.normal(ks[3], (w,))
    ref_out, ref_h = kref.rglru_ref(x, rg, ig, a_param)

    # mirror the model's associative-scan formulation
    f32 = jnp.float32
    log_a = (-8.0 * jax.nn.softplus(a_param) * jax.nn.sigmoid(rg)).astype(f32)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = beta * jax.nn.sigmoid(ig) * x

    def combine(u, w_):
        a1, b1 = u
        a2, b2 = w_
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ref_out),
                               atol=1e-5, rtol=1e-5)


def _decode_stream(apply_fn, params, cfg, x, state0):
    outs = []
    state = state0
    for t in range(x.shape[1]):
        o, state = apply_fn(params, cfg, x[:, t:t + 1], state=state,
                            decode=True)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def test_rglru_block_decode_matches_sequence():
    cfg = get_config("recurrentgemma-9b").reduced()
    params = R.rglru_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 12, cfg.d_model), jnp.float32)
    seq_out, _ = R.rglru_apply(params, cfg, x)
    dec_out = _decode_stream(R.rglru_apply, params, cfg, x,
                             R.rglru_state_init(cfg, 2))
    np.testing.assert_allclose(np.asarray(dec_out), np.asarray(seq_out),
                               atol=2e-2, rtol=2e-2)


def test_mlstm_block_decode_matches_sequence():
    cfg = get_config("xlstm-1.3b").reduced()
    params = R.mlstm_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 12, cfg.d_model), jnp.float32)
    seq_out, _ = R.mlstm_apply(params, cfg, x, backend="blocked", chunk=4)
    dec_out = _decode_stream(
        lambda p, c, xx, state, decode: R.mlstm_apply(p, c, xx, state=state,
                                                      decode=decode),
        params, cfg, x, R.mlstm_state_init(cfg, 2))
    np.testing.assert_allclose(np.asarray(dec_out), np.asarray(seq_out),
                               atol=2e-2, rtol=2e-2)


def test_slstm_block_decode_matches_sequence():
    cfg = get_config("xlstm-1.3b").reduced()
    params = R.slstm_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 10, cfg.d_model), jnp.float32)
    seq_out, _ = R.slstm_apply(params, cfg, x)
    dec_out = _decode_stream(R.slstm_apply, params, cfg, x,
                             R.slstm_state_init(cfg, 2))
    np.testing.assert_allclose(np.asarray(dec_out), np.asarray(seq_out),
                               atol=2e-2, rtol=2e-2)


def test_rglru_stability_long_sequence():
    """|a| < 1 by construction: state cannot blow up over long sequences."""
    cfg = get_config("recurrentgemma-9b").reduced()
    params = R.rglru_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 512, cfg.d_model), jnp.float32) * 10.0
    out, _ = R.rglru_apply(params, cfg, x)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
