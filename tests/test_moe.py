"""MoE routing invariants and grouped-dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe as MOE

KEY = jax.random.PRNGKey(5)


def _setup(arch="mixtral-8x7b"):
    cfg = get_config(arch).reduced()
    params = MOE.moe_init(KEY, cfg)
    return cfg, params


def test_moe_forward_finite_and_shape():
    cfg, params = _setup()
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y, aux = MOE.moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux["lb_loss"]) >= 1.0 - 1e-3   # E * sum(f*p) >= 1 always


def test_dispatch_capacity_respected():
    cfg, params = _setup()
    x = jax.random.normal(KEY, (1, 32, cfg.d_model), jnp.float32)
    s, E, k = 32, cfg.n_experts, cfg.top_k
    C = max(1, int(-(-s * k * cfg.capacity_factor // E)))
    h = x  # probe internals via the public einsum contract
    y, _ = MOE.moe_apply(params, cfg, x)
    # capacity: rerun the routing math and verify slot counts
    from repro.models import layers
    hh = layers.rmsnorm(params["norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,de->bse", hh.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, -1)
    _, idx = jax.lax.top_k(probs, k)
    counts = np.zeros(E)
    for t in range(s):
        for c in range(k):
            counts[int(idx[0, t, c])] += 1
    # no expert can receive more than C *kept* tokens; raw counts may exceed
    assert C >= 1


def test_grouped_equals_single_group_when_no_drops():
    """Group size must not change results when capacity is ample (no token
    drops): per-group capacity C = g·k·cf/E covers every assignment at
    cf = E/k."""
    import dataclasses
    cfg, params = _setup()
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    x = jax.random.normal(KEY, (1, 32, cfg.d_model), jnp.float32)
    y1, _ = MOE.moe_apply(params, cfg, x, group_size=32)
    y2, _ = MOE.moe_apply(params, cfg, x, group_size=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)


def test_decode_batch_grouping():
    cfg, params = _setup()
    x = jax.random.normal(KEY, (8, 1, cfg.d_model), jnp.float32)
    y, _ = MOE.moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_top1_arch():
    cfg, params = _setup("llama4-scout-17b-a16e")
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y, aux = MOE.moe_apply(params, cfg, x)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_padding_path():
    cfg, params = _setup()
    x = jax.random.normal(KEY, (1, 19, cfg.d_model), jnp.float32)  # odd seq
    y, _ = MOE.moe_apply(params, cfg, x, group_size=8)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_combine_weights_normalized():
    """Kept tokens' outputs are convex combinations: scaling all experts'
    outputs by c scales y by <= c (gate weights sum to <= 1)."""
    cfg, params = _setup()
    x = jax.random.normal(KEY, (1, 16, cfg.d_model), jnp.float32)
    y1, _ = MOE.moe_apply(params, cfg, x)
    p2 = dict(params)
    p2["wo"] = params["wo"] * 2.0
    y2, _ = MOE.moe_apply(p2, cfg, x)
    # doubling wo doubles expert outputs; combine is linear in them
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1),
                               atol=1e-4, rtol=1e-3)
