"""Simulator-vs-compile parity: on small configs the analytical backend's
resident/transient bytes must land inside a tolerance band of the XLA
memory_analysis() ground truth. Compile-backed => slow tier."""
import pytest

from repro.configs import get_config
from repro.configs.base import DECODE, PREFILL, TRAIN, ShapeConfig
from repro.core import measure as MM

pytestmark = pytest.mark.slow

# The analytical model tracks residents tightly (closed-form params/opt/
# cache byte accounting) and transients to within a small constant factor
# (XLA fusion decisions aren't modeled). Bands validated in EXPERIMENTS;
# re-calibrate here if the simulator's terms change.
RESIDENT_BAND = (0.90, 1.10)
TRANSIENT_BAND = (0.25, 4.00)

CASES = [
    ("h2o-danube-1.8b", TRAIN), ("h2o-danube-1.8b", PREFILL),
    ("h2o-danube-1.8b", DECODE),
    ("mixtral-8x7b", TRAIN), ("mixtral-8x7b", PREFILL),
    ("xlstm-1.3b", TRAIN),
]


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch,kind", CASES)
def test_simulator_matches_compile(arch, kind, mesh):
    cfg = get_config(arch).reduced()
    shape = ShapeConfig(f"{kind}", kind, 128, 4)
    compiled = MM.CompileMeasurer(mesh).measure(cfg, shape)
    simulated = MM.SimulatedMeasurer(mesh).measure(cfg, shape)

    r = simulated.argument_bytes / max(compiled.argument_bytes, 1.0)
    assert RESIDENT_BAND[0] <= r <= RESIDENT_BAND[1], (
        f"resident off: sim={simulated.argument_bytes:.0f} "
        f"compile={compiled.argument_bytes:.0f} ratio={r:.2f}")

    t = simulated.transient_bytes / max(compiled.transient_bytes, 1.0)
    assert TRANSIENT_BAND[0] <= t <= TRANSIENT_BAND[1], (
        f"transient off: sim={simulated.transient_bytes:.0f} "
        f"compile={compiled.transient_bytes:.0f} ratio={t:.2f}")


def test_transient_grows_with_input_on_both_backends(mesh):
    """Both backends must agree transients grow with the input rung — the
    monotonicity the profiling ladder (and Eq. 5's inc) relies on. (The
    remat *ordering* is deliberately NOT asserted against the CPU compile
    backend: at smoke scale XLA's recompute buffers outweigh the residual
    savings, so remat grows CPU temp — the REMAT_SCALE model is a TPU-side
    planning assumption, covered hermetically in test_measure.py.)"""
    cfg = get_config("h2o-danube-1.8b").reduced()
    for m in (MM.CompileMeasurer(mesh), MM.SimulatedMeasurer(mesh)):
        small = m.measure(cfg, ShapeConfig("a", TRAIN, 64, 4))
        big = m.measure(cfg, ShapeConfig("b", TRAIN, 256, 4))
        assert big.transient_bytes > small.transient_bytes, m.backend


def test_compile_measurer_populates_shared_cache(tmp_path, mesh):
    cache = MM.ProfileCache(str(tmp_path / "p.json"))
    m = MM.CompileMeasurer(mesh, cache=cache)
    cfg = get_config("h2o-danube-1.8b").reduced()
    shape = ShapeConfig("t", TRAIN, 64, 4)
    p1 = m.measure(cfg, shape)
    assert m.last_compiled is not None
    p2 = m.measure(cfg, shape)            # served from cache: no compile
    assert m.last_compiled is None
    assert p2 == p1
    assert cache.hits == 1
