"""Simulator-vs-compile parity: on small configs the analytical backend's
resident/transient bytes must land inside a tolerance band of the XLA
memory_analysis() ground truth. Compile-backed => slow tier."""
import pytest

from repro.configs import get_config
from repro.configs.base import DECODE, PREFILL, TRAIN, ShapeConfig
from repro.core import measure as MM

pytestmark = pytest.mark.slow

# The analytical model tracks residents tightly (closed-form params/opt/
# cache byte accounting) and transients to within a small constant factor
# (XLA fusion decisions aren't modeled). Bands validated in EXPERIMENTS;
# re-calibrate here if the simulator's terms change.
RESIDENT_BAND = (0.90, 1.10)
TRANSIENT_BAND = (0.25, 4.00)

CASES = [
    ("h2o-danube-1.8b", TRAIN), ("h2o-danube-1.8b", PREFILL),
    ("h2o-danube-1.8b", DECODE),
    ("mixtral-8x7b", TRAIN), ("mixtral-8x7b", PREFILL),
    ("xlstm-1.3b", TRAIN),
]


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch,kind", CASES)
def test_simulator_matches_compile(arch, kind, mesh):
    cfg = get_config(arch).reduced()
    shape = ShapeConfig(f"{kind}", kind, 128, 4)
    compiled = MM.CompileMeasurer(mesh).measure(cfg, shape)
    simulated = MM.SimulatedMeasurer(mesh).measure(cfg, shape)

    r = simulated.argument_bytes / max(compiled.argument_bytes, 1.0)
    assert RESIDENT_BAND[0] <= r <= RESIDENT_BAND[1], (
        f"resident off: sim={simulated.argument_bytes:.0f} "
        f"compile={compiled.argument_bytes:.0f} ratio={r:.2f}")

    t = simulated.transient_bytes / max(compiled.transient_bytes, 1.0)
    assert TRANSIENT_BAND[0] <= t <= TRANSIENT_BAND[1], (
        f"transient off: sim={simulated.transient_bytes:.0f} "
        f"compile={compiled.transient_bytes:.0f} ratio={t:.2f}")


def test_transient_grows_with_input_on_both_backends(mesh):
    """Both backends must agree transients grow with the input rung — the
    monotonicity the profiling ladder (and Eq. 5's inc) relies on. (The
    remat *ordering* is deliberately NOT asserted against the CPU compile
    backend: at smoke scale XLA's recompute buffers outweigh the residual
    savings, so remat grows CPU temp — the REMAT_SCALE model is a TPU-side
    planning assumption, covered hermetically in test_measure.py.)"""
    cfg = get_config("h2o-danube-1.8b").reduced()
    for m in (MM.CompileMeasurer(mesh), MM.SimulatedMeasurer(mesh)):
        small = m.measure(cfg, ShapeConfig("a", TRAIN, 64, 4))
        big = m.measure(cfg, ShapeConfig("b", TRAIN, 256, 4))
        assert big.transient_bytes > small.transient_bytes, m.backend


def test_simulator_matches_executed_1f1b_pipeline():
    """The predictor's 1F1B in-flight transient model validated against the
    EXECUTED pipeline: the compile backend lowers the actual
    pipeline_1f1b train step (launch.compile routes through
    runtime.schedule on a pipe mesh) on fake host devices, and the
    simulator's peak must land inside a band of memory_analysis(). Bands
    are CPU-compile smoke-scale derived (XLA scratch the simulator does
    not model dominates the lower edge)."""
    from conftest import run_in_subprocess
    code = """
from repro.configs import get_config
from repro.configs.base import ShapeConfig, TRAIN, depth_variant
from repro.core import measure as MM
from repro.core.predictor import MemoryPlan
from repro.launch.mesh import build_mesh

cfg = depth_variant(get_config("h2o-danube-1.8b").reduced(), 4)
shape = ShapeConfig("t", TRAIN, 64, 8)
for ms, micro, remat in (({"data": 2, "pipe": 2}, 4, "none"),
                         ({"data": 2, "pipe": 4}, 4, "none")):
    plan = MemoryPlan(remat=remat, microbatches=micro)
    comp = MM.CompileMeasurer(build_mesh(ms)).measure(cfg, shape, plan)
    sim = MM.SimulatedMeasurer(ms).measure(cfg, shape, plan)
    r = sim.argument_bytes / max(comp.argument_bytes, 1.0)
    assert 0.85 <= r <= 1.15, ("resident", ms, r)
    t = sim.transient_bytes / max(comp.transient_bytes, 1.0)
    assert 0.15 <= t <= 4.00, ("transient", ms, t)
    p = sim.peak_bytes / max(comp.peak_bytes, 1.0)
    assert 0.20 <= p <= 2.50, ("peak", ms, p)
    print("PIPE_PARITY_OK", ms, round(r, 3), round(t, 3), round(p, 3))
"""
    out = run_in_subprocess(code, devices=8)
    assert out.count("PIPE_PARITY_OK") == 2


def test_compile_measurer_populates_shared_cache(tmp_path, mesh):
    cache = MM.ProfileCache(str(tmp_path / "p.json"))
    m = MM.CompileMeasurer(mesh, cache=cache)
    cfg = get_config("h2o-danube-1.8b").reduced()
    shape = ShapeConfig("t", TRAIN, 64, 4)
    p1 = m.measure(cfg, shape)
    assert m.last_compiled is not None
    p2 = m.measure(cfg, shape)            # served from cache: no compile
    assert m.last_compiled is None
    assert p2 == p1
    assert cache.hits == 1
