"""WSMC math: classifier thresholds (Tables I-II), predictor (Eqs. 6-11),
planner lattice, min_devices (Eq. 9)."""
import pytest

from repro import hw as HW
from repro.configs import SHAPES, get_config
from repro.core import classifier as C
from repro.core import expansion as E
from repro.core import planner as PL
from repro.core import predictor as PR


def _mk_profile(alpha, input_bytes=1e6, n_stages=1, seq=512, **kw):
    return E.MemoryProfile(
        arch="x", shape_name="s", kind="train", n_devices=8, seq_len=seq,
        global_batch=8, n_stages=n_stages, input_bytes=input_bytes,
        argument_bytes=0.0, transient_bytes=alpha * input_bytes * n_stages,
        output_bytes=0.0, reported_peak=0.0, **kw)


# --- Table I / II thresholds ------------------------------------------------

@pytest.mark.parametrize("alpha,inc,cat", [
    (1.0, 2.0, C.Category.EXPANDING_RAPID),
    (1.5, 2.5, C.Category.EXPANDING_RAPID),
    (1.0, 1.9, C.Category.EXPANDING_MEDIUM),
    (5.0, 0.5, C.Category.EXPANDING_MEDIUM),
    (0.75, 5.0, C.Category.MEDIUM),
    (0.51, 0.1, C.Category.MEDIUM),
    (0.5, 9.0, C.Category.SHRINKING),
    (0.1, 0.0, C.Category.SHRINKING),
])
def test_classify_thresholds(alpha, inc, cat):
    assert C.classify(alpha, inc) == cat


def test_classification_partitions_space():
    """Every (α, inc) lands in exactly one category."""
    for alpha in (0.0, 0.3, 0.5, 0.7, 1.0, 3.0, 50.0):
        for inc in (0.0, 1.0, 2.0, 10.0):
            assert C.classify(alpha, inc) in C.Category


def test_factor_table_is_papers():
    assert C.FACTOR_SHUF[C.Category.EXPANDING_RAPID] == 4
    assert C.FACTOR_SHUF[C.Category.EXPANDING_MEDIUM] == 3
    assert C.FACTOR_SHUF[C.Category.MEDIUM] == 2
    assert C.FACTOR_SHUF[C.Category.SHRINKING] == 1


# --- Eq. 4/5 ----------------------------------------------------------------

def test_mean_expansion_ratio():
    ps = [_mk_profile(2.0), _mk_profile(4.0)]
    assert abs(E.mean_expansion_ratio(ps) - 3.0) < 1e-9


def test_increasing_rate_linear_is_one():
    ps = [_mk_profile(2.0, input_bytes=x) for x in (1e6, 2e6, 4e6)]
    assert abs(E.increasing_rate(ps) - 1.0) < 1e-6


def test_increasing_rate_superlinear():
    # transient ∝ input² -> inc grows past 2
    ps = []
    for x in (1e6, 2e6, 4e6):
        p = _mk_profile(1.0, input_bytes=x)
        object.__setattr__(p, "transient_bytes", x * x / 1e6)
        ps.append(p)
    assert E.increasing_rate(ps) > 2.0


def test_fitted_slope_exact_on_linear_data():
    ps = [_mk_profile(3.0, input_bytes=x) for x in (1e6, 2e6, 3e6)]
    assert abs(E.fitted_slope(ps) - 3.0) < 1e-6


# --- Eqs. 6-11 ---------------------------------------------------------------

def _cls(cat=C.Category.MEDIUM, alpha=0.8, inc=1.0, slope=0.8, intercept=0.0):
    return C.Classification(category=cat, alpha=alpha, inc=inc, slope=slope,
                            intercept=intercept)


MESH = {"data": 16, "model": 16}


def test_capacity_eq11():
    assert HW.capacity_from_requirement(900, 300) == pytest.approx(
        1200 * 4 / 3 + HW.TPU_V5E.reserved_bytes)


def test_predict_monotone_in_microbatches():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    cls = _cls()
    t1 = PR.transient_bytes(cfg, shape, PR.MemoryPlan(microbatches=1), cls,
                            MESH)
    t4 = PR.transient_bytes(cfg, shape, PR.MemoryPlan(microbatches=4), cls,
                            MESH)
    assert t4 == pytest.approx(t1 / 4)


def test_predict_remat_ordering():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    cls = _cls()
    ts = [PR.transient_bytes(cfg, shape, PR.MemoryPlan(remat=r), cls, MESH)
          for r in ("none", "dots", "full")]
    assert ts[0] > ts[1] > ts[2]


def test_resident_includes_opt_state():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    r_f32 = PR.resident_bytes(cfg, shape, PR.MemoryPlan(optimizer="adamw_f32"),
                              MESH)
    r_af = PR.resident_bytes(cfg, shape,
                             PR.MemoryPlan(optimizer="adafactor"), MESH)
    assert r_f32 > r_af


def test_decode_cache_dominates_resident():
    cfg = get_config("mistral-nemo-12b")
    shape = SHAPES["decode_32k"]
    plan = PR.MemoryPlan(kv_shard="seq")
    cache = PR.cache_bytes_per_device(cfg, shape, plan, MESH)
    assert cache > 0
    res = PR.resident_bytes(cfg, shape, plan, MESH)
    assert res > cache  # params + cache


def test_min_devices_monotone():
    cfg = get_config("nemotron-4-340b")
    shape = SHAPES["train_4k"]
    cls = _cls(C.Category.MEDIUM)
    light = PR.MemoryPlan(remat="full", microbatches=16,
                          optimizer="adafactor")
    heavy = PR.MemoryPlan(remat="none", microbatches=1,
                          optimizer="adamw_f32")
    dl = PR.min_devices(cfg, shape, light, cls)
    dh = PR.min_devices(cfg, shape, heavy, cls)
    assert dl > 0
    assert dh == -1 or dh >= dl


# --- planner ------------------------------------------------------------------

def test_candidate_lattice_fastest_first():
    cfg = get_config("h2o-danube-1.8b")
    cands = PL.candidate_plans(cfg, SHAPES["train_4k"])
    pens = [p.step_time_penalty() for p in cands]
    assert pens == sorted(pens)
    assert cands[0].remat == "none" and cands[0].microbatches == 1


def test_wsmc_plan_picks_first_fitting():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    dec = PL.wsmc_plan(cfg, shape, _cls(), MESH)
    assert dec.prediction.fits
    assert dec.policy == "wsmc"
    # a plan one notch faster must NOT fit (otherwise it would be chosen)
    cands = [p for p in PL.candidate_plans(cfg, shape)
             if (shape.global_batch // p.microbatches) % 16 == 0]
    idx = cands.index(dec.plan)
    for faster in cands[:idx]:
        assert not PR.predict(cfg, shape, faster, _cls(), MESH).fits


def test_default_plan_is_safest():
    cfg = get_config("h2o-danube-1.8b")
    plan = PL.default_plan(cfg, SHAPES["train_4k"])
    assert plan.remat == "full" and plan.optimizer == "adafactor"


def test_oracle_search_counts_compiles():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    budget = HW.TPU_V5E.hbm_bytes / HW.CAPACITY_HEADROOM \
        - HW.TPU_V5E.reserved_bytes
    calls = []

    def fake_measure(plan):
        calls.append(plan)
        # only full remat fits in this fake world
        return budget * (0.5 if plan.remat == "full" else 10.0)

    plan, peak, n = PL.oracle_plan(cfg, shape, fake_measure)
    assert plan.remat == "full"
    assert n == len(calls) and n > 1
