"""Pipeline parallelism: pipelined forward/backward == sequential."""
import pytest

from conftest import run_in_subprocess

# subprocess + XLA compiles => slow tier
pytestmark = pytest.mark.slow


def test_pipeline_matches_sequential():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel.pipeline import pipeline_apply, split_stages

mesh = make_mesh((4,), ("pipe",))
n_stages, n_layers, d = 4, 8, 16
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (n_layers, d, d)) * 0.3

def layer(w, x):
    return jnp.tanh(x @ w)

def stage_fn(stage_ws, x):   # stage_ws [layers_per_stage, d, d]
    def body(c, w):
        return layer(w, c), None
    y, _ = jax.lax.scan(body, x, stage_ws)
    return y

stage_params = split_stages({"w": ws}, n_stages)["w"]
n_micro, mb = 8, 4
x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

out_pipe = pipeline_apply(lambda p, xx: stage_fn(p, xx), stage_params, x,
                          mesh=mesh, axis="pipe")

def sequential(ws, x_flat):
    def body(c, w):
        return layer(w, c), None
    y, _ = jax.lax.scan(body, x_flat, ws)
    return y

out_seq = jax.vmap(lambda xx: sequential(ws, xx))(x)
np.testing.assert_allclose(np.asarray(out_pipe), np.asarray(out_seq),
                           atol=1e-5, rtol=1e-5)

# backward: grads through the pipeline match sequential grads
def loss_pipe(ws_stacked):
    sp = split_stages({"w": ws_stacked}, 4)["w"]
    return jnp.sum(pipeline_apply(lambda p, xx: stage_fn(p, xx), sp, x,
                                  mesh=mesh, axis="pipe") ** 2)

def loss_seq(ws_):
    return jnp.sum(jax.vmap(lambda xx: sequential(ws_, xx))(x) ** 2)

g_pipe = jax.grad(loss_pipe)(ws)
g_seq = jax.grad(loss_seq)(ws)
np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                           atol=2e-4, rtol=2e-4)
print("PIPELINE_OK")
"""
    out = run_in_subprocess(code, devices=4)
    assert "PIPELINE_OK" in out


def test_pipeline_mixed_dtype_stage():
    """The scan-carry dtype derives from the stage OUTPUT (jax.eval_shape),
    so a stage_fn whose output dtype differs from its input (bf16
    activations -> fp32 head) pipelines without poisoning the carry."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel.pipeline import pipeline_apply, split_stages

mesh = make_mesh((4,), ("pipe",))
n_stages, d = 4, 16
key = jax.random.PRNGKey(0)
ws = (jax.random.normal(key, (4, d, d)) * 0.3).astype(jnp.bfloat16)

def stage_fn(stage_ws, x):        # bf16 weights, fp32 output
    w = stage_ws[0]
    return jnp.tanh(x.astype(jnp.bfloat16) @ w).astype(jnp.float32)

sp = split_stages({"w": ws}, n_stages)["w"]
x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, d)).astype(jnp.bfloat16)
out = pipeline_apply(stage_fn, sp, x, mesh=mesh, axis="pipe")
assert out.dtype == jnp.float32, out.dtype

def ref(xx):
    y = xx
    for i in range(4):
        y = jnp.tanh(y.astype(jnp.bfloat16) @ ws[i]).astype(jnp.float32)
    return y
out_ref = jax.vmap(ref)(x)
np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                           atol=1e-2, rtol=1e-2)
print("MIXED_DTYPE_OK")
"""
    out = run_in_subprocess(code, devices=4)
    assert "MIXED_DTYPE_OK" in out


@pytest.mark.parametrize("pipe", [2, 4])
def test_1f1b_schedule_matches_sequential(pipe):
    """Gradient/loss parity of the 1F1B runtime schedule against the
    single-shot reference, on a (data, pipe) mesh of fake host devices."""
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.base import depth_variant
from repro.launch.mesh import build_mesh
from repro.models import init_params
from repro.parallel.axes import axis_rules
from repro.runtime import schedule as SCH
from repro.runtime.train_step import TrainStepConfig, make_loss_fn
from repro.search import execplan as XP

pipe = {pipe}
cfg = depth_variant(get_config("h2o-danube-1.8b").reduced(), 4)
tcfg = TrainStepConfig(microbatches=4)
mesh = build_mesh({{"data": 2, "pipe": pipe}})
loss_pipe = SCH.make_pipeline_loss_fn(cfg, tcfg, mesh)
loss_ref = make_loss_fn(cfg, tcfg)

params = init_params(jax.random.PRNGKey(0), cfg)
key = jax.random.PRNGKey(1)
batch = {{"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
          "targets": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}}

eplan = XP.ExecutionPlan(mesh_axes=tuple(sorted(dict(mesh.shape).items())),
                         schedule=SCH.SCHEDULE_PIPELINE)
with mesh, axis_rules(eplan.strategy().rules(), mesh=mesh):
    (v_p, m_p), g_p = jax.jit(jax.value_and_grad(loss_pipe, has_aux=True))(
        params, batch)
(v_r, m_r), g_r = jax.jit(jax.value_and_grad(loss_ref, has_aux=True))(
    params, batch)

np.testing.assert_allclose(float(v_p), float(v_r), rtol=2e-3)
# compare leaf-by-leaf on the host (raveling sharded outputs through jnp
# re-lays them out; device_get per leaf is the ground truth)
import jax.tree_util as jtu
leaves_p = jtu.tree_leaves_with_path(g_p)
leaves_r = jtu.tree_leaves_with_path(g_r)
assert len(leaves_p) == len(leaves_r) > 0
for (path, a), (_, b) in zip(leaves_p, leaves_r):
    np.testing.assert_allclose(
        np.asarray(jax.device_get(a), np.float32),
        np.asarray(jax.device_get(b), np.float32),
        atol=2e-2, rtol=2e-2, err_msg=jtu.keystr(path))
print("PARITY_OK", pipe, float(v_p), float(v_r))
"""
    out = run_in_subprocess(code, devices=8)
    assert "PARITY_OK" in out
