"""Pipeline parallelism: pipelined forward/backward == sequential."""
import pytest

from conftest import run_in_subprocess

# subprocess + XLA compiles => slow tier
pytestmark = pytest.mark.slow


def test_pipeline_matches_sequential():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel.pipeline import pipeline_apply, split_stages

mesh = make_mesh((4,), ("pipe",))
n_stages, n_layers, d = 4, 8, 16
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (n_layers, d, d)) * 0.3

def layer(w, x):
    return jnp.tanh(x @ w)

def stage_fn(stage_ws, x):   # stage_ws [layers_per_stage, d, d]
    def body(c, w):
        return layer(w, c), None
    y, _ = jax.lax.scan(body, x, stage_ws)
    return y

stage_params = split_stages({"w": ws}, n_stages)["w"]
n_micro, mb = 8, 4
x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

out_pipe = pipeline_apply(lambda p, xx: stage_fn(p, xx), stage_params, x,
                          mesh=mesh, axis="pipe")

def sequential(ws, x_flat):
    def body(c, w):
        return layer(w, c), None
    y, _ = jax.lax.scan(body, x_flat, ws)
    return y

out_seq = jax.vmap(lambda xx: sequential(ws, xx))(x)
np.testing.assert_allclose(np.asarray(out_pipe), np.asarray(out_seq),
                           atol=1e-5, rtol=1e-5)

# backward: grads through the pipeline match sequential grads
def loss_pipe(ws_stacked):
    sp = split_stages({"w": ws_stacked}, 4)["w"]
    return jnp.sum(pipeline_apply(lambda p, xx: stage_fn(p, xx), sp, x,
                                  mesh=mesh, axis="pipe") ** 2)

def loss_seq(ws_):
    return jnp.sum(jax.vmap(lambda xx: sequential(ws_, xx))(x) ** 2)

g_pipe = jax.grad(loss_pipe)(ws)
g_seq = jax.grad(loss_seq)(ws)
np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                           atol=2e-4, rtol=2e-4)
print("PIPELINE_OK")
"""
    out = run_in_subprocess(code, devices=4)
    assert "PIPELINE_OK" in out
