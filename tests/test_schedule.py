"""Planned-mesh execution layer, hermetic tier: schedule dispatch +
validation, split_stages error paths, ExecutionPlan promotion/build, the
runnable mesh_space, and the `--mesh auto` dry-run smoke — all with ZERO
XLA compiles (the executed pipeline itself is covered by the slow tier:
test_pipeline.py / test_parity_slow.py / test_train_pipeline.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config
from repro.configs.base import DECODE, TRAIN, ShapeConfig, depth_variant
from repro.core import measure as MM
from repro.core import planner as PL
from repro.core import predictor as PR
from repro.core import profiler as PF
from repro.parallel.pipeline import split_stages
from repro.runtime import schedule as SCH
from repro.runtime.train_step import TrainStepConfig, make_train_step
from repro.search import execplan as XP
from repro.search import space as SP


def _cls(cfg=None, shape=None):
    m = MM.SimulatedMeasurer({"data": 8})
    return PF.classify_workload(cfg or get_config("h2o-danube-1.8b"),
                                shape or SHAPES["train_4k"], None,
                                measurer=m)


def _no_compile(monkeypatch):
    import repro.launch.compile as LC

    def boom(*a, **k):
        raise AssertionError("XLA compile attempted in hermetic test")
    monkeypatch.setattr(LC, "build", boom)


# --- schedule dispatch -------------------------------------------------------

def test_schedule_kind_dispatch():
    assert SCH.schedule_kind(TRAIN, 1, 1) == SCH.SCHEDULE_SINGLE
    assert SCH.schedule_kind(TRAIN, 8, 1) == SCH.SCHEDULE_SCAN
    assert SCH.schedule_kind(TRAIN, 8, 2) == SCH.SCHEDULE_PIPELINE
    # serving steps are always single-shot, whatever the knobs say
    assert SCH.schedule_kind(DECODE, 8, 2) == SCH.SCHEDULE_SINGLE


def test_make_train_step_exposes_schedule():
    cfg = get_config("h2o-danube-1.8b").reduced()
    s1 = make_train_step(cfg, TrainStepConfig(microbatches=1))
    s4 = make_train_step(cfg, TrainStepConfig(microbatches=4))
    assert s1.schedule == SCH.SCHEDULE_SINGLE
    assert s4.schedule == SCH.SCHEDULE_SCAN


def test_make_train_step_rejects_bad_requests():
    cfg = get_config("h2o-danube-1.8b").reduced()
    with pytest.raises(ValueError, match="unknown schedule"):
        SCH.make_train_step(cfg, TrainStepConfig(), schedule="gpipe")
    with pytest.raises(ValueError, match="microbatches > 1"):
        SCH.make_train_step(cfg, TrainStepConfig(microbatches=1),
                            schedule=SCH.SCHEDULE_SCAN)
    with pytest.raises(ValueError, match="real jax Mesh"):
        SCH.make_train_step(cfg, TrainStepConfig(microbatches=4),
                            mesh={"data": 2, "pipe": 2},
                            schedule=SCH.SCHEDULE_PIPELINE)


def test_validate_pipeline_error_paths():
    cfg = depth_variant(get_config("h2o-danube-1.8b").reduced(), 4)
    ok = TrainStepConfig(microbatches=4)
    # happy path: mesh-shape dicts are enough to validate against
    assert SCH.validate_pipeline(cfg, ok, {"data": 2, "pipe": 2}) == 2
    with pytest.raises(ValueError, match="no pipe axis"):
        SCH.validate_pipeline(cfg, ok, {"data": 4})
    with pytest.raises(ValueError, match="never fills"):
        SCH.validate_pipeline(cfg, TrainStepConfig(microbatches=1),
                              {"pipe": 2})
    with pytest.raises(ValueError, match="not divisible"):
        SCH.validate_pipeline(cfg, ok, {"pipe": 3})
    with pytest.raises(ValueError, match="model axis"):
        SCH.validate_pipeline(cfg, ok, {"model": 2, "pipe": 2})
    moe = depth_variant(get_config("mixtral-8x7b").reduced(), 4)
    with pytest.raises(ValueError, match="MoE"):
        SCH.validate_pipeline(moe, ok, {"pipe": 2})


def test_split_stages_error_paths():
    params = {"w": jnp.zeros((6, 3))}
    out = split_stages(params, 2)
    assert out["w"].shape == (2, 3, 3)
    with pytest.raises(ValueError, match="does not divide"):
        split_stages(params, 4)
    with pytest.raises(ValueError, match="n_stages"):
        split_stages(params, 0)


# --- ExecutionPlan -----------------------------------------------------------

def test_execution_plan_promotion_and_strategy():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    space = SP.mesh_space(cfg, shape, max_devices=64, data=(4,), model=(1,),
                          pipe=(2,), executable=True)
    cand = space.point(cfg, microbatches=8, pipe=2, data=4, model=1)
    from repro.search.strategies import SearchResult
    res = SearchResult(cand, "wsmc", 1)
    ep = XP.from_search_result(cfg, shape, res)
    assert ep.schedule == SCH.SCHEDULE_PIPELINE
    assert ep.mesh_shape == {"data": 4, "model": 1, "pipe": 2}
    assert ep.n_devices == 8 and ep.pipe == 2
    st = ep.strategy()
    assert st.pipeline and st.rules()["layers"] == "pipe"
    assert "mesh=" in ep.describe() and "pipeline_1f1b" in ep.describe()
    # serving results never promote to a pipeline schedule
    dec = XP.from_search_result(cfg, SHAPES["decode_32k"], res)
    assert dec.schedule == SCH.SCHEDULE_SINGLE


def test_execution_plan_build_on_host():
    ep = XP.ExecutionPlan(mesh_axes=(("data", 1),))
    mesh, strategy = ep.build(jax.devices())
    assert dict(mesh.shape) == {"data": 1}
    assert not strategy.pipeline
    big = XP.ExecutionPlan(mesh_axes=(("data", 64),))
    with pytest.raises(ValueError, match="devices"):
        big.build(jax.devices())


def test_host_execution_subsumes_host_mesh_for():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    # best-effort model axis over surviving devices (old host_mesh_for)
    ep = XP.host_execution(cfg, shape, PR.MemoryPlan(), 6, model_parallel=4)
    assert ep.mesh_shape == {"data": 2, "model": 3}
    ep = XP.host_execution(cfg, shape, PR.MemoryPlan(microbatches=8), 8,
                           model_parallel=2)
    assert ep.mesh_shape == {"data": 4, "model": 2}
    assert ep.schedule == SCH.SCHEDULE_SCAN


# --- the runnable mesh space -------------------------------------------------

def test_executable_space_rejects_unrunnable_pipe():
    shape = SHAPES["train_4k"]
    # repeats=1 after reduced(): no pipe split possible
    flat = get_config("h2o-danube-1.8b").reduced()
    space = XP.auto_mesh_space(flat, shape, n_devices=8)
    assert all(c.mesh_shape["pipe"] == 1 for c in space.candidates(flat,
                                                                   shape))
    # depth 4 makes pipe 2/4 executable, but never together with TP
    deep = depth_variant(flat, 4)
    space = XP.auto_mesh_space(deep, shape, n_devices=8)
    cands = space.candidates(deep, shape)
    assert any(c.mesh_shape["pipe"] > 1 for c in cands)
    for c in cands:
        if c.mesh_shape["pipe"] > 1:
            assert c.mesh_shape["model"] == 1
            assert c.plan.microbatches >= c.mesh_shape["pipe"]


def test_pipe_legal_tests_unit_repeats_not_n_layers():
    """The stages split the stacked unit repeats (tail runs outside), so a
    tail-bearing arch whose n_layers % pipe != 0 but repeats % pipe == 0
    must still be plannable — PIPE_LEGAL mirrors validate_pipeline."""
    cfg = get_config("recurrentgemma-9b")        # repeats=12, tail=2 -> 38
    assert cfg.n_layers % 4 != 0 and cfg.repeats % 4 == 0
    shape = SHAPES["train_4k"]
    space = SP.mesh_space(cfg, shape, max_devices=64, data=(2,), model=(1,),
                          pipe=(4,), executable=True)
    cands = space.candidates(cfg, shape)
    assert any(c.mesh_shape["pipe"] == 4 for c in cands)
    SCH.validate_pipeline(cfg, TrainStepConfig(microbatches=4),
                          {"data": 2, "pipe": 4})


def test_mesh_search_prefers_filling_the_host():
    """With the compute-parallel ordering term, candidates that use more of
    the device budget come first (more devices = less work per device)."""
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    small = SP.Candidate(plan=PR.MemoryPlan(), mesh=(("data", 1),))
    big = SP.Candidate(plan=PR.MemoryPlan(), mesh=(("data", 8),))
    assert big.step_time_penalty() < small.step_time_penalty()
    ep = XP.plan_execution(cfg, shape, _cls(cfg, shape), n_devices=8)
    assert ep.n_devices == 8


def test_plan_execution_zero_compiles(monkeypatch):
    _no_compile(monkeypatch)
    cfg = depth_variant(get_config("h2o-danube-1.8b").reduced(), 4)
    shape = ShapeConfig("t", TRAIN, 64, 8)
    cls = _cls(cfg, shape)
    for strategy in ("fastest", "staged", "exhaustive", "greedy"):
        ep = XP.plan_execution(cfg, shape, cls, n_devices=8,
                               strategy=strategy)
        assert ep.n_devices <= 8
        assert ep.schedule in SCH.SCHEDULES
        # the promoted plan is executable by construction
        if ep.pipe > 1:
            SCH.validate_pipeline(
                cfg, TrainStepConfig(microbatches=ep.plan.microbatches),
                ep.mesh_shape)


def test_plan_deployment_facade():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    ep = PL.plan_deployment(cfg, shape, _cls(cfg, shape), n_devices=16)
    assert isinstance(ep, XP.ExecutionPlan)
    assert ep.n_devices <= 16


# --- `--mesh auto` dry-run smoke (zero compiles) ----------------------------

def test_dryrun_mesh_auto_simulate(tmp_path, monkeypatch):
    _no_compile(monkeypatch)
    from repro.launch import dryrun as DR
    cache = MM.ProfileCache(str(tmp_path / "p.json"))
    measurers = {"screen": MM.SimulatedMeasurer(DR.MESH_SHAPES["single"],
                                                cache=cache)}
    kb = {}
    res = DR.run_cell("h2o-danube-1.8b", SHAPES["train_4k"], measurers, kb,
                      do_roofline=False, auto_mesh=True, backend="simulate",
                      cache=cache, max_devices=64)
    assert res["status"] == "ok"
    ep = res["execution_plan"]
    assert ep["n_devices"] <= 64
    assert ep["schedule"] in SCH.SCHEDULES
    assert res["mesh_planned"]["peak_static_bytes"] > 0
    assert res["mesh_planned"]["n_devices"] == ep["n_devices"]


def test_dryrun_mesh_auto_cli_main(tmp_path, monkeypatch):
    """The full `python -m repro.launch.dryrun --mesh auto --backend
    simulate` flow, in-process and compile-free."""
    _no_compile(monkeypatch)
    from repro.launch import dryrun as DR
    out = tmp_path / "cells"
    rc = DR.main(["--arch", "h2o-danube-1.8b", "--shape", "train_4k",
                  "--mesh", "auto", "--backend", "simulate",
                  "--no-roofline", "--out", str(out),
                  "--kb", str(tmp_path / "kb.json"), "--max-devices", "64"])
    assert rc == 0
    import json
    cell = json.loads(
        (out / "h2o-danube-1.8b__train_4k.json").read_text())
    assert cell["status"] == "ok"
    assert cell["execution_plan"]["mesh"]["data"] >= 1


# --- predictor: planned-pipe resident model ---------------------------------

def test_pipe_resident_model_splits_only_the_unit_stack():
    cfg = get_config("h2o-danube-1.8b")
    flat = PR.sharded_param_count(cfg, {"data": 4})
    piped = PR.sharded_param_count(cfg, {"data": 4, "pipe": 2})
    # pipe halves the unit stack but replicates embed/head/norm
    assert flat / 2 < piped < flat


def test_pipe_drops_grad_accumulator_resident():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    plan = PR.MemoryPlan(microbatches=8)
    scan = PR.resident_bytes(cfg, shape, plan, {"data": 4})
    pipe = PR.resident_bytes(cfg, shape, plan, {"data": 4, "pipe": 2})
    # the pipeline schedule has no f32 grad-accumulator argument
    assert pipe < scan


def test_simulator_pipe_transient_has_boundary_carries():
    cfg = get_config("h2o-danube-1.8b")
    shape = SHAPES["train_4k"]
    deep = MM.simulated_transient_bytes(cfg, shape,
                                        PR.MemoryPlan(microbatches=8),
                                        {"data": 4, "pipe": 2})
    deeper = MM.simulated_transient_bytes(cfg, shape,
                                          PR.MemoryPlan(microbatches=32),
                                          {"data": 4, "pipe": 2})
    # more microbatches = more scan ticks = more boundary carries, even
    # though the per-microbatch activations shrink
    assert deep > 0 and deeper > 0


def test_legacy_facade_signature_unchanged():
    """compile.py / tests / benchmarks call make_train_step(cfg, tcfg)."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    tcfg = TrainStepConfig(microbatches=2)
    step = make_train_step(cfg, tcfg)
    assert step.schedule == SCH.SCHEDULE_SCAN
    assert callable(step)


def test_fit_microbatches_respects_mesh():
    from repro.launch.train import fit_microbatches, parse_mesh
    cfg = depth_variant(get_config("h2o-danube-1.8b").reduced(), 4)
    plan = PR.MemoryPlan(microbatches=8)
    # micro=8 over batch 8 leaves per-micro batch 1: unshardable over data=2
    fit = fit_microbatches(cfg, plan, {"data": 2, "pipe": 2}, 8)
    assert fit.microbatches == 4
    # already-valid plans pass through untouched
    assert fit_microbatches(cfg, fit, {"data": 2, "pipe": 2}, 8) is fit
    # a pipeline that can never fill raises
    with pytest.raises(ValueError, match="cannot run"):
        fit_microbatches(cfg, plan, {"data": 8, "pipe": 4}, 8)
    # unknown mesh axes are rejected at parse time
    with pytest.raises(ValueError, match="unknown mesh axis"):
        parse_mesh("data:2,pip:2")


def test_execution_plan_roundtrips_overrides():
    ep = XP.ExecutionPlan(plan=PR.MemoryPlan(microbatches=4),
                          mesh_axes=(("data", 2), ("pipe", 2)),
                          schedule=SCH.SCHEDULE_PIPELINE)
    bumped = dataclasses.replace(
        ep, plan=dataclasses.replace(ep.plan, remat="full"))
    assert bumped.schedule == SCH.SCHEDULE_PIPELINE
    assert bumped.plan.remat == "full" and ep.plan.remat == "none"
