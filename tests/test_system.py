"""End-to-end behaviour tests for the paper's system (WSMC-JAX).

The paper's claim chain, miniaturized: profile a workload cheaply ->
classify -> plan its memory configuration -> the plan trains as well as the
default while using (predictably) less memory.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig, TRAIN
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import ModelSettings, init_params
from repro.models.attention import AttnSettings
from repro.optim import optimizers as opt
from repro.runtime.train_step import TrainStepConfig, make_train_step

# XLA compiles dominate the runtime => slow tier
pytestmark = pytest.mark.slow

SETTINGS = ModelSettings(attn=AttnSettings(backend="blocked", q_block=16,
                                           kv_block=16))


def _train(cfg, tcfg, steps=20, seq=64, batch=4, seed=0):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    opt_state = opt.init_state(tcfg.optimizer, params)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                    global_batch=batch, seed=seed))
    losses = []
    for s in range(steps):
        batch_ = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        params, opt_state, m = step_fn(params, opt_state, batch_,
                                       jnp.asarray(s))
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases_baseline():
    cfg = get_config("h2o-danube-1.8b").reduced()
    tcfg = TrainStepConfig(remat="none", microbatches=1,
                           optimizer=opt.OptimizerConfig(lr=1e-2),
                           settings=SETTINGS, warmup_steps=2, total_steps=40)
    losses = _train(cfg, tcfg)
    assert losses[-1] < losses[0] - 0.5


def test_memory_plan_equivalent_training():
    """The paper's key operational claim: the WSMC-planned (memory-saving)
    configuration reaches the same loss as the memory-hungry default —
    remat/microbatching change memory, not math."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    base = TrainStepConfig(remat="none", microbatches=1,
                           optimizer=opt.OptimizerConfig(lr=1e-2),
                           settings=SETTINGS, warmup_steps=2, total_steps=40)
    lean = TrainStepConfig(remat="full", microbatches=4,
                           optimizer=opt.OptimizerConfig(lr=1e-2),
                           settings=SETTINGS, warmup_steps=2, total_steps=40)
    l_base = _train(cfg, base, steps=12)
    l_lean = _train(cfg, lean, steps=12)
    # same trajectory within numerical tolerance of microbatch reduction order
    assert abs(l_base[-1] - l_lean[-1]) < 0.15, (l_base[-1], l_lean[-1])


def test_grad_compression_still_trains():
    cfg = get_config("h2o-danube-1.8b").reduced()
    tcfg = TrainStepConfig(remat="none", microbatches=1,
                           optimizer=opt.OptimizerConfig(lr=1e-2),
                           settings=SETTINGS, warmup_steps=2,
                           total_steps=40, compress_grads=True)
    losses = _train(cfg, tcfg)
    assert losses[-1] < losses[0] - 0.4


def test_wsmc_end_to_end_on_cpu_mesh():
    """Profile -> classify -> plan -> train with the planned config."""
    from repro.core import planner as PL
    from repro.core.classifier import Classification, Category
    cfg = get_config("h2o-danube-1.8b").reduced()
    shape = ShapeConfig("t", TRAIN, 64, 4)
    # classification from known-profile values (full pipeline in
    # test_dryrun_small; here keep it single-device fast)
    cls = Classification(category=Category.EXPANDING_MEDIUM, alpha=3.0,
                         inc=1.2, slope=3.0, intercept=0.0)
    dec = PL.wsmc_plan(cfg, shape, cls, {"data": 1, "model": 1})
    tcfg = TrainStepConfig(remat=dec.plan.remat,
                           microbatches=dec.plan.microbatches,
                           optimizer=opt.OptimizerConfig(
                               kind=dec.plan.optimizer, lr=1e-2),
                           settings=SETTINGS, warmup_steps=2,
                           total_steps=40)
    losses = _train(cfg, tcfg, steps=12)
    assert losses[-1] < losses[0]
