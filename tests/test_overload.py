"""Overload-proof paged serving, hermetic tier: the BlockAllocator
reservation ledger (worst vs expected modes, refcounted prefixes,
hardening against double-free / over-commit / interleaved exhaustion),
the trace length-stats profile, optimistic admission, prefix-block
sharing, and SLO-aware eviction-and-requeue — all on the scripted
executor, ZERO XLA compiles. Token parity of the eviction path against
`greedy_generate` on the real executor lives in the slow tier
(test_serve.py)."""
import pytest

from repro.serving import (BlockAllocator, Engine, PoolExhausted, Request,
                           ScriptedExecutor, length_stats, synthetic_trace)
from repro.serving.engine import _percentile

VOCAB = 97


def _req(rid, prompt_len=4, max_new=4, arrival=0, slo=0, prefix=None):
    prompt = tuple((3 + rid * 5 + i) % (VOCAB - 2) + 2
                   for i in range(prompt_len))
    if prefix is not None:
        prompt = tuple(prefix) + prompt
    return Request(rid=rid, arrival=arrival, prompt=prompt, max_new=max_new,
                   prefix_id=(0 if prefix is not None else None),
                   prefix_len=(len(prefix) if prefix is not None else 0),
                   slo=slo)


def _tokens(report):
    return {c.rid: list(c.tokens) for c in report.completions}


# --- BlockAllocator hardening ------------------------------------------------

def test_allocator_double_free_raises():
    a = BlockAllocator(4, 2)
    a.reserve(0, 2)
    a.alloc(0)
    a.free(0)
    with pytest.raises(RuntimeError, match="double free"):
        a.free(0)


def test_allocator_reserve_beyond_capacity_raises():
    a = BlockAllocator(4, 2)
    with pytest.raises(RuntimeError, match="over-commits"):
        a.reserve(0, 5)
    a.reserve(0, 4)
    with pytest.raises(RuntimeError, match="over-commits"):
        a.reserve(1, 1)


def test_allocator_duplicate_reservation_raises():
    a = BlockAllocator(4, 2)
    a.reserve(0, 1)
    with pytest.raises(RuntimeError, match="already holds"):
        a.reserve(0, 1)


def test_allocator_alloc_without_reservation_raises():
    a = BlockAllocator(4, 2)
    with pytest.raises(RuntimeError, match="no reservation"):
        a.alloc(7)


def test_allocator_worst_mode_caps_alloc_at_reservation():
    a = BlockAllocator(4, 2)
    a.reserve(0, 2)
    a.alloc(0)
    a.alloc(0)
    with pytest.raises(RuntimeError, match="exceeded its reservation"):
        a.alloc(0)


def test_allocator_expected_mode_overdrafts_then_exhausts():
    """Expected mode: alloc past the reservation is legal (that is the
    optimistic bet) and a dry pool raises PoolExhausted, not a silent
    wrong answer."""
    a = BlockAllocator(3, 2, reservation="expected")
    a.reserve(0, 1)
    a.alloc(0)
    a.alloc(0)          # overdraft past the reservation: allowed
    a.alloc(0)
    assert a.free_blocks == 0
    with pytest.raises(PoolExhausted):
        a.alloc(0)


def test_allocator_interleaved_exhaustion_and_reuse():
    """Interleaved reserve/alloc/free never loses a block: the free list
    plus owned blocks always partition the pool, and freed blocks are
    immediately reusable."""
    a = BlockAllocator(6, 2)
    a.reserve(0, 3)
    a.reserve(1, 3)
    got0 = [a.alloc(0) for _ in range(3)]
    got1 = [a.alloc(1) for _ in range(2)]
    assert len(set(got0) | set(got1)) == 5
    assert a.free_blocks + a.in_use == 6
    returned = a.free(0)
    assert sorted(returned) == sorted(got0)
    a.reserve(2, 3)
    got2 = [a.alloc(2) for _ in range(3)]
    assert not (set(got2) & set(got1))
    assert a.free_blocks + a.in_use == 6
    assert a.peak_committed <= a.n_blocks


def test_allocator_prefix_refcount_never_negative():
    a = BlockAllocator(8, 2)
    blocks = a.create_prefix("sys", 2)
    assert len(blocks) == 2
    a.acquire_prefix("sys")
    a.release_prefix("sys")
    with pytest.raises(RuntimeError, match="negative"):
        a.release_prefix("sys")
    with pytest.raises(RuntimeError, match="negative"):
        a.release_prefix("never-created")


def test_allocator_cached_prefix_is_reclaimable_capacity():
    """A refcount-0 prefix stays cached (re-acquirable without a new
    prefill) but does not count against admission, and is reclaimed when
    an alloc needs its blocks."""
    a = BlockAllocator(4, 2)
    a.create_prefix("sys", 2)
    assert a.prefix_refs("sys") == 0
    assert a.committed == 0                  # unreferenced: free capacity
    assert a.available_blocks == 4
    a.reserve(0, 4)                          # full-pool reservation admits
    got = [a.alloc(0) for _ in range(4)]     # ...which forces the reclaim
    assert len(set(got)) == 4
    assert a.prefix_refs("sys") == -1        # reclaimed
    with pytest.raises(KeyError):
        a.acquire_prefix("sys")


def test_allocator_referenced_prefix_survives_pressure():
    a = BlockAllocator(4, 2, reservation="expected")
    a.create_prefix("sys", 2)
    a.acquire_prefix("sys")
    a.reserve(0, 1)
    a.alloc(0)
    a.alloc(0)
    with pytest.raises(PoolExhausted):       # referenced: NOT reclaimable
        a.alloc(0)
    assert a.prefix_refs("sys") == 1


def test_allocator_duplicate_prefix_raises():
    a = BlockAllocator(4, 2)
    a.create_prefix("sys", 1)
    with pytest.raises(RuntimeError, match="already cached"):
        a.create_prefix("sys", 1)


# --- hypothesis: ledger invariants under arbitrary interleavings -------------

def test_allocator_property_invariants():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed (optional test dep)")
    given = hypothesis.given
    st = hypothesis.strategies

    @hypothesis.settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["reserve", "alloc", "free",
                                               "mkpfx", "acq", "rel"]),
                              st.integers(0, 3)),
                    max_size=40),
           st.sampled_from(["worst", "expected"]))
    def run(ops, mode):
        a = BlockAllocator(8, 2, reservation=mode)
        for op, x in ops:
            try:
                if op == "reserve":
                    a.reserve(x, x + 1)
                elif op == "alloc":
                    a.alloc(x)
                elif op == "free":
                    a.free(x)
                elif op == "mkpfx":
                    a.create_prefix(f"p{x}", x + 1)
                elif op == "acq":
                    a.acquire_prefix(f"p{x}")
                else:
                    a.release_prefix(f"p{x}")
            except (RuntimeError, KeyError):
                pass                         # rejected ops must not corrupt
            # the ledger partitions the pool exactly, refcounts never go
            # negative, and no block is owned twice
            owned = [b for ids in a._owned.values() for b in ids]
            pfx = [b for p in a._prefix.values() for b in p["blocks"]]
            assert a.free_blocks + len(owned) + len(pfx) == a.n_blocks
            assert len(set(owned) | set(pfx)) == len(owned) + len(pfx)
            assert all(p["refs"] >= 0 for p in a._prefix.values())
            assert a.committed <= a.n_blocks
            assert a.available_blocks >= a.free_blocks

    run()


# --- trace: length stats and prefix determinism ------------------------------

def test_length_stats_per_bucket_and_fallback():
    trace = [_req(0, prompt_len=4, max_new=3),
             _req(1, prompt_len=4, max_new=5),
             _req(2, prompt_len=8, max_new=2)]
    s = length_stats(trace)
    m, sd, mx = s.by_prompt[4]               # written = 6 and 8
    assert (m, mx) == (7.0, 8) and sd == pytest.approx(1.0)
    assert s.by_prompt[8] == (9.0, 0.0, 9)
    # k scales the margin, clamped at the bucket max
    assert s.expected_written(4, 0.0) == 7.0
    assert s.expected_written(4, 1.0) == 8.0
    assert s.expected_written(4, 99.0) == 8.0
    # unseen bucket falls back to the whole-trace distribution
    assert s.expected_written(16, 0.0) == pytest.approx(s.mean)


def test_prefix_trace_leaves_base_stream_unperturbed():
    base = synthetic_trace(8, vocab_size=VOCAB, seed=3)
    pfx = synthetic_trace(8, vocab_size=VOCAB, seed=3, prefix_len=6)
    for b, p in zip(base, pfx):
        assert p.prompt[6:] == b.prompt
        assert p.prompt[:6] == pfx[0].prompt[:6]      # one shared prefix
        assert (p.max_new, p.arrival) == (b.max_new, b.arrival)
        assert p.prefix_id == 0 and p.prefix_len == 6
    assert all(r.prefix_id is None for r in base)


def test_slo_classes_drawn_without_perturbing_base():
    base = synthetic_trace(8, vocab_size=VOCAB, seed=3)
    slo = synthetic_trace(8, vocab_size=VOCAB, seed=3, slo_classes=(0, 2))
    assert [r.prompt for r in slo] == [r.prompt for r in base]
    assert set(r.slo for r in slo) == {0, 2}


# --- optimistic admission ----------------------------------------------------

def _overload_trace(n=10):
    """Burst arrivals, short typical generations with one long tail —
    worst-case reservations leave most of the pool idle."""
    return [_req(rid, prompt_len=4, max_new=(16 if rid == 0 else 2))
            for rid in range(n)]


def test_optimistic_admission_beats_worst_case_token_identically():
    trace = _overload_trace()
    n_blocks, block = 12, 4

    def run(mode):
        alloc = BlockAllocator(n_blocks, block, reservation=mode)
        stats = length_stats(trace) if mode == "expected" else None
        eng = Engine(ScriptedExecutor(VOCAB), n_slots=8, allocator=alloc,
                     stats=stats, sigma_k=0.0)
        return eng.run(trace)

    worst = run("worst")
    opt = run("expected")
    # every request still completes, with the exact same token streams
    assert _tokens(opt) == _tokens(worst)
    assert len(opt.completions) == len(trace)
    # worst case: the tail request reserves ceil((4+16-1)/4)=5 blocks and
    # every short one 2, so 4 fit the 12-block pool; expected admission
    # reserves E[written]=6.4 -> 2 blocks each and fits 6 (1.5x)
    assert worst.max_concurrent == 4
    assert opt.max_concurrent >= 6
    assert opt.ticks <= worst.ticks          # never slower under overload


def test_optimistic_reservations_are_expected_not_worst():
    trace = _overload_trace()
    alloc = BlockAllocator(12, 4, reservation="expected")
    Engine(ScriptedExecutor(VOCAB), n_slots=8, allocator=alloc,
           stats=length_stats(trace), sigma_k=0.0).run(trace)
    # E[written | prompt 4] = (19 + 9*5)/10 = 6.4 -> 2 blocks, so peak
    # commitment stays far below 8 worst-case-5-block reservations
    assert alloc.peak_committed <= 12
    assert alloc.peak_in_use <= 12


def test_worst_mode_peak_blocks_within_committed():
    """Non-optimistic mode: actual usage never exceeds the worst-case
    commitment the ledger promised (the benchmark asserts this too)."""
    trace = _overload_trace()
    alloc = BlockAllocator(12, 4)
    report = Engine(ScriptedExecutor(VOCAB), n_slots=8,
                    allocator=alloc).run(trace)
    assert report.peak_blocks <= alloc.peak_committed <= alloc.n_blocks


# --- eviction-and-requeue ----------------------------------------------------

def test_eviction_requeue_is_token_identical_and_terminates():
    """Drive an expected-mode pool into exhaustion: evictions must happen,
    every request must still complete (no deadlock, no starvation), and
    the replayed requests emit exactly the tokens of an unpressured run."""
    trace = [_req(rid, prompt_len=4, max_new=8) for rid in range(6)]
    stats = length_stats([_req(rid, prompt_len=4, max_new=2)
                          for rid in range(6)])   # wrong-on-purpose profile
    tight = BlockAllocator(8, 4, reservation="expected")
    pressured = Engine(ScriptedExecutor(VOCAB), n_slots=6, allocator=tight,
                       stats=stats, sigma_k=0.0).run(trace)
    roomy = Engine(ScriptedExecutor(VOCAB), n_slots=6,
                   allocator=BlockAllocator(64, 4)).run(trace)
    assert pressured.evictions > 0
    assert len(pressured.completions) == len(trace)
    assert _tokens(pressured) == _tokens(roomy)
    assert pressured.ticks == pressured.decode_ticks \
        + pressured.admit_ticks + pressured.idle_ticks


def test_eviction_stress_no_deadlock():
    """Sustained overload with chunked re-prefill: many rounds of evict +
    requeue still make forward progress to full completion."""
    trace = [_req(rid, prompt_len=8, max_new=12, arrival=rid // 4)
             for rid in range(16)]
    stats = length_stats([_req(0, prompt_len=8, max_new=1)])
    alloc = BlockAllocator(10, 4, reservation="expected")
    report = Engine(ScriptedExecutor(VOCAB), n_slots=8, allocator=alloc,
                    chunk_prefill=4, stats=stats,
                    sigma_k=0.0).run(trace, max_ticks=20_000)
    assert len(report.completions) == len(trace)
    assert report.evictions > 0
    roomy = Engine(ScriptedExecutor(VOCAB), n_slots=8,
                   allocator=BlockAllocator(64, 4),
                   chunk_prefill=4).run(trace)
    assert _tokens(report) == _tokens(roomy)


def test_eviction_prefers_loosest_slo_class():
    """Under pressure the slo=1 (looser) request is evicted before any
    slo=0 request, regardless of progress."""
    trace = [_req(0, prompt_len=4, max_new=10, slo=0),
             _req(1, prompt_len=4, max_new=10, slo=1),
             _req(2, prompt_len=4, max_new=10, slo=0)]
    stats = length_stats([_req(0, prompt_len=4, max_new=1)])
    alloc = BlockAllocator(6, 4, reservation="expected")
    evicted = []
    eng = Engine(ScriptedExecutor(VOCAB), n_slots=3, allocator=alloc,
                 stats=stats, sigma_k=0.0)
    orig = eng._evict

    def spy(slots, i, queue):
        evicted.append(slots[i].req.rid)
        orig(slots, i, queue)
    eng._evict = spy
    report = eng.run(trace)
    assert len(report.completions) == 3
    assert evicted and evicted[0] == 1       # loosest class goes first
    assert _tokens(report) == _tokens(
        Engine(ScriptedExecutor(VOCAB), n_slots=3,
               allocator=BlockAllocator(64, 4)).run(trace))


# --- prefix-block sharing ----------------------------------------------------

def _prefix_trace(n=6, prefix_len=8, prompt_len=4, max_new=4):
    prefix = tuple(2 + (i * 11) % (VOCAB - 2) for i in range(prefix_len))
    return [_req(rid, prompt_len=prompt_len, max_new=max_new, prefix=prefix)
            for rid in range(n)]


def test_prefix_sharing_token_identical_and_cuts_prefill_work():
    trace = _prefix_trace(n=6, prefix_len=8)
    block = 4

    def run(share):
        ex = ScriptedExecutor(VOCAB)
        rep = Engine(ex, n_slots=6,
                     allocator=BlockAllocator(40, block),
                     chunk_prefill=block, prefix_share=share).run(trace)
        return rep, ex

    shared, ex_s = run(True)
    plain, ex_p = run(False)
    assert _tokens(shared) == _tokens(plain)
    assert len(shared.completions) == len(trace)
    # one prefix prefill + per-request suffixes vs full prompts every time
    assert ex_s.chunk_tokens < ex_p.chunk_tokens
    assert ex_s.chunk_tokens == 8 + 6 * 4    # prefix once, 6 private tails


def test_prefix_sharing_multiplies_concurrency_per_block():
    """Shared prefix blocks are charged once, so the same pool admits more
    sharers than private-prefix requests."""
    trace = _prefix_trace(n=8, prefix_len=8, max_new=2)
    block = 4
    pool = 14       # 2 prefix blocks + 8 x (1 own tail + 0.25 boundary...)

    def run(share):
        return Engine(ScriptedExecutor(VOCAB), n_slots=8,
                      allocator=BlockAllocator(pool, block),
                      chunk_prefill=block, prefix_share=share).run(trace)

    shared, plain = run(True), run(False)
    assert _tokens(shared) == _tokens(plain)
    assert shared.max_concurrent > plain.max_concurrent
    # sharing admits more at once, so ABSOLUTE peak usage may rise; the
    # win is physical blocks per concurrently served request
    assert (shared.peak_blocks / shared.max_concurrent
            < plain.peak_blocks / plain.max_concurrent)


def test_prefix_sharing_with_eviction_releases_references():
    """Eviction under prefix sharing releases the prefix reference and the
    rerun still matches the unpressured stream (writer eviction triggers
    adoption by the next sharer)."""
    trace = _prefix_trace(n=8, prefix_len=8, max_new=8)
    stats = length_stats([_req(0, prompt_len=12, max_new=1)])
    alloc = BlockAllocator(12, 4, reservation="expected")
    report = Engine(ScriptedExecutor(VOCAB), n_slots=6, allocator=alloc,
                    chunk_prefill=4, prefix_share=True, stats=stats,
                    sigma_k=0.0).run(trace, max_ticks=20_000)
    assert len(report.completions) == len(trace)
    roomy = Engine(ScriptedExecutor(VOCAB), n_slots=6,
                   allocator=BlockAllocator(64, 4),
                   chunk_prefill=4, prefix_share=True).run(trace)
    assert _tokens(report) == _tokens(roomy)
    # every reference was released on completion
    assert all(p["refs"] == 0 for p in alloc._prefix.values())


def test_prefix_share_requires_chunked_paged_engine():
    with pytest.raises(ValueError, match="BlockAllocator"):
        Engine(ScriptedExecutor(VOCAB), n_slots=2, prefix_share=True)
    with pytest.raises(ValueError, match="chunk_prefill"):
        Engine(ScriptedExecutor(VOCAB), n_slots=2,
               allocator=BlockAllocator(8, 4), prefix_share=True)


# --- latency percentiles and TTFT --------------------------------------------

def test_percentile_nearest_rank():
    vals = list(range(1, 101))
    assert _percentile(vals, 50) == 50
    assert _percentile(vals, 95) == 95
    assert _percentile(vals, 99) == 99
    assert _percentile([7], 99) == 7
    assert _percentile([], 50) == 0.0


def test_report_percentiles_and_ttft():
    trace = [_req(rid, prompt_len=4, max_new=4, arrival=rid)
             for rid in range(5)]
    report = Engine(ScriptedExecutor(VOCAB), n_slots=2,
                    allocator=BlockAllocator(32, 4)).run(trace)
    lp = report.latency_percentiles()
    tp = report.ttft_percentiles()
    for c in report.completions:
        assert c.first_token >= c.admitted >= c.arrival
        assert 0 <= c.ttft <= c.latency
    assert lp["p50"] <= lp["p95"] <= lp["p99"]
    assert tp["p95"] <= lp["p95"]
    assert report.mean_ttft() <= report.mean_latency()
    assert "lat_p50/p95/p99=" in report.describe()


def test_scripted_executor_is_suffix_consistent():
    """prefill(prompt) == decode(prompt[-1], len(prompt)-1): the property
    (shared with the real KV-cache executor) that makes evicted-and-
    requeued re-prefill token-identical by construction."""
    ex = ScriptedExecutor(VOCAB)
    prompt = [5, 9, 2, 44]
    assert ex.prefill(0, prompt) == ex.decode([prompt[-1]],
                                              [len(prompt) - 1])[0]


# --- named ledger errors and idempotent release ------------------------------

def test_double_free_and_negative_refcount_are_named_errors():
    from repro.serving import DoubleFree, NegativeRefcount
    a = BlockAllocator(4, 2)
    a.reserve(0, 2)
    a.alloc(0)
    a.free(0)
    with pytest.raises(DoubleFree):
        a.free(0)
    a.create_prefix("sys", 1)
    a.acquire_prefix("sys")
    a.release_prefix("sys")
    with pytest.raises(NegativeRefcount):
        a.release_prefix("sys")


def test_free_block_rejects_foreign_and_repeated_blocks():
    from repro.serving import DoubleFree
    a = BlockAllocator(4, 2)
    a.reserve(0, 2)
    bid = a.alloc(0)
    a.free_block(0, bid)
    with pytest.raises(DoubleFree):
        a.free_block(0, bid)            # already returned
    with pytest.raises(DoubleFree):
        a.free_block(7, bid)            # rid never reserved


def test_release_prefix_missing_ok_is_idempotent():
    from repro.serving import NegativeRefcount
    a = BlockAllocator(4, 2)
    a.release_prefix("never-created", missing_ok=True)   # no-op
    a.create_prefix("sys", 1)
    a.acquire_prefix("sys")
    a.release_prefix("sys", missing_ok=True)
    a.release_prefix("sys", missing_ok=True)             # still a no-op
    with pytest.raises(NegativeRefcount):
        a.release_prefix("sys")
    assert a.audit() == []


# --- mid-run pool shrinks ----------------------------------------------------

def test_shrink_retires_free_blocks_immediately():
    a = BlockAllocator(8, 2)
    assert a.shrink(3) == 3
    assert a.n_blocks == 5 and a.retired_blocks == 3
    assert a.shrink_debt == 0 and a.audit() == []


def test_shrink_on_busy_pool_becomes_debt_collected_on_free():
    """Live blocks are never yanked: shrinking a fully-owned pool books
    DEBT, and the blocks retire as the lanes naturally free them."""
    a = BlockAllocator(4, 2, reservation="expected")
    a.reserve(0, 4)
    for _ in range(4):
        a.alloc(0)
    assert a.shrink(2) == 0
    assert a.shrink_debt == 2 and a.n_blocks == 4
    assert a.committed > 0                # pressure visible to the ladder
    a.free(0)
    assert a.shrink_debt == 0 and a.n_blocks == 2
    assert a.free_blocks == 2 and a.retired_blocks == 2
    assert a.audit() == []


def test_shrink_always_leaves_one_block():
    a = BlockAllocator(4, 2)
    a.shrink(99)
    assert a.n_blocks == 1 and a.audit() == []


# --- the ledger auditor ------------------------------------------------------

def test_audit_clean_on_fresh_and_busy_pools():
    a = BlockAllocator(6, 2)
    assert a.audit() == []
    a.reserve(0, 2)
    a.alloc(0)
    a.create_prefix("sys", 1)
    assert a.audit() == []


def test_audit_detects_vanished_and_duplicated_blocks():
    a = BlockAllocator(4, 2)
    a._free.popleft()                     # a block vanishes
    assert a.audit() != []
    b = BlockAllocator(4, 2)
    b._free.append(b._free[0])            # a block exists twice
    assert b.audit() != []


def test_audit_detects_retired_block_back_in_circulation():
    a = BlockAllocator(4, 2)
    a.shrink(1)
    zombie = next(iter(a._retired_ids))
    a._free.append(zombie)
    assert any("retired" in p for p in a.audit())


# --- hypothesis: engine survives random fault interleavings ------------------

def test_engine_property_random_fault_interleavings():
    """Whatever seeded fault mix lands — transient executor/allocator
    faults, pool shrinks, chaos cancels, lane stalls, deadlines — the
    engine must drain with every request accounted for, a whole ledger
    (strict every-tick audit + post-run leak check), and every
    completion token-identical to the fault-free run."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed (optional test dep)")
    from repro.serving import (ChaosAllocator, ChaosExecutor, Engine,
                               FaultPlan, LadderConfig, leak_check,
                               survivor_mismatches)
    given = hypothesis.given
    st = hypothesis.strategies

    trace = [_req(i, prompt_len=4 + (i % 2) * 4, max_new=4 + (i % 3) * 4,
                  arrival=i) for i in range(8)]
    stats = length_stats(trace)
    clean = Engine(ScriptedExecutor(VOCAB), 4,
                   allocator=BlockAllocator(16, 4, reservation="expected"),
                   chunk_prefill=4, stats=stats).run(trace)

    @hypothesis.settings(max_examples=30, deadline=None)
    @given(st.integers(0, 10_000), st.floats(0, 0.2), st.floats(0, 0.2),
           st.integers(0, 2), st.integers(0, 2), st.booleans())
    def run(seed, exec_rate, alloc_rate, n_shrinks, n_stalls, deadline):
        plan = FaultPlan.generate(seed, ticks=64, n_requests=len(trace),
                                  n_lanes=4, exec_rate=exec_rate,
                                  alloc_rate=alloc_rate,
                                  n_shrinks=n_shrinks, shrink_frac=0.25,
                                  n_cancels=1, n_stalls=n_stalls)
        alloc = ChaosAllocator(16, 4, "expected", plan=plan)
        eng = Engine(ChaosExecutor(ScriptedExecutor(VOCAB), plan), 4,
                     allocator=alloc, chunk_prefill=4, stats=stats,
                     faults=plan, deadline=(40 if deadline else 0),
                     ladder=LadderConfig(patience=1, high=0.9),
                     audit="strict", max_exec_retries=10)
        rep = eng.run(trace, max_ticks=20_000)
        assert len(rep.completions) + len(rep.cancellations) == len(trace)
        assert rep.audit_failures == 0
        assert leak_check(alloc) == []
        assert survivor_mismatches(rep, clean) == []

    run()
