"""Sharding rules and distributed execution (subprocess with 8 fake devices):
param specs divide evenly, distributed train step matches single-device,
SP/EP strategies compile."""
import pytest

from conftest import run_in_subprocess

# subprocess + XLA compiles => slow tier
pytestmark = pytest.mark.slow

_is_spec = None  # placeholder (subprocess snippets define their own)

from repro.configs import get_config
from repro.launch.compile import abstract_params
from repro.parallel import sharding as S


def test_specs_cover_all_params_single_device():
    """On a trivial mesh every spec must be fully replicated (no axes)."""
    import jax
    from repro.launch.mesh import make_mesh
    cfg = get_config("mixtral-8x7b").reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    specs = S.param_specs(cfg, abstract_params(cfg),
                          S.Strategy(), mesh)
    for spec in jax.tree.leaves(specs,
                                is_leaf=_is_spec):
        pass  # building specs must not raise
    assert specs is not None


def test_distributed_matches_single_device():
    """The same train step on a (2,2) mesh and on 1 device gives the same
    loss/params — the SPMD-correctness cornerstone."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig, TRAIN
from repro.launch.mesh import make_mesh
from repro.launch import compile as LC
from repro.models import init_params
from repro.optim import optimizers as opt
from repro.runtime.train_step import TrainStepConfig, make_train_step
from repro.parallel.axes import axis_rules
from repro.data.pipeline import DataConfig, TokenPipeline

cfg = get_config("h2o-danube-1.8b").reduced()
tcfg = TrainStepConfig(remat="dots", microbatches=2,
                       optimizer=opt.OptimizerConfig(lr=1e-3),
                       warmup_steps=1, total_steps=10)
params = init_params(jax.random.PRNGKey(0), cfg)
opt_state = opt.init_state(tcfg.optimizer, params)
pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=4, seed=1))
batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

# single device
step1 = jax.jit(make_train_step(cfg, tcfg))
p1, o1, m1 = step1(params, opt_state, batch, jnp.asarray(0))

# distributed
mesh = make_mesh((2, 2), ("data", "model"))
strategy = __import__("repro.parallel.sharding", fromlist=["x"])\\
    .default_strategy(cfg, mesh)
with mesh, axis_rules(strategy.rules(), mesh=mesh):
    stepN = jax.jit(make_train_step(cfg, tcfg))
    pN, oN, mN = stepN(params, opt_state, batch, jnp.asarray(0))

assert abs(float(m1["loss"]) - float(mN["loss"])) < 2e-2, \\
    (float(m1["loss"]), float(mN["loss"]))
d = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pN)))
assert d < 0.05, d
print("DIST_MATCH_OK", float(m1["loss"]), float(mN["loss"]))
"""
    out = run_in_subprocess(code, devices=8)
    assert "DIST_MATCH_OK" in out


def test_production_specs_divide_evenly():
    """Every param/cache/input spec must divide its dim on the production
    mesh for ALL archs (the exact check jit enforces at lower time)."""
    code = """
import jax
from repro.configs import ARCH_IDS, SHAPES, get_config, input_specs
from repro.launch.mesh import make_mesh
from repro.launch.compile import abstract_params
from repro.models.model import init_cache
from repro.parallel import sharding as S
from jax.sharding import PartitionSpec as _P

def _is_spec(x):
    return isinstance(x, _P)

mesh = make_mesh((2, 4), ("data", "model"))
for arch in ARCH_IDS:
    cfg = get_config(arch)
    strategy = S.default_strategy(cfg, mesh)
    pa = abstract_params(cfg)
    specs = S.param_specs(cfg, pa, strategy, mesh)
    flat_p = jax.tree.leaves(pa)
    flat_s = jax.tree.leaves(specs, is_leaf=_is_spec)
    for leaf, spec in zip(flat_p, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (arch, leaf.shape, spec)
    cache = init_cache(cfg, 16, 2048, abstract=True)
    cspecs = S.cache_specs(cfg, cache, strategy, mesh)
    for leaf, spec in zip(jax.tree.leaves(cache),
                          jax.tree.leaves(cspecs,
                                          is_leaf=_is_spec)):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (arch, "cache", leaf.shape, spec)
print("DIVIS_OK")
"""
    out = run_in_subprocess(code, devices=8)
    assert "DIVIS_OK" in out


def test_ep_strategy_and_compressed_psum():
    """EP sharding compiles for MoE; compressed_psum matches plain mean."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.optim.compress import compressed_psum
from repro.parallel import shard_map

mesh = make_mesh((8,), ("data",))
x = jnp.arange(64.0).reshape(8, 8) / 7.0

@partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
def f(xs):
    key = jax.random.PRNGKey(0)
    return compressed_psum(xs, "data", key)

got = f(x)
want = jnp.broadcast_to(x.mean(axis=0, keepdims=True), x.shape)
err = float(jnp.abs(got - want).max())
scale = float(jnp.abs(x).max()) / 127.0
assert err <= scale * 1.5 + 1e-6, (err, scale)
print("PSUM_OK", err)
"""
    out = run_in_subprocess(code, devices=8)
    assert "PSUM_OK" in out
