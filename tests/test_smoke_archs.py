"""Per-arch smoke tests (assignment requirement): reduced config of the same
family, one forward + one train step on CPU, asserting shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import ModelSettings, apply, init_params
from repro.models.attention import AttnSettings
from repro.optim import optimizers as opt
from repro.runtime.train_step import TrainStepConfig, make_train_step

# XLA compiles dominate the runtime => slow tier
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)
SETTINGS = ModelSettings(attn=AttnSettings(backend="blocked", q_block=16,
                                           kv_block=16))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    b, s = 2, 24
    tokens = jax.random.randint(KEY, (b, s - cfg.n_prefix_embeds), 0,
                                cfg.vocab_size)
    prefix = (jax.random.normal(KEY, (b, cfg.n_prefix_embeds, cfg.d_model),
                                jnp.bfloat16) if cfg.n_prefix_embeds else None)
    logits, cache, aux = apply(params, cfg, tokens, prefix_embeds=prefix,
                               settings=SETTINGS)
    assert logits.shape == (b, s, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert cache is None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    tcfg = TrainStepConfig(remat="dots", microbatches=2,
                           optimizer=opt.OptimizerConfig(lr=1e-3),
                           settings=SETTINGS, warmup_steps=1, total_steps=10)
    step = make_train_step(cfg, tcfg)
    opt_state = opt.init_state(tcfg.optimizer, params)
    b, s = 4, 16
    batch = {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "targets": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
    }
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jax.random.normal(
            KEY, (b, min(cfg.n_prefix_embeds, 4), cfg.d_model), jnp.bfloat16)
        # reduced() shrinks prefix to 4
    params2, opt_state2, metrics = step(params, opt_state, batch,
                                        jnp.asarray(0))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually changed
    delta = sum(float(jnp.abs(a.astype(jnp.float32)
                              - b_.astype(jnp.float32)).sum())
                for a, b_ in zip(jax.tree.leaves(params),
                                 jax.tree.leaves(params2)))
    assert delta > 0
