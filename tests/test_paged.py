"""Paged KV block pool, hermetic tier: block-granular capacity inversion
(predictor.serving_block_capacity), the paged planning mode of
plan_serving, the jax-free BlockAllocator, the paged engine scheduling
core (admission by actual footprint, block reuse, determinism), the
engine-level batched prefill, and eval_shape pins of the paged pool steps
— all with ZERO XLA compiles. Token parity of the real paged executor
against greedy_generate lives in the slow tier (test_serve.py)."""
import dataclasses

import jax
import pytest

from repro import hw as HW
from repro.configs import get_config
from repro.configs.base import DECODE, ShapeConfig
from repro.core import measure as MM
from repro.core import predictor as PR
from repro.core import profiler as PF
from repro.search import execplan as XP
from repro.search import space as SP
from repro.serving import (BlockAllocator, Engine, Request, ScriptedExecutor,
                           synthetic_trace, trace_context)

CFG = get_config("mistral-nemo-12b")         # pure global attn: all layers page
SHAPE = ShapeConfig("paged_t", DECODE, 4096, 8)
GIB = 2**30


def _cls(cfg=CFG, shape=SHAPE):
    sim = MM.SimulatedMeasurer({"data": 8})
    return PF.classify_workload(cfg, shape, None, n_points=2, base_seq=64,
                                measurer=sim)


@pytest.fixture(scope="module")
def cls():
    return _cls()


def _no_compile(monkeypatch):
    import repro.launch.compile as LC

    def boom(*a, **k):
        raise AssertionError("XLA compile attempted in hermetic test")
    monkeypatch.setattr(LC, "build", boom)


# --- block math: the requirement model at block granularity ------------------

def test_block_bytes_tile_the_ring_exactly(cls):
    """ceil(context / block) KV blocks must cost exactly one whole-sequence
    ring (+ the per-lane fixed state) when the block tiles the context —
    the block pool re-cuts the same bytes, it doesn't invent new ones."""
    mesh = {"data": 1, "model": 1}
    for block in (64, 256):
        plan = PR.MemoryPlan(kv_block_size=block)
        assert SHAPE.context % block == 0
        per_block = PR.kv_block_bytes_per_device(CFG, SHAPE, plan, mesh)
        lane = PR.lane_bytes_per_device(CFG, SHAPE, plan, mesh)
        one = dataclasses.replace(SHAPE, global_batch=1)
        ring = PR.cache_bytes_per_device(CFG, one, plan, mesh)
        n_blocks = SHAPE.context // block
        assert per_block > 0
        assert per_block * n_blocks + lane == pytest.approx(ring)


def test_block_bytes_kv_shard_aware():
    """'heads' pads 2 kv heads up to a whole replicated head over model=4;
    'seq' shards the block's positions — the block accounting must see the
    same difference serving_capacity does."""
    cfg = dataclasses.replace(CFG, name="nemo-kv2", n_kv_heads=2)
    mesh = {"data": 1, "model": 4}
    heads = PR.kv_block_bytes_per_device(
        cfg, SHAPE, PR.MemoryPlan(kv_shard="heads", kv_block_size=64), mesh)
    seq = PR.kv_block_bytes_per_device(
        cfg, SHAPE, PR.MemoryPlan(kv_shard="seq", kv_block_size=64), mesh)
    assert heads > seq > 0


def test_serving_block_capacity_is_exact(cls):
    """The returned block count fits the budget and one more per-device
    block does not — the inversion is exact w.r.t. the forward terms."""
    mesh = {"data": 2, "model": 2}
    plan = PR.MemoryPlan(kv_block_size=64)
    budget = 24 * GIB
    lanes = 4
    nb = PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh, lanes=lanes,
                                   hbm_budget=budget)
    _, dp, _ = PR.mesh_factors(mesh)
    assert nb > 0 and nb % dp == 0

    sh = dataclasses.replace(SHAPE, global_batch=lanes * dp)
    base = (PR.resident_bytes(CFG, sh, plan, mesh)
            - PR.cache_bytes_per_device(CFG, sh, plan, mesh)
            + lanes * PR.lane_bytes_per_device(CFG, sh, plan, mesh))
    tra = PR.transient_bytes(CFG, sh, plan, cls, mesh)
    per_block = PR.kv_block_bytes_per_device(CFG, SHAPE, plan, mesh)

    def capacity_at(blocks_per_device):
        return HW.capacity_from_requirement(
            base + blocks_per_device * per_block, tra)

    assert capacity_at(nb // dp) <= budget
    assert capacity_at(nb // dp + 1) > budget


def test_serving_block_capacity_monotone_and_bounds(cls):
    mesh = {"data": 1, "model": 1}
    plan = PR.MemoryPlan(kv_block_size=64)
    caps = [PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh,
                                      lanes=2, hbm_budget=b * GIB)
            for b in (38, 40, 48, 64)]
    assert caps == sorted(caps)
    assert caps[-1] > caps[0] > 0
    # nothing fits a toy budget
    assert PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh, lanes=1,
                                     hbm_budget=2**20) == 0
    # more lanes eat the block budget
    few = PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh, lanes=1,
                                    hbm_budget=48 * GIB)
    many = PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh, lanes=8,
                                     hbm_budget=48 * GIB)
    assert few > many > 0
    with pytest.raises(ValueError, match="kv_block_size"):
        PR.serving_block_capacity(CFG, SHAPE, PR.MemoryPlan(), cls, mesh)
    with pytest.raises(ValueError, match="lanes"):
        PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh, lanes=0)


def test_serving_block_capacity_avg_context_frees_blocks(cls):
    """Paged decode reads through block tables, so a short expected reach
    shrinks the per-lane transient and leaves more budget for blocks."""
    mesh = {"data": 1, "model": 1}
    plan = PR.MemoryPlan(kv_block_size=64)
    worst = PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh, lanes=8,
                                      hbm_budget=48 * GIB)
    short = PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh, lanes=8,
                                      hbm_budget=48 * GIB, avg_context=128)
    assert short > worst > 0


# --- plan_serving(kv="paged"): expected concurrency over the block pool -----

def test_plan_serving_paged_zero_compiles(monkeypatch, cls):
    _no_compile(monkeypatch)
    lens = [60] * 7 + [2000]                 # mostly-short traffic
    got, splan = XP.plan_serving(CFG, SHAPE, n_devices=4, cls=cls,
                                 hbm_budget=12 * GIB, kv="paged",
                                 seq_lens=lens)
    assert got is cls
    assert splan.capacity > 0
    assert splan.kv_block in XP.DEFAULT_KV_BLOCKS
    assert splan.blocks > 0
    assert "kv_block=" in splan.describe()
    assert splan.slots(cap=3) == 3


def test_plan_serving_paged_beats_ring_2x(cls):
    """Acceptance pin (planner level): under a budget that admits exactly
    two worst-case ring slots, the paged planner admits >= 2x the
    concurrency on a mostly-short length distribution."""
    mesh = {"data": 1, "model": 1}

    def req(n):
        sh = dataclasses.replace(SHAPE, global_batch=n)
        return PR.predict(CFG, sh, PR.MemoryPlan(), cls, mesh).capacity_bytes

    budget = (req(2) + req(3)) / 2

    def pinned(kv_blocks):
        return SP.serving_space(CFG, SHAPE, max_devices=1, data=(1,),
                                model=(1,), kv_blocks=kv_blocks)

    _, ring = XP.plan_serving(CFG, SHAPE, n_devices=1, cls=cls,
                              hbm_budget=budget, space=pinned((0,)))
    lens = [60] * 7 + [SHAPE.context]
    _, paged = XP.plan_serving(CFG, SHAPE, n_devices=1, cls=cls,
                               hbm_budget=budget, space=pinned((64, 256)),
                               kv="paged", seq_lens=lens)
    assert ring.capacity == 2
    assert paged.capacity >= 2 * ring.capacity
    assert paged.blocks >= paged.capacity    # enough blocks to cover lanes


def test_serving_space_kv_block_knob():
    space = SP.serving_space(CFG, SHAPE, max_devices=4,
                             kv_blocks=(64, SHAPE.context * 2))
    sizes = {c.plan.kv_block_size for c in space.candidates(CFG, SHAPE)}
    assert sizes == {64}                     # oversize block filtered out


# --- BlockAllocator: the jax-free free list ---------------------------------

def test_allocator_alloc_free_reuse():
    a = BlockAllocator(6, block_size=4)
    a.reserve(1, 3)
    ids = [a.alloc(1) for _ in range(3)]
    assert ids == [1, 2, 3]                  # id 0 is the scratch block
    assert a.in_use == 3 and a.committed == 3
    a.reserve(2, 3)
    assert not a.can_admit(1)                # fully committed
    assert a.free(1) == ids
    assert a.committed == 3 and a.in_use == 0
    b2 = [a.alloc(2) for _ in range(3)]
    assert b2 == [4, 5, 6]                   # FIFO reuse order
    a.free(2)
    assert a.free_blocks == 6
    assert a.peak_in_use == 3
    assert a.peak_committed == 6


def test_allocator_guards():
    with pytest.raises(ValueError, match="n_blocks"):
        BlockAllocator(0, 4)
    with pytest.raises(ValueError, match="block_size"):
        BlockAllocator(4, 0)
    a = BlockAllocator(4, 4)
    a.reserve(1, 2)
    with pytest.raises(RuntimeError, match="already holds"):
        a.reserve(1, 1)
    with pytest.raises(RuntimeError, match="over-commits"):
        a.reserve(2, 3)
    a.alloc(1)
    a.alloc(1)
    with pytest.raises(RuntimeError, match="reservation"):
        a.alloc(1)                           # beyond its reservation


def test_allocator_blocks_for():
    a = BlockAllocator(8, block_size=4)
    # written positions = prompt + max_new - 1
    assert a.blocks_for(Request(0, 0, (1,) * 4, 1)) == 1     # 4 -> 1 block
    assert a.blocks_for(Request(0, 0, (1,) * 4, 2)) == 2     # 5 -> 2
    assert a.blocks_for(Request(0, 0, (1,) * 8, 9)) == 4     # 16 -> 4


# --- the paged scheduling core ----------------------------------------------

def _burst(n, gens, seed=0, prompts=(4, 8)):
    return synthetic_trace(n, vocab_size=97, seed=seed, prompt_lens=prompts,
                           gen_lens=gens, mean_interarrival=0)


def test_paged_engine_matches_ring_engine_tokens():
    """The paged pool changes WHERE cache bytes live, never WHAT the model
    emits: scripted ring and paged runs produce identical completions."""
    trace = _burst(8, (2, 4, 8))
    ring = Engine(ScriptedExecutor(), 3).run(trace)
    paged = Engine(ScriptedExecutor(), 3,
                   allocator=BlockAllocator(16, 4)).run(trace)
    assert ([c.tokens for c in ring.completions]
            == [c.tokens for c in paged.completions])
    assert paged.n_blocks == 16
    assert 0 < paged.peak_blocks <= 16


def test_paged_admission_bounded_by_blocks_not_lanes():
    """With ample lanes but a tight block pool, the allocator is the
    admission controller: concurrency stops at what the blocks cover."""
    trace = _burst(6, (8,), prompts=(8,))    # each needs ceil(15/4)=4 blocks
    alloc = BlockAllocator(9, 4)             # room for exactly 2 at a time
    rep = Engine(ScriptedExecutor(), 6, allocator=alloc).run(trace)
    assert rep.max_concurrent == 2
    assert rep.peak_blocks <= 9
    assert len(rep.completions) == 6         # block reuse drains the queue
    assert alloc.committed == 0 and alloc.free_blocks == 9


def test_paged_engine_deterministic():
    trace = _burst(7, (1, 3, 9), seed=5)
    r1 = Engine(ScriptedExecutor(), 3,
                allocator=BlockAllocator(12, 4)).run(trace)
    r2 = Engine(ScriptedExecutor(), 3,
                allocator=BlockAllocator(12, 4)).run(trace)
    assert r1 == r2


def test_paged_engine_rejects_oversized_request():
    eng = Engine(ScriptedExecutor(), 2, allocator=BlockAllocator(2, 4))
    with pytest.raises(ValueError, match="never be admitted"):
        eng.run([Request(rid=0, arrival=0, prompt=(5,) * 8, max_new=9)])


def test_paged_acceptance_2x_concurrency_end_to_end(cls):
    """Acceptance pin, hermetic: plan ring and paged under the SAME tight
    budget, size engines from the plans, replay the same mostly-short
    trace — the paged engine runs >= 2x the concurrent sequences and
    completes identically."""
    mesh = {"data": 1, "model": 1}
    trace = synthetic_trace(12, vocab_size=97, seed=7, prompt_lens=(4, 8),
                            gen_lens=(4, 4, 8, 248), mean_interarrival=0.5)
    context = trace_context(trace)
    shape = ShapeConfig("paged_e2e", DECODE, context, 8)
    cls2 = _cls(CFG, shape)

    def req(n):
        sh = dataclasses.replace(shape, global_batch=n)
        return PR.predict(CFG, sh, PR.MemoryPlan(), cls2,
                          mesh).capacity_bytes

    budget = (req(2) + req(3)) / 2

    def pinned(kv_blocks):
        return SP.serving_space(CFG, shape, max_devices=1, data=(1,),
                                model=(1,), kv_blocks=kv_blocks)

    _, ring = XP.plan_serving(CFG, shape, n_devices=1, cls=cls2,
                              hbm_budget=budget, space=pinned((0,)))
    _, paged = XP.plan_serving(
        CFG, shape, n_devices=1, cls=cls2, hbm_budget=budget,
        space=pinned((4, 8, 16)), kv="paged",
        seq_lens=[len(r.prompt) + r.max_new - 1 for r in trace])

    ring_rep = Engine(ScriptedExecutor(), ring.slots(cap=len(trace))).run(trace)
    lanes = paged.slots(cap=len(trace))
    per_seq = -(-context // paged.kv_block)
    n_blocks = min(paged.blocks, lanes * per_seq)
    paged_rep = Engine(ScriptedExecutor(), lanes,
                       allocator=BlockAllocator(n_blocks,
                                                paged.kv_block)).run(trace)
    assert paged_rep.max_concurrent >= 2 * ring_rep.max_concurrent
    assert ([c.tokens for c in ring_rep.completions]
            == [c.tokens for c in paged_rep.completions])
    assert paged_rep.ticks <= ring_rep.ticks


# --- engine-level batched prefill -------------------------------------------

def test_batched_prefill_shares_calls_per_bucket():
    """6 burst requests, one prompt bucket, 4 slots: the first tick admits
    4 in ONE prefill call; stragglers backfill with at most one call per
    admission tick — calls strictly fewer than admissions."""
    trace = _burst(6, (4,), prompts=(4,))
    ex = ScriptedExecutor()
    rep = Engine(ex, 4).run(trace)
    assert rep.prefills == 6
    assert ex.prefill_batches == rep.prefill_calls
    assert rep.prefill_calls <= 3            # 1 burst call + <= 2 backfills
    assert rep.prefill_calls < rep.prefills


def test_batched_prefill_groups_by_bucket():
    """Same-tick admissions in DIFFERENT buckets stay separate calls (one
    padded compile shape per bucket)."""
    trace = [Request(rid=0, arrival=0, prompt=(3,) * 4, max_new=2),
             Request(rid=1, arrival=0, prompt=(3,) * 8, max_new=2),
             Request(rid=2, arrival=0, prompt=(4,) * 4, max_new=2)]
    ex = ScriptedExecutor()
    rep = Engine(ex, 4).run(trace)
    assert rep.prefills == 3
    assert rep.prefill_calls == 2            # buckets {4, 8}
    # token functions are per-request, so batching never changes outputs
    solo = [ScriptedExecutor().prefill(0, r.prompt) for r in trace]
    assert [c.tokens[0] for c in rep.completions] == solo


# --- shape pins (jax.eval_shape: trace only, no compiles) -------------------

def test_init_paged_pool_shapes():
    from repro.runtime import serve_step as SS
    cfg = get_config("gemma3-12b").reduced()   # window=8 locals + global
    lanes, n_blocks, block, context = 3, 9, 4, 16
    pool = SS.init_paged_pool(cfg, lanes, n_blocks, block, context,
                              abstract=True)
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    paged = ring = 0
    for i, blk in enumerate(cfg.unit):
        leaf = pool["units"][i]
        if SS.is_paged_block(blk, context):
            paged += 1
            assert leaf["kb"].shape == (cfg.repeats, n_blocks, block, K, hd)
            assert leaf["pos"].shape == (cfg.repeats, n_blocks, block)
        else:
            ring += 1
            L = blk.cache_len(context)
            assert leaf["k"].shape == (cfg.repeats, lanes, L, K, hd)
    assert paged >= 1 and ring >= 1          # the mixed tree is exercised
    with pytest.raises(ValueError, match="multiple"):
        SS.init_paged_pool(cfg, lanes, n_blocks, 5, context)


def test_paged_steps_preserve_pool_shapes():
    import jax.numpy as jnp

    from repro.models import init_params
    from repro.runtime import serve_step as SS
    cfg = get_config("gemma3-12b").reduced()
    lanes, n_blocks, block, context = 2, 7, 4, 16
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: init_params(key, cfg))
    pool = SS.init_paged_pool(cfg, lanes, n_blocks, block, context,
                              abstract=True)
    shapes = jax.tree.map(lambda a: a.shape, pool)

    prefill = SS.make_paged_prefill_step(cfg)
    tokens = jax.ShapeDtypeStruct((lanes, 4), jnp.int32)
    lane_ids = jax.ShapeDtypeStruct((lanes,), jnp.int32)
    tables = jax.ShapeDtypeStruct((lanes, context // block), jnp.int32)
    logits, new_pool = jax.eval_shape(
        lambda p, t, l, tb, P: prefill(p, t, l, tb, P, context=context),
        params, tokens, lane_ids, tables, pool)
    assert jax.tree.map(lambda a: a.shape, new_pool) == shapes
    assert logits.shape == (lanes, cfg.padded_vocab_size)

    decode = SS.make_paged_decode_step(cfg)
    tok1 = jax.ShapeDtypeStruct((lanes, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((lanes,), jnp.int32)
    logits, new_pool, mass = jax.eval_shape(
        lambda p, t, po, tb, P: decode(p, t, po, tb, P, context=context),
        params, tok1, pos, tables, pool)
    assert jax.tree.map(lambda a: a.shape, new_pool) == shapes
    assert logits.shape == (lanes, cfg.padded_vocab_size)
    assert mass is None                   # track_mass off by default

    ids = jax.ShapeDtypeStruct((lanes,), jnp.int32)
    reset = jax.eval_shape(SS.reset_pool_blocks, pool, ids)
    assert jax.tree.map(lambda a: a.shape, reset) == shapes


def test_batch_prefill_step_shapes():
    import jax.numpy as jnp

    from repro.models import init_params
    from repro.models import model as M
    from repro.runtime import serve_step as SS
    cfg = get_config("recurrentgemma-9b").reduced()   # attn + recurrent mix
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: init_params(key, cfg))
    pool = M.init_cache(cfg, 3, 16, abstract=True)
    step = SS.make_batch_prefill_step(cfg)
    tokens = jax.ShapeDtypeStruct((3, 4), jnp.int32)
    slots = jax.ShapeDtypeStruct((3,), jnp.int32)
    logits, new_pool = jax.eval_shape(
        lambda p, t, s, P: step(p, t, s, P, context=16),
        params, tokens, slots, pool)
    assert jax.tree.map(lambda a: a.shape, new_pool) \
        == jax.tree.map(lambda a: a.shape, pool)
    assert logits.shape == (3, cfg.padded_vocab_size)


def test_compact_decode_step_shapes():
    """The compacted decode gathers w < lanes per-lane rows, runs at width
    w, and scatters back: pool shapes are preserved exactly, logits come
    out at the COMPACTED width, and trimmed block tables (narrower than
    the pool max) are accepted — all traced, zero compiles."""
    import jax.numpy as jnp

    from repro.models import init_params
    from repro.runtime import serve_step as SS
    cfg = get_config("gemma3-12b").reduced()
    lanes, n_blocks, block, context = 4, 9, 4, 16
    w, mb = 2, 2                               # compacted width, trimmed table
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: init_params(key, cfg))
    pool = SS.init_paged_pool(cfg, lanes, n_blocks, block, context,
                              abstract=True)
    shapes = jax.tree.map(lambda a: a.shape, pool)

    compact = SS.make_compact_decode_step(cfg)
    tok = jax.ShapeDtypeStruct((w, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((w,), jnp.int32)
    tables = jax.ShapeDtypeStruct((w, mb), jnp.int32)
    lane_ids = jax.ShapeDtypeStruct((w,), jnp.int32)
    logits, new_pool, mass = jax.eval_shape(
        lambda p, t, po, tb, l, P: compact(p, t, po, tb, l, P,
                                           context=context),
        params, tok, pos, tables, lane_ids, pool)
    assert jax.tree.map(lambda a: a.shape, new_pool) == shapes
    assert logits.shape == (w, cfg.padded_vocab_size)
    assert mass is None


def test_chunk_prefill_step_appends_in_place():
    """The chunk-prefill step consumes [w, C] mid-prompt tokens against the
    live pool and returns it shape-identical (blocks written through the
    tables, rings in place) with per-row last-valid logits."""
    import jax.numpy as jnp

    from repro.models import init_params
    from repro.runtime import serve_step as SS
    cfg = get_config("gemma3-12b").reduced()
    lanes, n_blocks, block, context = 3, 9, 4, 16
    w, C, mb = 2, 8, 4
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: init_params(key, cfg))
    pool = SS.init_paged_pool(cfg, lanes, n_blocks, block, context,
                              abstract=True)
    shapes = jax.tree.map(lambda a: a.shape, pool)

    chunk = SS.make_chunk_prefill_step(cfg)
    tok = jax.ShapeDtypeStruct((w, C), jnp.int32)
    pos = jax.ShapeDtypeStruct((w, C), jnp.int32)
    tables = jax.ShapeDtypeStruct((w, mb), jnp.int32)
    lane_ids = jax.ShapeDtypeStruct((w,), jnp.int32)
    logits, new_pool = jax.eval_shape(
        lambda p, t, po, tb, l, P: chunk(p, t, po, tb, l, P,
                                         context=context),
        params, tok, pos, tables, lane_ids, pool)
    assert jax.tree.map(lambda a: a.shape, new_pool) == shapes
    assert logits.shape == (w, cfg.padded_vocab_size)


def test_gather_scatter_pool_lanes_roundtrip_shapes():
    """gather narrows every per-lane leaf to width w (paged leaves pass
    through untouched); scatter restores the full pool shape."""
    import jax.numpy as jnp

    from repro.runtime import serve_step as SS
    cfg = get_config("gemma3-12b").reduced()
    lanes, w = 4, 2
    pool = SS.init_paged_pool(cfg, lanes, 9, 4, 16, abstract=True)
    ids = jax.ShapeDtypeStruct((w,), jnp.int32)
    sub = jax.eval_shape(SS.gather_pool_lanes, pool, ids)
    for P, S in zip(pool["units"], sub["units"]):
        if SS._is_paged_leaf(P):
            assert jax.tree.map(lambda a: a.shape, S) \
                == jax.tree.map(lambda a: a.shape, P)
        else:
            for a, b in zip(jax.tree.leaves(P), jax.tree.leaves(S)):
                assert b.shape == (a.shape[0], w) + a.shape[2:]
    back = jax.eval_shape(SS.scatter_pool_lanes, pool, sub, ids)
    assert jax.tree.map(lambda a: a.shape, back) \
        == jax.tree.map(lambda a: a.shape, pool)


# --- executor-side compaction knobs (no compiles: constructor validation) ---

def test_paged_executor_bucket_and_chunk_validation():
    from repro.serving.executor import (PagedJaxExecutor, _cover,
                                        _pow2_buckets)
    assert _pow2_buckets(8) == (1, 2, 4, 8)
    assert _pow2_buckets(6) == (1, 2, 4, 6)    # n_lanes appended as cap
    assert _cover(3, (1, 2, 4)) == 4
    assert _cover(5, (1, 2, 4)) == 4           # clamps at the top bucket
    cfg = get_config("mistral-nemo-12b").reduced()
    params = None                              # constructor-only: never used
    with pytest.raises(ValueError, match="kv_block"):
        PagedJaxExecutor(params, cfg, n_lanes=2, n_blocks=8, kv_block=4,
                         context=16, chunk=6)
    # recurrent mixers now ride the chunked path (scan state carried in
    # per-lane pool leaves) — the constructor accepts them and flags the
    # tree so the engine can refuse prefix sharing
    rg = get_config("recurrentgemma-9b").reduced()
    ex = PagedJaxExecutor(params, rg, n_lanes=2, n_blocks=8, kv_block=4,
                          context=16, chunk=4)
    assert ex.has_recurrent
    attn_only = PagedJaxExecutor(params, cfg, n_lanes=2, n_blocks=8,
                                 kv_block=4, context=16, chunk=4)
    assert not attn_only.has_recurrent


def test_prefix_share_refuses_recurrent_mixers():
    """Shared prefix blocks carry attention KV only — a recurrent arch
    cannot resume a sharer mid-prompt, so the engine refuses upfront."""
    from repro.serving import BlockAllocator
    from repro.serving.engine import Engine
    from repro.serving.executor import PagedJaxExecutor
    rg = get_config("recurrentgemma-9b").reduced()
    ex = PagedJaxExecutor(None, rg, n_lanes=2, n_blocks=8, kv_block=4,
                          context=16, chunk=4)
    with pytest.raises(ValueError, match="prefix_share is attention-only"):
        Engine(ex, 2, allocator=BlockAllocator(8, 4), chunk_prefill=4,
               prefix_share=True)


# --- prefill as a first-class capacity term ---------------------------------

def test_prefill_transient_tiled_below_dense(cls):
    """The tiled flash-prefill kernel never materializes the
    O(chunk x context) score matrix or a dequantized fp copy of the
    gathered context, so its modeled transient must sit strictly below the
    dense jnp oracle's — and only the DENSE term may grow with reach."""
    mesh = {"data": 1, "model": 1}
    plan = PR.MemoryPlan(kv_block_size=64)
    kw = dict(prefill_tokens=64, mode="paper")
    dense = PR.prefill_transient_bytes(CFG, SHAPE, plan, cls, mesh,
                                       reach=4096, kernel="dense", **kw)
    tiled = PR.prefill_transient_bytes(CFG, SHAPE, plan, cls, mesh,
                                       reach=4096, kernel="tiled", **kw)
    assert dense > tiled > 0
    dense_short = PR.prefill_transient_bytes(CFG, SHAPE, plan, cls, mesh,
                                             reach=256, kernel="dense", **kw)
    tiled_short = PR.prefill_transient_bytes(CFG, SHAPE, plan, cls, mesh,
                                             reach=256, kernel="tiled", **kw)
    assert dense_short < dense
    assert tiled_short == tiled


def test_serving_block_capacity_charges_prefill_transient(cls):
    """Under a short expected reach (the regime paged serving plans for)
    a context-sized prefill burst raises the transient peak above the
    decode term, so blocks shrink — and the dense oracle (score matrix +
    fp gather) loses strictly more of them than the tiled kernel."""
    mesh = {"data": 1, "model": 1}
    plan = PR.MemoryPlan(kv_block_size=64)
    kw = dict(lanes=8, hbm_budget=48 * GIB, avg_context=128)
    base = PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh, **kw)
    tiled = PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh,
                                      prefill_tokens=SHAPE.context,
                                      prefill_kernel="tiled", **kw)
    dense = PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh,
                                      prefill_tokens=SHAPE.context,
                                      prefill_kernel="dense", **kw)
    assert base >= tiled > dense > 0
    # a token-budgeted prefill (small chunked transient) costs less than
    # admitting the whole prompt in one dense burst
    budgeted = PR.serving_block_capacity(CFG, SHAPE, plan, cls, mesh,
                                         prefill_tokens=64,
                                         prefill_kernel="tiled", **kw)
    assert budgeted >= tiled


def test_plan_serving_prefill_budget_knob(monkeypatch, cls):
    """plan_serving threads the prefill budget and kernel through to the
    capacity term and the returned ServingPlan; the budget is searchable
    as a serving-space knob; misuse raises before any planning work."""
    _no_compile(monkeypatch)
    lens = [60] * 7 + [2000]
    kw = dict(n_devices=4, cls=cls, hbm_budget=12 * GIB, kv="paged",
              seq_lens=lens)
    _, splan = XP.plan_serving(CFG, SHAPE, chunk=8, prefill_budget=16,
                               prefill_kernel="tiled", **kw)
    assert splan.prefill_budget == 16
    assert splan.prefill_kernel == "tiled"
    assert "prefill_budget=16" in splan.describe()
    _, dense = XP.plan_serving(CFG, SHAPE, chunk=8, prefill_budget=16,
                               prefill_kernel="dense", **kw)
    assert splan.capacity >= dense.capacity > 0
    # searched as a knob: the chosen budget is one of the candidates and
    # the lattice actually widened
    _, plain = XP.plan_serving(CFG, SHAPE, **kw)
    assert plain.prefill_budget == 0
    assert "prefill_budget" not in plain.describe()
    _, searched = XP.plan_serving(CFG, SHAPE, chunk=8,
                                  prefill_budgets=(16, 256), **kw)
    assert searched.prefill_budget in (16, 256)
    assert searched.considered == 2 * plain.considered
    with pytest.raises(ValueError, match="needs chunk > 0"):
        XP.plan_serving(CFG, SHAPE, prefill_budget=16, **kw)
    with pytest.raises(ValueError, match="unknown prefill_kernel"):
        XP.plan_serving(CFG, SHAPE, chunk=8, prefill_kernel="bogus", **kw)


def test_engine_prefill_budget_validation():
    with pytest.raises(ValueError, match="prefill_budget"):
        Engine(ScriptedExecutor(), 2, prefill_budget=-1,
               chunk_prefill=4, allocator=BlockAllocator(8, 4))
    with pytest.raises(ValueError, match="needs chunk_prefill"):
        Engine(ScriptedExecutor(), 2, prefill_budget=4)


def test_prefill_budget_token_identical_and_accounted():
    """The token budget changes WHEN chunks land, never WHAT tokens come
    out: a tightly budgeted run completes identically to the unbudgeted
    chunked run, spreads the chunk work over more calls, counts every
    prompt token exactly once in prefill_tokens, and keeps the tick
    taxonomy a partition."""
    trace = _burst(4, (2, 4), prompts=(8, 12))
    total_prompt = sum(len(r.prompt) for r in trace)

    def run(budget):
        ex = ScriptedExecutor()
        rep = Engine(ex, 4, allocator=BlockAllocator(24, 4),
                     chunk_prefill=4, prefill_budget=budget).run(trace)
        return ex, rep

    ex0, free = run(0)
    ex1, tight = run(4)              # one 4-token chunk per tick
    assert ([c.tokens for c in free.completions]
            == [c.tokens for c in tight.completions])
    assert free.prefill_tokens == tight.prefill_tokens == total_prompt
    assert free.prefill_throughput() > tight.prefill_throughput() > 0
    assert ex1.chunk_calls >= ex0.chunk_calls
    assert ex1.chunk_tokens == ex0.chunk_tokens
    assert tight.ticks > free.ticks  # the budget really throttled
    for rep in (free, tight):
        assert rep.ticks == (rep.decode_ticks + rep.admit_ticks
                             + rep.idle_ticks)


def test_prefill_budget_fair_share_tightest_slo_first():
    """Two same-length prompts in different SLO classes contend for a
    budget that admits ONE chunk per tick: the tighter class must reach
    its first token strictly earlier (round-robin leads with class 0)."""
    trace = [Request(rid=0, arrival=0, prompt=(3,) * 12, max_new=2, slo=1),
             Request(rid=1, arrival=0, prompt=(4,) * 12, max_new=2, slo=0)]
    rep = Engine(ScriptedExecutor(), 2, allocator=BlockAllocator(24, 4),
                 chunk_prefill=4, prefill_budget=4).run(trace)
    assert len(rep.completions) == 2
    by_rid = {c.rid: c for c in rep.completions}
    assert by_rid[1].first_token < by_rid[0].first_token


def test_report_percentiles_empty_without_completions():
    """Zero completions (an overload trace can evict everything before a
    first token) must yield empty percentile dicts and a describe() that
    still renders — not a KeyError at the report line."""
    from repro.serving.engine import ServeReport
    rep = ServeReport(policy="continuous", n_slots=2, completions=[],
                      ticks=5, decode_ticks=0, useful_slot_tokens=0,
                      idle_ticks=5, peak_queue=3, max_concurrent=0,
                      prefills=0)
    assert rep.latency_percentiles() == {}
    assert rep.ttft_percentiles() == {}
    assert rep.mean_ttft() == 0.0
    assert "lat_p50/p95/p99=-/-/-" in rep.describe()


def test_fused_prefill_avoids_dense_score_transient():
    """Jaxpr-level pin of the tentpole's memory claim: tracing the tiled
    kernel produces NO top-level intermediate as large as the
    O(chunk x context) score matrix, while the dense jnp oracle path
    materializes one at least that large (trace-only, zero compiles)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops as kops
    b, C, K, G, hd = 1, 8, 2, 2, 16
    bs, mB, nB = 8, 64, 16                   # context 512 >> chunk 8
    ctx = mB * bs
    q = jax.ShapeDtypeStruct((b, C, K, G, hd), jnp.float32)
    kn = jax.ShapeDtypeStruct((b, C, K, hd), jnp.float32)
    kp = jax.ShapeDtypeStruct((nB, bs, K, hd), jnp.bfloat16)
    pp = jax.ShapeDtypeStruct((nB, bs), jnp.int32)
    tb = jax.ShapeDtypeStruct((b, mB), jnp.int32)
    pos = jax.ShapeDtypeStruct((b, C), jnp.int32)

    def tiled(q, kn, vn, kp, vp, pp, tb, pos):
        return kops.paged_prefill_attention(q, kn, vn, kp, vp, pp, tb, pos,
                                            backend="interpret")

    def dense(q, kn, vn, kp, vp, pp, tb, pos):
        from repro.configs.base import BlockSpec
        from repro.models import attention as A
        cache = {"kb": kp, "vb": vp, "pos": pp}
        return A._chunk_append(q, kn, vn, cache, BlockSpec(), pos, tb,
                               A.AttnSettings(backend="naive"))

    score_elems = C * K * G * ctx            # the [C, heads, ctx] matrix

    def max_intermediate(fn):
        jaxpr = jax.make_jaxpr(fn)(q, kn, kn, kp, kp, pp, tb, pos).jaxpr
        return max(int(np.prod(v.aval.shape))
                   for eqn in jaxpr.eqns for v in eqn.outvars)

    assert max_intermediate(dense) >= score_elems
    assert max_intermediate(tiled) < score_elems
