"""Checkpointing: roundtrip, atomic commit, async save, latest-step recovery,
cross-mesh resharding restore (elastic)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as CK
from conftest import run_in_subprocess

# subprocess + XLA compiles => slow tier
pytestmark = pytest.mark.slow

TREE = {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "layers": [jnp.ones((2, 2)), jnp.zeros((5,))]},
        "opt": {"count": jnp.asarray(3)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    CK.save(d, 7, TREE, extra={"note": "x"})
    restored, manifest = CK.restore(d, TREE)
    assert manifest["step"] == 7 and manifest["extra"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_skips_uncommitted(tmp_path):
    d = str(tmp_path / "ck")
    CK.save(d, 1, TREE)
    CK.save(d, 5, TREE)
    os.makedirs(os.path.join(d, "step_00000009.tmp"))  # crashed mid-save
    assert CK.latest_step(d) == 5


def test_async_save(tmp_path):
    d = str(tmp_path / "ck")
    t = CK.save(d, 2, TREE, async_=True)
    t.join()
    assert CK.latest_step(d) == 2
    restored, _ = CK.restore(d, TREE)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(TREE["params"]["w"]))


def test_restore_rejects_shape_mismatch(tmp_path):
    d = str(tmp_path / "ck")
    CK.save(d, 1, TREE)
    bad = {"params": {"w": jnp.zeros((4, 4)),
                      "layers": TREE["params"]["layers"]},
           "opt": TREE["opt"]}
    with pytest.raises(ValueError):
        CK.restore(d, bad)


def test_elastic_reshard_restore(tmp_path):
    """Save on an 8-device (4,2) mesh, restore onto a (2,2) submesh — the
    elastic-restart path."""
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import ckpt as CK
from repro.launch.mesh import make_mesh

d = {str(tmp_path / 'eck')!r}
mesh8 = make_mesh((4, 2), ("data", "model"))
spec = {{"w": P("data", "model")}}
w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh8, spec["w"]))
CK.save(d, 1, {{"w": w}})

mesh4 = make_mesh((2, 2), ("data", "model"))
target = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
restored, _ = CK.restore(d, target, mesh=mesh4, spec_tree=spec)
assert restored["w"].sharding.mesh.devices.size == 4
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64.0).reshape(8, 8))
print("ELASTIC_OK")
"""
    out = run_in_subprocess(code, devices=8)
    assert "ELASTIC_OK" in out
