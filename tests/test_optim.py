"""Optimizers: AdamW reference math, adafactor behaviour, clipping, schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizers as opt
from repro.optim.schedule import warmup_cosine


def test_adamw_first_step_matches_reference():
    ocfg = opt.OptimizerConfig(kind="adamw_f32", lr=0.1, b1=0.9, b2=0.99,
                               eps=1e-8, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.5, 0.5, -1.0])}
    state = opt.init_state(ocfg, params)
    new_p, _ = opt.apply_updates(ocfg, params, grads, state, 0.1)
    # bias-corrected first step: m̂=g, v̂=g² -> step = g/(|g|+eps) = sign(g)
    expected = np.asarray([1.0, -2.0, 3.0]) - 0.1 * np.sign([0.5, 0.5, -1.0])
    np.testing.assert_allclose(np.asarray(new_p["w"]), expected, atol=1e-5)


def test_adamw_weight_decay_shrinks():
    ocfg = opt.OptimizerConfig(kind="adamw_f32", lr=0.1, weight_decay=0.5)
    params = {"w": jnp.asarray([10.0])}
    grads = {"w": jnp.asarray([0.0])}
    state = opt.init_state(ocfg, params)
    new_p, _ = opt.apply_updates(ocfg, params, grads, state, 0.1)
    assert float(new_p["w"][0]) < 10.0


def test_adamw_bf16_state_dtype():
    ocfg = opt.OptimizerConfig(kind="adamw_bf16")
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    state = opt.init_state(ocfg, params)
    assert state["m"]["w"].dtype == jnp.bfloat16


def test_adafactor_factored_shapes():
    ocfg = opt.OptimizerConfig(kind="adafactor")
    params = {"w": jnp.zeros((6, 8)), "b": jnp.zeros((8,)),
              "t": jnp.zeros((2, 6, 8))}
    state = opt.init_state(ocfg, params)
    assert state["f"]["w"]["vr"].shape == (6,)
    assert state["f"]["w"]["vc"].shape == (8,)
    assert state["f"]["t"]["vr"].shape == (2, 6)
    assert state["f"]["t"]["vc"].shape == (2, 8)
    assert state["f"]["b"]["v"].shape == (8,)


def test_adafactor_state_much_smaller():
    ocfg = opt.OptimizerConfig(kind="adafactor")
    params = {"w": jnp.zeros((512, 512))}
    state = opt.init_state(ocfg, params)
    state_elems = sum(x.size for x in jax.tree.leaves(state))
    assert state_elems < 0.01 * params["w"].size


def test_adafactor_descends_quadratic():
    ocfg = opt.OptimizerConfig(kind="adafactor", lr=0.1, weight_decay=0.0)
    params = {"w": jnp.full((4, 4), 5.0)}
    state = opt.init_state(ocfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(20):
        grads = jax.grad(loss)(params)
        params, state = opt.apply_updates(ocfg, params, grads, state, 0.1)
    assert float(loss(params)) < l0 * 0.5


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = opt.clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    cn = float(opt.global_norm(clipped))
    assert cn == pytest.approx(1.0, rel=1e-5)
    # under the limit: unchanged
    same, _ = opt.clip_by_global_norm(tree, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0])


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.asarray(s), 1e-3, 10, 100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9           # warmup ascends
    assert lrs[10] == pytest.approx(1e-3, rel=0.1)  # peak after warmup
    assert lrs[-1] < lrs[20]                         # cosine decays
    assert lrs[-1] >= 1e-4 - 1e-9                    # min_ratio floor
