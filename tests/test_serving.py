"""Memory-governed serving engine, hermetic tier: the capacity inversion
(predictor.serving_capacity), the max-concurrency planner (plan_serving),
and the jax-free scheduler core (admission bound, queueing, continuous-vs-
static occupancy) — all with ZERO XLA compiles. Token parity of the real
executor against greedy_generate lives in the slow tier (test_serve.py)."""
import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.configs.base import DECODE, ShapeConfig
from repro.core import measure as MM
from repro.core import predictor as PR
from repro.core import profiler as PF
from repro.search import execplan as XP
from repro.search import space as SP
from repro.serving import (BlockAllocator, Engine, Request, ScriptedExecutor,
                           describe_trace, synthetic_trace, trace_context)

CFG = get_config("h2o-danube-1.8b")
SHAPE = ShapeConfig("serve_t", DECODE, 4096, 8)
GIB = 2**30


def _cls(cfg=CFG, shape=SHAPE):
    sim = MM.SimulatedMeasurer({"data": 8})
    return PF.classify_workload(cfg, shape, None, n_points=2, base_seq=64,
                                measurer=sim)


def _no_compile(monkeypatch):
    import repro.launch.compile as LC

    def boom(*a, **k):
        raise AssertionError("XLA compile attempted in hermetic test")
    monkeypatch.setattr(LC, "build", boom)


@pytest.fixture(scope="module")
def cls():
    return _cls()


# --- serving_capacity: the requirement model run backwards -------------------

def test_serving_capacity_is_exact_admission_bound(cls):
    """The returned count fits the budget; one more per-device slot does
    not — the inversion is exact w.r.t. the forward model."""
    mesh = {"data": 2, "model": 1}
    budget = 8 * GIB
    plan = PR.MemoryPlan()
    cap = PR.serving_capacity(CFG, SHAPE, plan, cls, mesh, hbm_budget=budget)
    assert cap > 0 and cap % 2 == 0          # whole per-device slots x dp
    _, dp, _ = PR.mesh_factors(mesh)

    def capacity_at(n):
        sh = dataclasses.replace(SHAPE, global_batch=n)
        return PR.predict(CFG, sh, plan, cls, mesh).capacity_bytes

    assert capacity_at(cap) <= budget
    assert capacity_at(cap + dp) > budget


def test_serving_capacity_monotone_in_budget(cls):
    mesh = {"data": 1, "model": 4}
    caps = [PR.serving_capacity(CFG, SHAPE, PR.MemoryPlan(), cls, mesh,
                                hbm_budget=b * GIB) for b in (3, 4, 8, 16)]
    assert caps == sorted(caps)
    assert caps[-1] > caps[0] > 0


def test_serving_capacity_zero_when_nothing_fits(cls):
    cap = PR.serving_capacity(CFG, SHAPE, PR.MemoryPlan(), cls,
                              {"data": 1}, hbm_budget=2**20)
    assert cap == 0


def test_serving_capacity_kv_seq_beats_padded_heads():
    """With 2 kv heads over model=4, 'heads' sharding pads each device up
    to a whole replicated head (2x the exact share) while 'seq' shards the
    ring length evenly — the admission controller must see the difference
    (why kv_shard is a real knob in serving_space)."""
    cfg = dataclasses.replace(CFG, name="h2o-kv2", n_kv_heads=2)
    cls = _cls(cfg, SHAPE)
    mesh = {"data": 1, "model": 4}
    heads = PR.serving_capacity(cfg, SHAPE, PR.MemoryPlan(kv_shard="heads"),
                                cls, mesh, hbm_budget=6 * GIB)
    seq = PR.serving_capacity(cfg, SHAPE, PR.MemoryPlan(kv_shard="seq"),
                              cls, mesh, hbm_budget=6 * GIB)
    assert seq > heads > 0


# --- plan_serving: pick the config that maximizes admitted concurrency ------

def test_plan_serving_zero_compiles(monkeypatch, cls):
    _no_compile(monkeypatch)
    got_cls, splan = XP.plan_serving(CFG, SHAPE, n_devices=8, cls=cls,
                                     hbm_budget=8 * GIB)
    assert got_cls is cls
    assert splan.capacity > 0
    assert splan.execution.schedule == "single"
    assert splan.execution.n_devices <= 8
    assert splan.considered > 1
    # more devices must never admit less
    _, splan1 = XP.plan_serving(CFG, SHAPE, n_devices=1, cls=cls,
                                hbm_budget=8 * GIB)
    assert splan.capacity >= splan1.capacity
    assert "capacity=" in splan.describe()


def test_plan_serving_beats_single_device_default(cls):
    """The planned mesh admits strictly more than the naive data:1 host
    default under a tight budget — the whole point of planning the mesh."""
    budget = 3 * GIB
    _, auto = XP.plan_serving(CFG, SHAPE, n_devices=8, cls=cls,
                              hbm_budget=budget)
    pinned = SP.serving_space(CFG, SHAPE, max_devices=8, data=(1,),
                              model=(1,))
    _, host = XP.plan_serving(CFG, SHAPE, n_devices=8, cls=cls,
                              hbm_budget=budget, space=pinned)
    assert auto.capacity > host.capacity
    assert auto.capacity >= 8                # the planned mesh fills the host


def test_serving_plan_slots_cap(cls):
    _, splan = XP.plan_serving(CFG, SHAPE, n_devices=8, cls=cls,
                               hbm_budget=8 * GIB)
    assert splan.slots() == splan.capacity
    assert splan.slots(cap=4) == 4
    assert splan.slots(cap=10**9) == splan.capacity


def test_serving_space_pins_serving_knobs():
    space = SP.serving_space(CFG, SHAPE, max_devices=8)
    for cand in space.candidates(CFG, SHAPE):
        assert cand.plan.remat == "none"
        assert cand.plan.microbatches == 1
        assert cand.mesh_shape["pipe"] == 1
        assert cand.plan.kv_shard in ("heads", "seq")


class _CountingMeasurer(MM.SimulatedMeasurer):
    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.n_measures = 0

    def _measure(self, *args, **kwargs):
        self.n_measures += 1
        return super()._measure(*args, **kwargs)


def test_auto_plan_threads_measurer():
    """`--backend compile` on the auto path must reach the provided
    measurer (satellite: the flag used to be silently ignored)."""
    counting = _CountingMeasurer({"data": 8})
    cls, eplan = XP.auto_plan(CFG, SHAPE, n_devices=8, measurer=counting)
    assert counting.n_measures > 0           # classification ladder used it
    assert eplan.n_devices <= 8


# --- synthetic traces --------------------------------------------------------

def test_synthetic_trace_deterministic():
    kw = dict(vocab_size=512, seed=3, prompt_lens=(4, 8), gen_lens=(2, 4),
              mean_interarrival=1.5)
    t1 = synthetic_trace(10, **kw)
    t2 = synthetic_trace(10, **kw)
    assert t1 == t2
    assert t1 != synthetic_trace(10, **{**kw, "seed": 4})
    arrivals = [r.arrival for r in t1]
    assert arrivals == sorted(arrivals)
    assert all(2 <= tok < 512 for r in t1 for tok in r.prompt)
    assert trace_context(t1) == max(len(r.prompt) + r.max_new for r in t1)
    assert "requests" in describe_trace(t1)


def test_synthetic_trace_burst_mode():
    t = synthetic_trace(5, vocab_size=64, seed=0, mean_interarrival=0)
    assert all(r.arrival == 0 for r in t)


# --- the scheduler core ------------------------------------------------------

def _burst(n, gens, seed=0, prompts=(4, 8)):
    return synthetic_trace(n, vocab_size=97, seed=seed, prompt_lens=prompts,
                           gen_lens=gens, mean_interarrival=0)


def test_admission_never_exceeds_slot_pool():
    """The deterministic occupancy pin: with 6 burst requests and a
    2-slot pool (the WSMC capacity bound), concurrency never exceeds 2,
    oversubscribed requests queue, and everything still completes."""
    trace = _burst(6, (2, 4, 8))
    rep = Engine(ScriptedExecutor(), 2).run(trace)
    assert rep.max_concurrent == 2
    assert rep.peak_queue >= 4               # 6 arrived, 2 admitted at t=0
    assert len(rep.completions) == 6
    assert rep.generated_tokens == sum(r.max_new for r in trace)
    for c in rep.completions:
        assert len(c.tokens) == trace[c.rid].max_new
        assert c.admitted >= c.arrival
        assert c.finished >= c.admitted


def test_admission_bound_derives_from_serving_capacity(cls):
    """Acceptance pin, end to end: the slot pool sized by the PREDICTED
    capacity bounds concurrency — admission never exceeds
    predictor.serving_capacity, the rest queue, everyone completes."""
    mesh = {"data": 1, "model": 4}
    cap = PR.serving_capacity(CFG, SHAPE, PR.MemoryPlan(), cls, mesh,
                              hbm_budget=8 * GIB)
    assert 0 < cap < 8                       # the budget is genuinely tight
    trace = _burst(cap + 4, (2, 4, 8))
    rep = Engine(ScriptedExecutor(), cap).run(trace)
    assert rep.max_concurrent <= cap
    assert rep.peak_queue > 0                # oversubscription queued
    assert len(rep.completions) == cap + 4


def test_engine_run_is_deterministic():
    trace = _burst(7, (1, 3, 9), seed=5)
    r1 = Engine(ScriptedExecutor(), 3).run(trace)
    r2 = Engine(ScriptedExecutor(), 3).run(trace)
    assert r1 == r2


def test_continuous_beats_static_occupancy():
    """Acceptance pin: on a mixed-length trace, continuous batching's
    useful-token fraction of decode-step slots is strictly higher than the
    fixed-batch baseline's (backfill vs straggler-pinned idle slots)."""
    trace = _burst(8, (2, 8))
    cont = Engine(ScriptedExecutor(), 3, policy="continuous").run(trace)
    stat = Engine(ScriptedExecutor(), 3, policy="static").run(trace)
    assert len(cont.completions) == len(stat.completions) == 8
    # same tokens generated either way (scheduling must not change outputs)
    assert ([c.tokens for c in cont.completions]
            == [c.tokens for c in stat.completions])
    assert cont.occupancy() > stat.occupancy()
    assert cont.ticks <= stat.ticks
    assert 0.0 < stat.occupancy() < cont.occupancy() <= 1.0


def test_single_token_requests_complete_without_decode():
    trace = _burst(4, (1,))
    rep = Engine(ScriptedExecutor(), 4).run(trace)
    assert len(rep.completions) == 4
    assert rep.decode_ticks == 0
    assert all(len(c.tokens) == 1 for c in rep.completions)
    # finishing at admission still counts as having been concurrent/busy
    assert rep.max_concurrent == 4
    assert rep.idle_ticks == 0


def test_staggered_arrivals_idle_then_serve():
    trace = [r for r in synthetic_trace(4, vocab_size=97, seed=1,
                                        prompt_lens=(4,), gen_lens=(2,),
                                        mean_interarrival=6.0)]
    rep = Engine(ScriptedExecutor(), 2).run(trace)
    assert len(rep.completions) == 4
    if trace[-1].arrival > 8:                # gaps => idle ticks counted
        assert rep.idle_ticks > 0


def test_engine_rejects_bad_config():
    with pytest.raises(ValueError, match="n_slots"):
        Engine(ScriptedExecutor(), 0)
    with pytest.raises(ValueError, match="policy"):
        Engine(ScriptedExecutor(), 2, policy="paged")


def test_engine_rejects_degenerate_requests():
    """max_new=0 / empty prompts must fail fast, not spin to max_ticks."""
    from repro.serving import Request
    eng = Engine(ScriptedExecutor(), 2)
    with pytest.raises(ValueError, match="max_new"):
        eng.run([Request(rid=0, arrival=0, prompt=(5,), max_new=0)])
    with pytest.raises(ValueError, match="prompt"):
        eng.run([Request(rid=0, arrival=0, prompt=(), max_new=2)])


def test_report_metrics_bounds():
    trace = _burst(5, (2, 4))
    rep = Engine(ScriptedExecutor(), 2).run(trace)
    assert 0.0 < rep.occupancy() <= 1.0
    assert rep.throughput() > 0
    assert rep.mean_latency() >= 0
    assert "occupancy=" in rep.describe()


# --- slot-aware prefill shapes (trace-only: jax.eval_shape, no compiles) ----

def test_prefill_cache_pads_to_full_ring():
    """A prompt shorter than cache_len must still emit the FULL ring (empty
    slots pos=-1) — shorter rings would wrap at prompt_len and evict live
    context, and pool slots need uniform shapes."""
    from repro.models import init_params
    from repro.models import model as M
    from repro.runtime.serve_step import make_prefill_step
    cfg = get_config("h2o-danube-1.8b").reduced()
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: init_params(key, cfg))
    tokens = jax.ShapeDtypeStruct((1, 4), jax.numpy.int32)
    prefill = make_prefill_step(cfg)
    _, cache = jax.eval_shape(lambda p, t: prefill(p, t, context=12),
                              params, tokens)
    ref = M.init_cache(cfg, 1, 12, abstract=True)
    assert jax.tree.map(lambda a: a.shape, cache) \
        == jax.tree.map(lambda a: a.shape, ref)


def test_write_cache_slot_preserves_pool_shapes():
    from repro.models import model as M
    from repro.runtime.serve_step import write_cache_slot
    cfg = get_config("recurrentgemma-9b").reduced()   # attn + recurrent mix
    pool = M.init_cache(cfg, 3, 16, abstract=True)
    one = M.init_cache(cfg, 1, 16, abstract=True)
    out = jax.eval_shape(lambda P, o: write_cache_slot(cfg, P, o, 1),
                         pool, one)
    assert jax.tree.map(lambda a: a.shape, out) \
        == jax.tree.map(lambda a: a.shape, pool)


# --- tick taxonomy (the metrics invariant the bugfix sweep pins) ------------

def test_tick_taxonomy_is_a_partition():
    """Every tick is exactly one of decode / admit-only / idle — the
    accounting identity ticks == decode + admit + idle must hold for
    bursty, staggered, and chunked schedules alike."""
    traces = [_burst(6, (2, 4)),
              synthetic_trace(5, vocab_size=97, seed=3, prompt_lens=(4, 8),
                              gen_lens=(1, 4), mean_interarrival=3.0),
              _burst(4, (1,))]
    for chunk in (0, 4):
        for trace in traces:
            rep = Engine(ScriptedExecutor(), 2,
                         allocator=BlockAllocator(24, 4),
                         chunk_prefill=chunk).run(trace)
            assert rep.ticks == (rep.decode_ticks + rep.admit_ticks
                                 + rep.idle_ticks)
            assert len(rep.completions) == len(trace)


def test_single_token_burst_counts_admit_not_idle():
    """Prefill-only traffic: the admission ticks must land in admit_ticks,
    never in idle_ticks (the engine was busy) nor decode_ticks (no decode
    step ran)."""
    rep = Engine(ScriptedExecutor(), 4).run(_burst(4, (1,)))
    assert rep.decode_ticks == 0
    assert rep.idle_ticks == 0
    assert rep.admit_ticks >= 1
    assert rep.ticks == rep.admit_ticks


# --- lane compaction (scripted: bucket selection and width accounting) ------

def test_compacted_decode_picks_covering_bucket():
    """With 2 active lanes in a 4-lane pool the engine must decode at
    width 2, and at width 1 once one lane remains — tick_widths records
    the smallest covering bucket each decode tick."""
    trace = [Request(rid=0, arrival=0, prompt=(3, 4), max_new=6),
             Request(rid=1, arrival=0, prompt=(5, 6), max_new=2)]
    ex = ScriptedExecutor(buckets=(1, 2, 4))
    rep = Engine(ex, 4, allocator=BlockAllocator(16, 4)).run(trace)
    assert set(ex.tick_widths) == {1, 2}
    assert ex.tick_widths == sorted(ex.tick_widths, reverse=True)
    assert rep.decode_lane_tokens == sum(ex.tick_widths)
    assert rep.decode_lane_tokens < rep.decode_ticks * 4
    assert rep.occupancy() > 0.9             # vs ~0.4 at full width


def test_compacted_and_full_width_tokens_identical():
    """Compaction changes WHICH lanes ride each decode step, never what
    any lane emits: bucketed and full-width scripted runs agree."""
    trace = _burst(8, (2, 4, 8), seed=11)
    full = Engine(ScriptedExecutor(), 4,
                  allocator=BlockAllocator(32, 4)).run(trace)
    ex = ScriptedExecutor(buckets=(1, 2, 4))
    comp = Engine(ex, 4, allocator=BlockAllocator(32, 4)).run(trace)
    assert ([c.tokens for c in full.completions]
            == [c.tokens for c in comp.completions])
    assert min(ex.tick_widths) < 4           # compaction actually engaged


def test_engine_width_accounting_without_decode_width():
    """Executors without decode_width (the ring JaxExecutor protocol) are
    charged full pool width — occupancy falls back to the old meaning."""
    rep = Engine(ScriptedExecutor(), 3).run(_burst(4, (2, 4)))
    assert rep.decode_lane_tokens == rep.decode_ticks * 3


# --- chunked prefill (scripted: scheduling and parity) ----------------------

def test_chunked_prefill_matches_whole_prompt_tokens():
    """Splitting a long prompt into chunks interleaved with decode ticks
    must not change any completion: same trace, chunked vs unchunked."""
    trace = synthetic_trace(6, vocab_size=97, seed=9, prompt_lens=(4, 20),
                            gen_lens=(2, 4), mean_interarrival=0.5)
    whole = Engine(ScriptedExecutor(), 3,
                   allocator=BlockAllocator(40, 4)).run(trace)
    ex = ScriptedExecutor()
    chunked = Engine(ex, 3, allocator=BlockAllocator(40, 4),
                     chunk_prefill=8).run(trace)
    assert ([c.tokens for c in whole.completions]
            == [c.tokens for c in chunked.completions])
    assert chunked.chunk_calls == ex.chunk_calls > 0
    assert chunked.ticks == (chunked.decode_ticks + chunked.admit_ticks
                             + chunked.idle_ticks)


def test_chunked_prefill_short_prompts_skip_chunking():
    """Prompts <= chunk_prefill take the whole-prompt path — zero chunk
    calls, identical schedule to chunk_prefill=0."""
    trace = _burst(4, (2,), prompts=(4,))
    ex = ScriptedExecutor()
    rep = Engine(ex, 2, allocator=BlockAllocator(16, 4),
                 chunk_prefill=8).run(trace)
    ref = Engine(ScriptedExecutor(), 2,
                 allocator=BlockAllocator(16, 4)).run(trace)
    assert ex.chunk_calls == 0 and rep.chunk_calls == 0
    assert rep.completions == ref.completions


def test_engine_rejects_misaligned_chunk():
    with pytest.raises(ValueError, match="multiple"):
        Engine(ScriptedExecutor(), 2, allocator=BlockAllocator(8, 4),
               chunk_prefill=6)
    with pytest.raises(ValueError, match=">= 0"):
        Engine(ScriptedExecutor(), 2, chunk_prefill=-1)
