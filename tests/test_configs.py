"""Config system: registry, param counts vs eval_shape, shape registry."""
import jax
import pytest

from repro.configs import (ARCH_IDS, SHAPES, get_config, param_count,
                           active_param_count, shape_applicable, input_specs)
from repro.launch.compile import abstract_params

EXPECTED_B = {  # published sizes (±20% tolerance; DESIGN.md notes deviations)
    "xlstm-1.3b": 1.4, "musicgen-medium": 1.5, "nemotron-4-340b": 341.0,
    "h2o-danube-1.8b": 1.8, "gemma3-12b": 12.0, "mistral-nemo-12b": 12.2,
    "recurrentgemma-9b": 9.0, "mixtral-8x7b": 46.7,
    "llama4-scout-17b-a16e": 107.0, "internvl2-26b": 20.0,
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_eval_shape(arch):
    cfg = get_config(arch).reduced()
    analytic = param_count(cfg)
    actual = sum(int(x.size) for x in jax.tree.leaves(abstract_params(cfg)))
    assert abs(analytic - actual) / actual < 0.02, (analytic, actual)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_size_in_expected_range(arch):
    n = param_count(get_config(arch)) / 1e9
    exp = EXPECTED_B[arch]
    assert 0.8 * exp < n < 1.25 * exp, (arch, n, exp)


def test_pattern_covers_depth():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert len(cfg.blocks()) == cfg.n_layers


def test_moe_active_params_smaller():
    cfg = get_config("mixtral-8x7b")
    assert active_param_count(cfg) < 0.4 * param_count(cfg)


def test_long_500k_skips():
    skipped = {a for a in ARCH_IDS
               if not shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert skipped == {"musicgen-medium", "nemotron-4-340b",
                       "mistral-nemo-12b", "internvl2-26b"}


def test_40_cells_defined():
    assert len(ARCH_IDS) * len(SHAPES) == 40


def test_input_specs_kinds():
    cfg = get_config("internvl2-26b")
    tr = input_specs(cfg, SHAPES["train_4k"])
    assert tr["tokens"].shape == (256, 4096 - cfg.n_prefix_embeds)
    assert "prefix_embeds" in tr
    de = input_specs(cfg, SHAPES["decode_32k"])
    assert de["tokens"].shape == (128, 1)
    assert "positions" in de


def test_padded_vocab_divisible():
    for arch in ARCH_IDS:
        assert get_config(arch).padded_vocab_size % 16 == 0
