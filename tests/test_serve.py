"""Serving: prefill+decode equals full forward; greedy generation runs;
the continuous-batching engine is token-identical to per-request greedy
decoding; ring-KV wraparound under heterogeneous batched positions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ModelSettings, apply, init_params
from repro.models.attention import AttnSettings
from repro.runtime.serve_step import (greedy_generate, make_decode_step,
                                      make_prefill_step, write_cache_slot)
from repro.serving import Engine, synthetic_trace, trace_context
from repro.serving.executor import JaxExecutor

# XLA compiles dominate the runtime => slow tier
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(1)
SETTINGS = ModelSettings(attn=AttnSettings(backend="naive"))

DECODE_ARCHS = ["h2o-danube-1.8b", "xlstm-1.3b", "recurrentgemma-9b",
                "gemma3-12b", "mixtral-8x7b", "llama4-scout-17b-a16e",
                "mistral-nemo-12b", "musicgen-medium", "nemotron-4-340b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    S, S0, b = 24, 16, 2
    tokens = jax.random.randint(KEY, (b, S), 0, cfg.vocab_size)
    full_logits, _, _ = apply(params, cfg, tokens, settings=SETTINGS)
    prefill = make_prefill_step(cfg, SETTINGS)
    decode = make_decode_step(cfg, SETTINGS)
    _, cache = prefill(params, tokens[:, :S0], context=S)
    errs = []
    for t in range(S0, S):
        pos = jnp.full((b,), t, jnp.int32)
        lg, cache = decode(params, tokens[:, t:t + 1], pos, cache, context=S)
        errs.append(float(jnp.abs(lg - full_logits[:, t]).max()))
    assert max(errs) < 0.06, errs   # bf16 params tolerance


def test_greedy_generate_deterministic():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(KEY, cfg)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    out1 = greedy_generate(params, cfg, prompt, n_steps=6, context=16,
                           settings=SETTINGS)
    out2 = greedy_generate(params, cfg, prompt, n_steps=6, context=16,
                           settings=SETTINGS)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.padded_vocab_size


def test_prefill_last_logits_only():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(KEY, cfg)
    prefill = make_prefill_step(cfg, SETTINGS)
    logits, cache = prefill(params, jax.random.randint(KEY, (2, 8), 0, 100),
                            context=16)
    assert logits.shape == (2, cfg.padded_vocab_size)
    assert cache is not None


# --- the serving engine: continuous batching over the slot pool -------------

# attention-only, recurrent+windowed-attention mix, and xLSTM state caches
ENGINE_ARCHS = ["h2o-danube-1.8b", "recurrentgemma-9b", "gemma3-12b"]


@pytest.mark.parametrize("arch", ENGINE_ARCHS)
def test_engine_matches_greedy_generate(arch):
    """Acceptance pin: engine output is token-identical to greedy_generate
    for every request of a mixed-length trace, even though the engine
    serves them through a shared slot pool with batched heterogeneous-
    position decode and slot reuse."""
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    trace = synthetic_trace(5, vocab_size=cfg.vocab_size, seed=2,
                            prompt_lens=(4, 6), gen_lens=(3, 6),
                            mean_interarrival=1.0)
    context = trace_context(trace)
    executor = JaxExecutor(params, cfg, n_slots=2, context=context,
                           settings=SETTINGS)
    report = Engine(executor, 2).run(trace)
    assert len(report.completions) == len(trace)
    assert report.max_concurrent == 2        # slots were actually shared
    for c in report.completions:
        req = trace[c.rid]
        ref = greedy_generate(params, cfg,
                              jnp.asarray(req.prompt, jnp.int32)[None],
                              n_steps=req.max_new, context=context,
                              settings=SETTINGS)
        assert list(c.tokens) == np.asarray(ref)[0].tolist(), c.rid


def test_engine_static_policy_same_tokens():
    """Scheduling policy changes WHEN requests run, never WHAT they emit."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(KEY, cfg)
    trace = synthetic_trace(4, vocab_size=cfg.vocab_size, seed=3,
                            prompt_lens=(4,), gen_lens=(2, 5),
                            mean_interarrival=0)
    context = trace_context(trace)
    reports = []
    for policy in ("continuous", "static"):
        ex = JaxExecutor(params, cfg, n_slots=2, context=context,
                         settings=SETTINGS)
        reports.append(Engine(ex, 2, policy=policy).run(trace))
    assert ([c.tokens for c in reports[0].completions]
            == [c.tokens for c in reports[1].completions])
    assert reports[0].occupancy() >= reports[1].occupancy()


# --- the paged engine: block-table decode over the shared pool --------------

# pure global attention, and a mixed paged/per-lane tree (window=8 locals)
PAGED_ARCHS = ["mistral-nemo-12b", "gemma3-12b"]


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_engine_matches_greedy_generate(arch):
    """Acceptance pin: the paged engine (block pool + per-sequence block
    tables, kv_block=4 so every request spans MULTIPLE blocks, slot and
    block reuse across requests) emits exactly greedy_generate's tokens,
    with ONE decode compile at lane width."""
    from repro.serving import BlockAllocator
    from repro.serving.executor import PagedJaxExecutor
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    trace = synthetic_trace(5, vocab_size=cfg.vocab_size, seed=2,
                            prompt_lens=(4, 6), gen_lens=(3, 6),
                            mean_interarrival=1.0)
    context = trace_context(trace)
    kv_block = 4
    n_blocks = 12
    executor = PagedJaxExecutor(params, cfg, n_lanes=2, n_blocks=n_blocks,
                                kv_block=kv_block, context=context,
                                settings=SETTINGS)
    allocator = BlockAllocator(n_blocks, kv_block)
    report = Engine(executor, 2, allocator=allocator).run(trace)
    assert len(report.completions) == len(trace)
    assert report.max_concurrent == 2        # lanes were actually shared
    assert 0 < report.peak_blocks <= n_blocks
    assert executor.compile_counts()["decode"] == 1
    for c in report.completions:
        req = trace[c.rid]
        assert len(req.prompt) + req.max_new - 1 > kv_block  # spans blocks
        ref = greedy_generate(params, cfg,
                              jnp.asarray(req.prompt, jnp.int32)[None],
                              n_steps=req.max_new, context=executor.context,
                              settings=SETTINGS)
        assert list(c.tokens) == np.asarray(ref)[0].tolist(), c.rid


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_compacted_chunked_engine_matches_greedy_generate(arch):
    """Acceptance pin for lane compaction + chunked prefill: bucketed
    decode widths (ticks with one live lane run at width 1) and prompts
    split into kv_block-aligned chunks still emit exactly
    greedy_generate's tokens, and the compact step compiles at most once
    per (lane, table) bucket pair actually touched."""
    from repro.serving import BlockAllocator
    from repro.serving.executor import PagedJaxExecutor
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    trace = synthetic_trace(5, vocab_size=cfg.vocab_size, seed=2,
                            prompt_lens=(4, 10), gen_lens=(3, 6),
                            mean_interarrival=1.0)
    context = trace_context(trace)
    kv_block, n_blocks = 4, 16
    executor = PagedJaxExecutor(params, cfg, n_lanes=2, n_blocks=n_blocks,
                                kv_block=kv_block, context=context,
                                settings=SETTINGS, compact=True,
                                chunk=kv_block)
    # the jitted steps are memoized per (cfg, settings) process-wide, so
    # compile counts from earlier tests persist — assert on deltas
    before = executor.compile_counts()
    report = Engine(executor, 2, allocator=BlockAllocator(n_blocks, kv_block),
                    chunk_prefill=kv_block).run(trace)
    assert len(report.completions) == len(trace)
    assert report.chunk_calls > 0            # long prompts went chunked
    counts = executor.compile_counts()
    assert counts["decode"] == before["decode"]  # every tick was compacted
    assert 0 < counts["decode_compact"] <= (len(executor.lane_buckets)
                                            * len(executor.table_buckets))
    assert report.decode_lane_tokens < report.decode_ticks * 2
    for c in report.completions:
        req = trace[c.rid]
        ref = greedy_generate(params, cfg,
                              jnp.asarray(req.prompt, jnp.int32)[None],
                              n_steps=req.max_new, context=executor.context,
                              settings=SETTINGS)
        assert list(c.tokens) == np.asarray(ref)[0].tolist(), c.rid


def test_evicted_requests_match_greedy_generate():
    """Acceptance pin for eviction-and-requeue on the REAL executor: an
    expected-mode pool sized below the trace's true demand (stats come
    from a deliberately short profile) forces evictions mid-decode, and
    the evicted requests — re-prefilled from prompt + already-emitted
    tokens through the chunked path — still reproduce greedy_generate
    exactly."""
    from repro.serving import BlockAllocator
    from repro.serving.engine import LengthStats
    from repro.serving.executor import PagedJaxExecutor
    cfg = get_config("mistral-nemo-12b").reduced()
    params = init_params(KEY, cfg)
    trace = synthetic_trace(4, vocab_size=cfg.vocab_size, seed=2,
                            prompt_lens=(4, 6), gen_lens=(6,),
                            mean_interarrival=0)
    context = trace_context(trace)
    kv_block = 4
    n_blocks = 5        # max request needs 3 blocks; two lanes want 6
    # wrong-on-purpose profile: claims every request writes ~1 block
    stats = LengthStats(by_prompt={}, mean=4.0, std=0.0, max=4)
    executor = PagedJaxExecutor(params, cfg, n_lanes=2, n_blocks=n_blocks,
                                kv_block=kv_block, context=context,
                                settings=SETTINGS, chunk=kv_block)
    allocator = BlockAllocator(n_blocks, kv_block, reservation="expected")
    report = Engine(executor, 2, allocator=allocator,
                    chunk_prefill=kv_block, stats=stats,
                    sigma_k=0.0).run(trace)
    assert report.evictions > 0              # the pressure actually hit
    assert len(report.completions) == len(trace)
    for c in report.completions:
        req = trace[c.rid]
        ref = greedy_generate(params, cfg,
                              jnp.asarray(req.prompt, jnp.int32)[None],
                              n_steps=req.max_new, context=executor.context,
                              settings=SETTINGS)
        assert list(c.tokens) == np.asarray(ref)[0].tolist(), c.rid


def test_prefix_shared_engine_matches_greedy_generate():
    """Acceptance pin for refcounted prefix sharing on the REAL executor:
    requests sharing a system-prompt prefix map their leading blocks to
    shared physical blocks (one prefix prefill, per-request suffixes
    through the chunked path) and still emit exactly greedy_generate's
    tokens for their FULL prompts."""
    from repro.serving import BlockAllocator
    from repro.serving.executor import PagedJaxExecutor
    cfg = get_config("mistral-nemo-12b").reduced()
    params = init_params(KEY, cfg)
    trace = synthetic_trace(4, vocab_size=cfg.vocab_size, seed=2,
                            prompt_lens=(4, 6), gen_lens=(3, 5),
                            mean_interarrival=1.0, prefix_len=8)
    context = trace_context(trace)
    kv_block, n_blocks = 4, 20

    def run(share):
        ex = PagedJaxExecutor(params, cfg, n_lanes=2, n_blocks=n_blocks,
                              kv_block=kv_block, context=context,
                              settings=SETTINGS, chunk=kv_block)
        rep = Engine(ex, 2, allocator=BlockAllocator(n_blocks, kv_block),
                     chunk_prefill=kv_block, prefix_share=share).run(trace)
        return rep

    shared = run(True)
    assert len(shared.completions) == len(trace)
    assert shared.chunk_calls < run(False).chunk_calls  # suffixes only
    for c in shared.completions:
        req = trace[c.rid]
        ref = greedy_generate(params, cfg,
                              jnp.asarray(req.prompt, jnp.int32)[None],
                              n_steps=req.max_new, context=context,
                              settings=SETTINGS)
        assert list(c.tokens) == np.asarray(ref)[0].tolist(), c.rid


def test_paged_engine_pallas_kernel_backend():
    """The Pallas paged-decode kernel (interpret-mode on CPU) drives the
    engine to the same tokens as the ring engine under identical settings:
    prefill is shared (blocked), so the only difference is ring jnp decode
    vs the kernel's block-table reads — the indirection the TPU kernel
    runs, exercised end to end."""
    from repro.models import ModelSettings
    from repro.serving import BlockAllocator
    from repro.serving.executor import JaxExecutor, PagedJaxExecutor
    cfg = get_config("mistral-nemo-12b").reduced()
    params = init_params(KEY, cfg)
    trace = synthetic_trace(3, vocab_size=cfg.vocab_size, seed=4,
                            prompt_lens=(4, 8), gen_lens=(3, 5),
                            mean_interarrival=0)
    settings = ModelSettings(attn=AttnSettings(backend="pallas"))
    # block-aligned context so ring and paged share one prefill extent
    context = -(-trace_context(trace) // 4) * 4
    ring_ex = JaxExecutor(params, cfg, n_slots=2, context=context,
                          settings=settings)
    ring = Engine(ring_ex, 2).run(trace)
    paged_ex = PagedJaxExecutor(params, cfg, n_lanes=2, n_blocks=10,
                                kv_block=4, context=context,
                                settings=settings)
    paged = Engine(paged_ex, 2,
                   allocator=BlockAllocator(10, 4)).run(trace)
    assert ([c.tokens for c in ring.completions]
            == [c.tokens for c in paged.completions])


@pytest.mark.parametrize("kv_quant", ["none", "int8"])
def test_fused_prefill_engine_matches_jnp_chunked(kv_quant):
    """The fused flash-prefill kernel (write + attend in one pass through
    the block tables, quantize-on-write in-kernel; interpret-mode on CPU)
    drives the chunked engine to the same tokens as the jnp chunk-append
    oracle (scatter, gather, dense SDPA) under identical settings — with a
    token budget throttling the chunk schedule on the kernel run, so the
    scheduling knob is covered by the same parity pin."""
    from repro.models import ModelSettings
    from repro.serving import BlockAllocator
    from repro.serving.executor import PagedJaxExecutor
    cfg = get_config("mistral-nemo-12b").reduced()
    params = init_params(KEY, cfg)
    trace = synthetic_trace(4, vocab_size=cfg.vocab_size, seed=4,
                            prompt_lens=(6, 10), gen_lens=(3, 5),
                            mean_interarrival=1.0)
    context = -(-trace_context(trace) // 4) * 4
    kv_block, n_blocks = 4, 14

    def run(backend, budget=0):
        settings = ModelSettings(attn=AttnSettings(backend=backend))
        ex = PagedJaxExecutor(params, cfg, n_lanes=2, n_blocks=n_blocks,
                              kv_block=kv_block, context=context,
                              settings=settings, chunk=kv_block,
                              kv_quant=kv_quant)
        rep = Engine(ex, 2, allocator=BlockAllocator(n_blocks, kv_block),
                     chunk_prefill=kv_block,
                     prefill_budget=budget).run(trace)
        assert ex.chunk_calls > 0            # the prefill kernel really ran
        return rep

    oracle = run("naive")
    fused = run("pallas", budget=kv_block)   # one chunk per tick
    assert len(fused.completions) == len(trace)
    assert fused.prefill_tokens == oracle.prefill_tokens \
        == sum(len(r.prompt) for r in trace)
    assert ([c.tokens for c in fused.completions]
            == [c.tokens for c in oracle.completions])
    if kv_quant == "none":
        # fp pools: the kernel is exact, so greedy_generate is matched too
        for c in fused.completions:
            req = trace[c.rid]
            ref = greedy_generate(params, cfg,
                                  jnp.asarray(req.prompt, jnp.int32)[None],
                                  n_steps=req.max_new, context=context,
                                  settings=SETTINGS)
            assert list(c.tokens) == np.asarray(ref)[0].tolist(), c.rid


RECURRENT_CHUNK_ARCHS = ["recurrentgemma-9b", "xlstm-1.3b"]


@pytest.mark.parametrize("arch", RECURRENT_CHUNK_ARCHS)
def test_recurrent_chunked_engine_matches_greedy_generate(arch):
    """Acceptance pin for chunked prefill over recurrent mixers: the scan
    state (mLSTM C/n/m, sLSTM core, RG-LRU h + conv tails) carries across
    chunk boundaries through the per-lane pool leaves, fresh lanes reset
    stale state, and the engine stays token-identical to greedy_generate
    — the gate that used to refuse non-attention archs is gone."""
    from repro.serving import BlockAllocator
    from repro.serving.executor import PagedJaxExecutor
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    trace = synthetic_trace(4, vocab_size=cfg.vocab_size, seed=2,
                            prompt_lens=(5, 10), gen_lens=(3, 6),
                            mean_interarrival=1.0)
    context = trace_context(trace)
    kv_block, n_blocks = 4, 16
    executor = PagedJaxExecutor(params, cfg, n_lanes=2, n_blocks=n_blocks,
                                kv_block=kv_block, context=context,
                                settings=SETTINGS, chunk=kv_block)
    report = Engine(executor, 2,
                    allocator=BlockAllocator(n_blocks, kv_block),
                    chunk_prefill=kv_block).run(trace)
    assert len(report.completions) == len(trace)
    assert report.chunk_calls > 0            # lane reuse + mid-prompt state
    for c in report.completions:
        req = trace[c.rid]
        ref = greedy_generate(params, cfg,
                              jnp.asarray(req.prompt, jnp.int32)[None],
                              n_steps=req.max_new, context=executor.context,
                              settings=SETTINGS)
        assert list(c.tokens) == np.asarray(ref)[0].tolist(), c.rid


def test_ring_wraparound_heterogeneous_positions():
    """Batched decode past cache_len with per-sequence positions must match
    the single-sequence reference: gemma3's sliding-window layers wrap
    their ring (slot = pos % L) several times while the global layer does
    not, and each pool row sits at a different position."""
    cfg = get_config("gemma3-12b").reduced()   # window=8 locals + global
    assert any(b.window for b in cfg.blocks())
    params = init_params(KEY, cfg)
    prompts = [5, 9, 12]
    n_steps = 10
    context = max(prompts) + n_steps           # window L=8 wraps; global no
    prefill = make_prefill_step(cfg, SETTINGS)
    decode = make_decode_step(cfg, SETTINGS)

    # single-sequence reference: each request decoded alone
    singles, caches = [], []
    for p in prompts:
        toks = jax.random.randint(jax.random.PRNGKey(p), (1, p), 2,
                                  cfg.vocab_size)
        logits, cache = prefill(params, toks, context=context)
        caches.append(cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        steps = []
        for t in range(n_steps):
            pos = jnp.full((1,), p + t, jnp.int32)
            logits, cache = decode(params, tok[:, None], pos, cache,
                                   context=context)
            steps.append((int(tok[0]), np.asarray(logits[0])))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        singles.append(steps)

    # pool path: all three in one batch at heterogeneous positions
    from repro.models.model import init_cache
    pool = init_cache(cfg, len(prompts), context)
    for i, cache in enumerate(caches):
        pool = write_cache_slot(cfg, pool, cache, i)
    for t in range(n_steps):
        toks = jnp.asarray([[singles[i][t][0]] for i in range(3)], jnp.int32)
        pos = jnp.asarray([p + t for p in prompts], jnp.int32)
        logits, pool = decode(params, toks, pos, pool, context=context)
        for i in range(3):
            ref = singles[i][t][1]
            got = np.asarray(logits[i])
            assert np.abs(got - ref).max() < 2e-2, (i, t)
            assert int(got.argmax()) == int(ref.argmax()), (i, t)
