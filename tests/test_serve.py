"""Serving: prefill+decode equals full forward; greedy generation runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ModelSettings, apply, init_params
from repro.models.attention import AttnSettings
from repro.runtime.serve_step import (greedy_generate, make_decode_step,
                                      make_prefill_step)

# XLA compiles dominate the runtime => slow tier
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(1)
SETTINGS = ModelSettings(attn=AttnSettings(backend="naive"))

DECODE_ARCHS = ["h2o-danube-1.8b", "xlstm-1.3b", "recurrentgemma-9b",
                "gemma3-12b", "mixtral-8x7b", "llama4-scout-17b-a16e",
                "mistral-nemo-12b", "musicgen-medium", "nemotron-4-340b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    S, S0, b = 24, 16, 2
    tokens = jax.random.randint(KEY, (b, S), 0, cfg.vocab_size)
    full_logits, _, _ = apply(params, cfg, tokens, settings=SETTINGS)
    prefill = make_prefill_step(cfg, SETTINGS)
    decode = make_decode_step(cfg, SETTINGS)
    _, cache = prefill(params, tokens[:, :S0], context=S)
    errs = []
    for t in range(S0, S):
        pos = jnp.full((b,), t, jnp.int32)
        lg, cache = decode(params, tokens[:, t:t + 1], pos, cache, context=S)
        errs.append(float(jnp.abs(lg - full_logits[:, t]).max()))
    assert max(errs) < 0.06, errs   # bf16 params tolerance


def test_greedy_generate_deterministic():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(KEY, cfg)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    out1 = greedy_generate(params, cfg, prompt, n_steps=6, context=16,
                           settings=SETTINGS)
    out2 = greedy_generate(params, cfg, prompt, n_steps=6, context=16,
                           settings=SETTINGS)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.padded_vocab_size


def test_prefill_last_logits_only():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(KEY, cfg)
    prefill = make_prefill_step(cfg, SETTINGS)
    logits, cache = prefill(params, jax.random.randint(KEY, (2, 8), 0, 100),
                            context=16)
    assert logits.shape == (2, cfg.padded_vocab_size)
    assert cache is not None
