"""Serving-engine example: replay a deterministic mixed-length trace
through continuous batching over the slotted ring-KV pool, then compare
against the fixed-batch baseline.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax

from repro.configs import get_config
from repro.models import ModelSettings, init_params
from repro.models.attention import AttnSettings
from repro.serving import Engine, describe_trace, synthetic_trace, trace_context
from repro.serving.executor import JaxExecutor

cfg = get_config("mistral-nemo-12b").reduced()
settings = ModelSettings(attn=AttnSettings(backend="blocked",
                                           q_block=32, kv_block=32))
SLOTS = 3

params = init_params(jax.random.PRNGKey(0), cfg)
trace = synthetic_trace(8, vocab_size=cfg.vocab_size, seed=1,
                        prompt_lens=(8, 16), gen_lens=(4, 12),
                        mean_interarrival=1.0)
context = trace_context(trace)
print("trace:", describe_trace(trace))

for policy in ("continuous", "static"):
    executor = JaxExecutor(params, cfg, n_slots=SLOTS, context=context,
                           settings=settings)
    engine = Engine(executor, SLOTS, policy=policy)
    t0 = time.time()
    report = engine.run(trace)
    print(report.describe() + f" wall={time.time() - t0:.2f}s")

first = report.completions[0]
print(f"  req{first.rid} tokens: {list(first.tokens)}")
