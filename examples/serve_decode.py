"""Batched serving example: prefill a batch of prompts, stream greedy
decode against the ring KV cache (sliding-window + global layers).

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import ModelSettings, init_params
from repro.models.attention import AttnSettings
from repro.runtime.serve_step import make_decode_step, make_prefill_step

cfg = get_config("mistral-nemo-12b").reduced()
settings = ModelSettings(attn=AttnSettings(backend="blocked",
                                           q_block=32, kv_block=32))
B, PROMPT, GEN = 4, 24, 12
CONTEXT = PROMPT + GEN

params = init_params(jax.random.PRNGKey(0), cfg)
prompts = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 2,
                             cfg.vocab_size)

prefill = make_prefill_step(cfg, settings)
decode = make_decode_step(cfg, settings)

t0 = time.time()
last_logits, cache = prefill(params, prompts, context=CONTEXT)
print(f"prefill {B}×{PROMPT} tokens: {time.time()-t0:.2f}s "
      f"(cache built for {CONTEXT} positions)")

tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
stream = [tok]
t0 = time.time()
for t in range(GEN - 1):
    pos = jnp.full((B,), PROMPT + t, jnp.int32)
    logits, cache = decode(params, tok[:, None], pos, cache, context=CONTEXT)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    stream.append(tok)
dt = time.time() - t0
gen = jnp.stack(stream, axis=1)
print(f"decoded {GEN-1} steps × {B} seqs in {dt:.2f}s "
      f"({dt/(GEN-1)*1e3:.0f} ms/step)")
for b in range(B):
    print(f"  seq{b}: {gen[b].tolist()}")
