"""Serving-engine example: replay a deterministic mixed-length trace
through continuous batching over the PAGED KV block pool — compacted
decode, chunked prefill, optimistic admission, prefix sharing — then bend
the pool's capacity with int8 blocks and block-granular retention and
measure what the bend costs in token agreement.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax

from repro.configs import get_config
from repro.models import ModelSettings, init_params
from repro.models.attention import AttnSettings
from repro.serving import (BlockAllocator, Engine, describe_trace,
                           length_stats, synthetic_trace, trace_context)
from repro.serving.executor import PagedJaxExecutor
from repro.serving.quality import token_agreement

cfg = get_config("mistral-nemo-12b").reduced()
settings = ModelSettings(attn=AttnSettings(backend="naive"))
SLOTS, KV_BLOCK, N_BLOCKS = 3, 4, 24

params = init_params(jax.random.PRNGKey(0), cfg)
trace = synthetic_trace(8, vocab_size=cfg.vocab_size, seed=1,
                        prompt_lens=(8, 16), gen_lens=(4, 12),
                        mean_interarrival=1.0, prefix_len=4)
context = trace_context(trace)
print("trace:", describe_trace(trace))

# (kv_quant, kv_retain): exact fp blocks, int8 codes, int8 + keep only the
# 2 hottest blocks per sequence (plus the write tail)
for kv_quant, kv_retain in (("none", 0), ("int8", 0), ("int8", 2)):
    executor = PagedJaxExecutor(params, cfg, n_lanes=SLOTS,
                                n_blocks=N_BLOCKS, kv_block=KV_BLOCK,
                                context=context, settings=settings,
                                compact=True, chunk=KV_BLOCK,
                                kv_quant=kv_quant, kv_retain=kv_retain)
    allocator = BlockAllocator(N_BLOCKS, KV_BLOCK, reservation="expected")
    engine = Engine(executor, SLOTS, allocator=allocator,
                    chunk_prefill=KV_BLOCK, prefix_share=True,
                    stats=length_stats(trace), kv_retain=kv_retain)
    t0 = time.time()
    report = engine.run(trace)
    wall = time.time() - t0
    agree = token_agreement(params, cfg, trace, report, context=context,
                            settings=settings)
    print(f"[{kv_quant:4s} retain={kv_retain}] " + report.describe()
          + f" wall={wall:.2f}s")
    print(f"  {agree.describe()}")

first = report.completions[0]
print(f"  req{first.rid} tokens: {list(first.tokens)}")
