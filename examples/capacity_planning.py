"""Capacity planning walkthrough — the paper's §III-E online phase, live:

 1. an "ad-hoc" workload arrives (mixtral-family training job),
 2. WSMC compiles a ladder of small shapes (seconds; zero data movement),
 3. classifies its memory-expansion behaviour (Tables I-II),
 4. predicts the capacity at the real target shape (Eqs. 6-11),
 5. picks the fastest knob setting that fits the HBM budget,
 6. VALIDATES the prediction against a real compile of the target.

    PYTHONPATH=src python examples/capacity_planning.py
(re-executes itself with 8 fake CPU devices for the mesh)
"""
import dataclasses
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

from repro import hw as HW
from repro.configs import get_config
from repro.configs.base import ShapeConfig, TRAIN
from repro.core import planner as PL
from repro.core import profiler as PF
from repro.core.classifier import classify_profiles
from repro.launch import compile as LC
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
cfg = get_config("mixtral-8x7b").reduced()
target = ShapeConfig("target", TRAIN, 512, 8)
# a miniature "HBM" so the planning problem is real at example scale:
hbm = dataclasses.replace(HW.TPU_V5E, hbm_bytes=96 * 2**20,
                          reserved_bytes=4 * 2**20)

print(f"== workload: {cfg.name} train seq={target.seq_len} "
      f"batch={target.global_batch} on mesh {dict(mesh.shape)}")

print("\n[1] profiling ladder (small shapes, compile-time only)...")
ladder = PF.profile_ladder(cfg, target, mesh, n_points=3, base_seq=64)
for p in ladder:
    print(f"    seq={p.seq_len:4d}  input/dev={p.input_bytes/2**10:7.1f} KiB"
          f"  transient/dev={p.transient_bytes/2**20:7.2f} MiB"
          f"  α(per-stage)={p.alpha:6.2f}")

print("\n[2] classification (paper Tables I-II):")
cls = classify_profiles(ladder)
print(f"    category={cls.category.value}  α={cls.alpha:.2f} "
      f"inc={cls.inc:.2f}  factor_shuf={cls.factor}")

print("\n[3] plan search (fastest knob setting whose prediction fits):")
# 'fitted' mode: ladder regression + safety margin (the beyond-paper
# predictor). 'paper' mode needs the offline-calibrated Table III factors
# (artifacts/kb.json) — shown for comparison.
dec = PL.wsmc_plan(cfg, target, cls, dict(mesh.shape), hw=hbm,
                   mode="fitted")
dec_paper = PL.wsmc_plan(cfg, target, cls, dict(mesh.shape), hw=hbm)
print(f"    plan: remat={dec.plan.remat} microbatches="
      f"{dec.plan.microbatches} optimizer={dec.plan.optimizer}")
print(f"    predicted capacity (fitted): "
      f"{dec.prediction.capacity_bytes/2**20:.1f} MiB of "
      f"{hbm.hbm_bytes/2**20:.0f} MiB budget "
      f"(considered {dec.considered} candidates, fits={dec.prediction.fits})")
print(f"    predicted capacity (paper factors, uncalibrated): "
      f"{dec_paper.prediction.capacity_bytes/2**20:.1f} MiB")

print("\n[4] validation: compile the REAL target with the planned config...")
bundle = LC.build(cfg, target, mesh,
                  strategy=PF.strategy_for(cfg, dec.plan, mesh),
                  tcfg=PF._tcfg_for(dec.plan))
ma = bundle.compile().memory_analysis()
peak = ma.argument_size_in_bytes + ma.output_size_in_bytes \
    + ma.temp_size_in_bytes
print(f"    measured static peak: {peak/2**20:.1f} MiB/device")
req = dec.prediction.resident_bytes + dec.prediction.transient_bytes
print(f"    fitted prediction / measured = {req / peak:.2f} "
      f"(the offline phase calibrates the paper factors to stay >= 1)")

print("\n[5] what the default (no-WSMC) policy would have done:")
dflt = PL.default_plan(cfg, target)
print(f"    default: remat={dflt.remat} microbatches={dflt.microbatches} "
      f"optimizer={dflt.optimizer} + a full-HBM capacity request")
frac = dec.prediction.capacity_bytes / hbm.hbm_bytes
print(f"    -> WSMC requests {frac:.0%} of HBM instead of 100%, at "
      f"{dec.plan.step_time_penalty()/dflt.step_time_penalty():.2f}x "
      f"the default's step-time penalty")
