"""Quickstart: train a tiny gemma3-family model for 30 steps on CPU, then
greedy-decode from it — the whole public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import ModelSettings, init_params
from repro.models.attention import AttnSettings
from repro.optim import optimizers as opt
from repro.runtime.serve_step import greedy_generate
from repro.runtime.train_step import TrainStepConfig, make_train_step

cfg = get_config("gemma3-12b").reduced()          # same family, tiny dims
settings = ModelSettings(attn=AttnSettings(backend="blocked",
                                           q_block=32, kv_block=32))
tcfg = TrainStepConfig(remat="dots", microbatches=2,
                       optimizer=opt.OptimizerConfig(lr=5e-3),
                       settings=settings, warmup_steps=3, total_steps=30)

params = init_params(jax.random.PRNGKey(0), cfg)
opt_state = opt.init_state(tcfg.optimizer, params)
step = jax.jit(make_train_step(cfg, tcfg))
pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                global_batch=8))

print(f"training {cfg.name}: "
      f"{sum(x.size for x in jax.tree.leaves(params)):,} params")
for s in range(30):
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
    params, opt_state, m = step(params, opt_state, batch, jnp.asarray(s))
    if s % 10 == 0 or s == 29:
        print(f"  step {s:3d}  loss {float(m['loss']):.4f}")

prompt = jnp.asarray(pipe.batch_at(99)["tokens"][:2, :16])
out = greedy_generate(params, cfg, prompt, n_steps=8, context=24,
                      settings=settings)
print("greedy continuation:", out[0].tolist())
