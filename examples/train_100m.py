"""End-to-end driver: train a ~100M-parameter danube-family model.

Default invocation is CPU-sized (16 steps to prove the loop); pass
--steps 300 for the full few-hundred-step run the deliverable describes
(hours on this container's single core; minutes on a real slice).

    PYTHONPATH=src python examples/train_100m.py [--steps N]

Everything (WSMC planning, checkpointing, watchdog, preemption guard) runs
through the production driver, repro.launch.train.
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    sys.exit(train_main([
        "--arch", "h2o-danube-1.8b", "--reduced-100m",
        "--seq", str(args.seq), "--batch", str(args.batch),
        "--steps", str(args.steps),
        "--ckpt-dir", "artifacts/ckpt_100m", "--ckpt-interval", "50",
        "--log-every", "5",
    ]))
