"""Pluggable search strategies over a ConfigSpace.

Every WSMC consumer (planner, hillclimb, serve, dry-run, benchmarks) walks
the same candidate lattice through one of these:

  fastest_first       — the paper's §III-E walk: predict per candidate
                        (closed form, Eqs. 6-11), take the fastest that
                        fits. Zero measurements.
  exhaustive_verified — the 'proper configuration' oracle: measure-verify
                        candidates fastest-first until one's measured peak
                        fits. O(lattice) backend calls.
  staged              — screen the WHOLE lattice with the compile-free
                        simulator, keep the top-k fitting candidates,
                        verify only those with the expensive backend —
                        oracle-quality search in O(k) compiles.
  greedy_coordinate   — hillclimbing absorbed from launch/hillclimb.py:
                        from a start point, move one knob at a time and
                        keep strict improvements of a caller-chosen score.

The measurement cost split (cheap screening predictor in front of expensive
validation) is the search framing of Crispy (arXiv:2206.13852) and Will et
al. (arXiv:2306.03672) applied to the paper's planner.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from repro import hw as HW
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import measure as MM
from repro.core import predictor as PR
from repro.core.classifier import Classification
from repro.search import space as SP
from repro.search.space import Candidate, ConfigSpace


@dataclasses.dataclass(frozen=True)
class SearchResult:
    candidate: Candidate
    policy: str
    considered: int                  # candidates examined (cheap screen)
    measured: int = 0                # expensive verify-backend invocations
    prediction: Optional[PR.CapacityPrediction] = None
    peak_bytes: Optional[float] = None     # verified peak, when measured

    @property
    def plan(self) -> PR.MemoryPlan:
        return self.candidate.plan

    @property
    def mesh_shape(self) -> Dict[str, int]:
        return self.candidate.mesh_shape

    def describe_outcome(self) -> str:
        """One-line cost summary for driver logs: predicted capacity,
        verified peak, or just the candidates examined."""
        if self.prediction is not None:
            return (f"capacity="
                    f"{self.prediction.capacity_bytes / 2**20:.0f} MiB")
        if self.peak_bytes is not None:
            return (f"verified_peak={self.peak_bytes / 2**20:.0f} MiB "
                    f"measured={self.measured}")
        return f"considered={self.considered}"


def plan_budget(hw: HW.HardwareSpec = HW.TPU_V5E) -> float:
    """Peak bytes/device a plan may measure at and still be configurable
    within HBM after the Eq. 11 headroom + runtime reserve."""
    return hw.hbm_bytes / HW.CAPACITY_HEADROOM - hw.reserved_bytes


def feasibility_score(scorer: "CandidateScorer", cfg: ModelConfig,
                      shape: ShapeConfig,
                      hw: HW.HardwareSpec = HW.TPU_V5E) -> Callable:
    """Score for greedy_coordinate: fitting candidates compete on speed
    (then peak); non-fitting ones descend on peak first, so the climb can
    escape an infeasible start one knob at a time."""
    budget = plan_budget(hw)

    def score(cand: Candidate):
        peak = scorer.peak(cfg, shape, cand)
        if peak <= budget:
            return (0, cand.step_time_penalty(), peak)
        return (1, peak, cand.step_time_penalty())

    return score


# ---------------------------------------------------------------------------
# Candidate scoring
# ---------------------------------------------------------------------------

def resolved_ep(cfg: Optional[ModelConfig], cand: Candidate,
                mesh_shape: Optional[Mapping[str, int]] = None) -> bool:
    """The EP mode this candidate will actually run with. ep=None means
    "keep the default_strategy choice" (sharding.default_strategy: EP when
    the expert count tiles the model axis), so scoring must resolve it the
    same way the launch drivers will."""
    ep = cand.extra("ep")
    if ep is not None:
        return bool(ep)
    if cfg is None or not cfg.is_moe:
        return False
    model = int((cand.mesh_shape or dict(mesh_shape or {})).get("model", 1))
    return model > 0 and cfg.n_experts % model == 0


def measure_key(cand: Candidate, cfg: Optional[ModelConfig] = None,
                mesh_shape: Optional[Mapping[str, int]] = None) -> Tuple:
    """What a measurement backend can actually distinguish about a
    candidate: the plan, the mesh, and the (resolved) EP mode. All other
    extras (moe_group, q_block, …) ride through the launch drivers, not the
    measurer — candidates differing only in those measure identically."""
    return (cand.plan, cand.mesh, resolved_ep(cfg, cand, mesh_shape))


class CandidateScorer:
    """Adapts a MemoryMeasurer (or a legacy `measure(plan)` callable) to
    score Candidates that may each carry their own mesh / extras.

    The simulate backend is cloned per distinct (mesh, ep) — microseconds
    each; the compile backend lazily builds a real jax Mesh per distinct
    mesh shape, which is only sensible for the handful of verify calls
    `staged` makes. Compile verification scores the plan knobs only (extras
    like moe_group ride through the launch drivers, not the measurer).

    Results are memoized per (workload, measure_key): re-scoring the same
    measurer-visible configuration (greedy revisits, extras-only twins) is
    free and does not count as a backend call."""

    def __init__(self, measurer: Optional[MM.MemoryMeasurer] = None,
                 measure: Optional[Callable[[PR.MemoryPlan], float]] = None):
        if measurer is None and measure is None:
            raise TypeError("CandidateScorer needs `measurer` or `measure`")
        self.measurer = measurer
        self.measure_fn = measure
        self.calls = 0
        self._clones: Dict[Tuple, MM.MemoryMeasurer] = {}
        self._memo: Dict[Tuple, float] = {}

    def peak(self, cfg: ModelConfig, shape: ShapeConfig,
             cand: Candidate) -> float:
        base_mesh = None if self.measurer is None else self.measurer.mesh_shape
        ep = resolved_ep(cfg, cand, base_mesh)
        if self.measurer is not None and self.measurer.backend != "simulate":
            ep = False       # the compile backend scores plan + mesh only
        key = (cfg.name, cfg.n_layers, cfg.d_model, shape.kind,
               shape.seq_len, shape.global_batch, cand.plan, cand.mesh, ep)
        if key in self._memo:
            return self._memo[key]
        self.calls += 1
        if self.measure_fn is not None:
            peak = self.measure_fn(cand.plan)
        else:
            peak = self._measurer_for(cand, ep).measure_peak(cfg, shape,
                                                             cand.plan)
        self._memo[key] = peak
        return peak

    def _measurer_for(self, cand: Candidate, ep: bool) -> MM.MemoryMeasurer:
        base = self.measurer
        want = cand.mesh_shape or base.mesh_shape
        if want == base.mesh_shape and not ep:
            return base
        key = (tuple(sorted(want.items())), ep)
        if key not in self._clones:
            if base.backend == "simulate":
                self._clones[key] = MM.SimulatedMeasurer(
                    want, cache=base.cache, ep=ep)
            else:
                from repro.launch.mesh import make_mesh
                axes, sizes = zip(*sorted(want.items()))
                self._clones[key] = MM.CompileMeasurer(
                    make_mesh(sizes, axes), cache=base.cache)
        return self._clones[key]


def _as_scorer(measurer=None, measure=None) -> CandidateScorer:
    if isinstance(measurer, CandidateScorer):
        return measurer
    return CandidateScorer(measurer=measurer, measure=measure)


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------

def _dp_filtered(shape: ShapeConfig,
                 cands: List[Candidate]) -> List[Candidate]:
    """The §III-E walk's divisibility screen, with the planner's historical
    fallback: if nothing divides, keep the slowest/safest candidate."""
    kept = [c for c in cands if SP.DP_DIVIDES_BATCH.check(None, shape, c)]
    return kept or cands[-1:]


def _measure_distinct(cands: List[Candidate],
                      cfg: Optional[ModelConfig] = None,
                      mesh_shape: Optional[Mapping[str, int]] = None
                      ) -> List[Candidate]:
    """Drop candidates a measurement backend cannot tell apart (same plan,
    mesh, resolved EP — only ordering-neutral extras differ), keeping
    first-seen fastest-first order. Without this, spaces with many extras
    (hillclimb) would spend their verify budget k times on the same
    configuration."""
    seen = set()
    out = []
    for c in cands:
        key = measure_key(c, cfg, mesh_shape)
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


def fastest_first(space: ConfigSpace, cfg: ModelConfig, shape: ShapeConfig,
                  cls: Classification, *, mode: str = "paper",
                  hw: HW.HardwareSpec = HW.TPU_V5E,
                  factors: Optional[dict] = None) -> SearchResult:
    """Paper §III-E: predict per candidate, take the fastest that fits.
    `factors` is the offline-calibrated Table III
    (profiler.calibrated_factors). Zero measurement-backend calls."""
    if cls is None:
        raise ValueError("fastest_first needs a workload Classification")
    cands = space.candidates(cfg, shape)
    if not cands:
        raise ValueError(f"{space.name}: no valid candidates")
    cands = _dp_filtered(shape, cands)
    for i, cand in enumerate(cands):
        pred = PR.predict(cfg, shape, cand.plan, cls, cand.mesh_shape, mode,
                          hw, factors)
        if pred.fits:
            return SearchResult(cand, "wsmc", i + 1, prediction=pred)
    # nothing fits: return the safest with its (over-budget) prediction
    cand = cands[-1]
    pred = PR.predict(cfg, shape, cand.plan, cls, cand.mesh_shape, mode, hw,
                      factors)
    return SearchResult(cand, "wsmc_overflow", len(cands), prediction=pred)


def exhaustive_verified(space: ConfigSpace, cfg: ModelConfig,
                        shape: ShapeConfig, *,
                        measurer: Optional[MM.MemoryMeasurer] = None,
                        measure: Optional[Callable] = None,
                        hw: HW.HardwareSpec = HW.TPU_V5E,
                        max_candidates: Optional[int] = None) -> SearchResult:
    """The 'proper configuration' oracle: measure-verify candidates
    fastest-first until one's measured peak fits. Under the compile backend
    each call is a real compile (exactly the cost WSMC avoids); under the
    simulator the whole search is compile-free."""
    scorer = _as_scorer(measurer, measure)
    base_mesh = None if scorer.measurer is None else scorer.measurer.mesh_shape
    cands = _measure_distinct(space.candidates(cfg, shape), cfg, base_mesh)
    if not cands:
        raise ValueError(f"{space.name}: no valid candidates")
    if max_candidates:
        cands = cands[:max_candidates]
    budget = plan_budget(hw)
    best: Optional[Tuple[Candidate, float]] = None
    for i, cand in enumerate(cands):
        peak = scorer.peak(cfg, shape, cand)
        if peak <= budget:
            return SearchResult(cand, "oracle", i + 1, measured=scorer.calls,
                                peak_bytes=peak)
        if best is None or peak < best[1]:
            best = (cand, peak)
    return SearchResult(best[0], "oracle_overflow", len(cands),
                        measured=scorer.calls, peak_bytes=best[1])


def staged(space: ConfigSpace, cfg: ModelConfig, shape: ShapeConfig, *,
           screener, verifier, k: int = 5,
           hw: HW.HardwareSpec = HW.TPU_V5E) -> SearchResult:
    """Screen the full lattice with the cheap backend (simulator: zero
    compiles), keep the top-k fastest candidates the screen says fit, and
    verify only those with the expensive backend — turning oracle-quality
    search from O(lattice) compiles into O(k)."""
    screen = _as_scorer(screener)
    verify = _as_scorer(verifier)
    all_cands = space.candidates(cfg, shape)
    if not all_cands:
        raise ValueError(f"{space.name}: no valid candidates")
    base_mesh = None if screen.measurer is None else screen.measurer.mesh_shape
    cands = _measure_distinct(all_cands, cfg, base_mesh)
    budget = plan_budget(hw)
    scored = [(screen.peak(cfg, shape, c), c) for c in cands]
    fitting = [c for peak, c in scored if peak <= budget]
    if fitting:
        shortlist = fitting[:k]
    else:        # screen says nothing fits: verify the k least-bad points
        shortlist = [c for _, c in
                     sorted(scored, key=lambda pc: pc[0])[:k]]
    best: Optional[Tuple[Candidate, float]] = None
    for cand in shortlist:
        peak = verify.peak(cfg, shape, cand)
        if peak <= budget:
            return SearchResult(cand, "staged", len(all_cands),
                                measured=verify.calls, peak_bytes=peak)
        if best is None or peak < best[1]:
            best = (cand, peak)
    return SearchResult(best[0], "staged_overflow", len(all_cands),
                        measured=verify.calls, peak_bytes=best[1])


def greedy_coordinate(space: ConfigSpace, cfg: ModelConfig,
                      shape: ShapeConfig, *,
                      score: Callable[[Candidate], object],
                      start: Optional[Candidate] = None,
                      max_rounds: int = 3,
                      scorer: Optional[CandidateScorer] = None
                      ) -> SearchResult:
    """Hillclimbing over the knob axes: from `start` (the space's baseline
    point by default), try every alternative value of every knob, keep a
    move iff it strictly improves `score` (any comparable; lower is better),
    and repeat until a full round makes no move. Pass the `scorer` backing
    `score` so the result reports how many backend measurements the climb
    actually spent."""
    cur = start if start is not None else space.point(cfg)
    best_s = score(cur)
    considered = 1
    for _ in range(max_rounds):
        moved = False
        for knob in space.knobs:
            current_v = space.value_of(cur, knob.name)
            for v in knob.values:
                if v == current_v:
                    continue
                cand = space.point(cfg, base=cur, **{knob.name: v})
                if not space.is_valid(cfg, shape, cand):
                    continue
                s = score(cand)
                considered += 1
                if s < best_s:
                    cur, best_s, current_v, moved = cand, s, v, True
        if not moved:
            break
    return SearchResult(cur, "greedy", considered,
                        measured=scorer.calls if scorer else 0)


# ---------------------------------------------------------------------------
# Registry + one-call façade
# ---------------------------------------------------------------------------

STRATEGIES = {
    "fastest_first": fastest_first,
    "exhaustive_verified": exhaustive_verified,
    "staged": staged,
    "greedy_coordinate": greedy_coordinate,
}

_ALIASES = {
    "fastest": "fastest_first", "wsmc": "fastest_first",
    "exhaustive": "exhaustive_verified", "oracle": "exhaustive_verified",
    "greedy": "greedy_coordinate",
}

# The short names every --strategy CLI flag offers.
CLI_STRATEGIES = ("fastest", "staged", "exhaustive", "greedy")


def get_strategy(name: str):
    canonical = _ALIASES.get(name, name)
    if canonical not in STRATEGIES:
        raise KeyError(f"unknown search strategy {name!r}; "
                       f"known: {sorted(STRATEGIES) + sorted(_ALIASES)}")
    return STRATEGIES[canonical]


def plan_for(cfg: ModelConfig, shape: ShapeConfig,
             cls: Optional[Classification],
             mesh_shape: Mapping[str, int], *, strategy: str = "fastest",
             measurer: Optional[MM.MemoryMeasurer] = None,
             cache: Optional[MM.ProfileCache] = None, k: int = 5,
             mode: str = "paper", hw: HW.HardwareSpec = HW.TPU_V5E,
             factors: Optional[dict] = None,
             space: Optional[ConfigSpace] = None) -> SearchResult:
    """One-call façade for the entry points (serve / dryrun / benchmarks):
    build the paper space over the given fixed mesh (or walk a caller-built
    `space`, e.g. a mesh_space for `--mesh auto`) and run the named
    strategy. `measurer` is the verify backend for the measured strategies
    (defaults to the free simulator); `staged` always screens with the
    simulator regardless."""
    fn = get_strategy(strategy)
    if space is None:
        space = SP.paper_space(cfg, shape, mesh_shape)
    if fn is fastest_first:
        return fastest_first(space, cfg, shape, cls, mode=mode, hw=hw,
                             factors=factors)
    if measurer is None:
        measurer = MM.SimulatedMeasurer(dict(mesh_shape), cache=cache)
    if fn is exhaustive_verified:
        return exhaustive_verified(space, cfg, shape, measurer=measurer,
                                   hw=hw)
    if fn is staged:
        screener = MM.SimulatedMeasurer(dict(mesh_shape),
                                        cache=measurer.cache or cache)
        return staged(space, cfg, shape, screener=screener,
                      verifier=measurer, k=k, hw=hw)
    # greedy: fitting candidates compete on speed, unfitting descend on peak
    scorer = _as_scorer(measurer)
    return greedy_coordinate(space, cfg, shape, scorer=scorer,
                             score=feasibility_score(scorer, cfg, shape, hw))
