"""Execution plans: the bridge from a SearchResult to something you RUN.

The paper's point (§III-E) is that the *predicted* memory requirement drives
the *actual* deployment configuration — Crispy (Will et al., 2022) showed
memory models only pay off when they emit runnable allocations. Before this
module the search subsystem could plan a pipe-axis mesh no driver could
execute; an `ExecutionPlan` closes that loop:

  plan       — the WSMC memory plan (remat x microbatches x optimizer x kv)
  mesh_axes  — the planned mesh as {axis: size}, a search OUTPUT
  ep         — the resolved expert-parallel mode (strategy-level knob)
  schedule   — the runtime schedule kind (single | scan | pipeline_1f1b)

`build(devices)` turns it into a live (jax Mesh, sharding.Strategy) pair via
launch.mesh.build_mesh; `plan_execution` is the one-call `--mesh auto`
entry: search a runnable mesh_space, promote the winner.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro import hw as HW
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import measure as MM
from repro.core.classifier import Classification
from repro.core.predictor import MemoryPlan
from repro.runtime.schedule_kinds import (SCHEDULE_PIPELINE, SCHEDULE_SCAN,  # noqa: F401 — re-exported schedule vocabulary
                                          SCHEDULE_SINGLE, SCHEDULES,
                                          schedule_kind)
from repro.search import space as SP
from repro.search import strategies as ST
from repro.search.strategies import SearchResult


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A runnable deployment configuration: memory plan + planned mesh +
    sharding mode + runtime schedule. The thing `--mesh auto` executes."""
    plan: MemoryPlan = MemoryPlan()
    mesh_axes: Tuple[Tuple[str, int], ...] = (("data", 1),)
    ep: bool = False
    schedule: str = SCHEDULE_SINGLE
    policy: str = ""                 # which search policy emitted it

    @property
    def mesh_shape(self) -> Dict[str, int]:
        return dict(self.mesh_axes)

    @property
    def n_devices(self) -> int:
        n = 1
        for _, size in self.mesh_axes:
            n *= int(size)
        return n

    @property
    def pipe(self) -> int:
        return int(self.mesh_shape.get("pipe", 1))

    def describe(self) -> str:
        p = self.plan
        mesh = "x".join(f"{a}:{n}" for a, n in self.mesh_axes)
        return (f"mesh={mesh} schedule={self.schedule} remat={p.remat} "
                f"micro={p.microbatches} opt={p.optimizer} kv={p.kv_shard} "
                f"ep={self.ep}"
                + (f" policy={self.policy}" if self.policy else ""))

    def strategy(self):
        """The matching sharding.Strategy (lazy import: keep this module
        usable without touching jax device state)."""
        from repro.parallel import sharding as S
        return S.Strategy(kv_shard=self.plan.kv_shard, ep=self.ep,
                          pipeline=self.pipe > 1)

    def build(self, devices: Optional[Sequence] = None):
        """Construct the planned mesh (over the first n_devices of
        `devices`) and the matching sharding Strategy. Returns
        (mesh, strategy)."""
        from repro.launch.mesh import build_mesh
        return build_mesh(self.mesh_shape, devices), self.strategy()


def from_search_result(cfg: ModelConfig, shape: ShapeConfig,
                       res: SearchResult,
                       mesh_shape: Optional[Mapping[str, int]] = None
                       ) -> ExecutionPlan:
    """Promote a SearchResult to an ExecutionPlan. `mesh_shape` is the
    fallback for results from fixed-mesh spaces whose candidates carry no
    mesh of their own."""
    ms = dict(res.mesh_shape or mesh_shape or {"data": 1})
    ep = ST.resolved_ep(cfg, res.candidate, ms)
    sched = schedule_kind(shape.kind, res.plan.microbatches,
                          int(ms.get("pipe", 1)))
    return ExecutionPlan(plan=res.plan,
                         mesh_axes=tuple(sorted(ms.items())),
                         ep=ep, schedule=sched, policy=res.policy)


def for_mesh(cfg: ModelConfig, shape: ShapeConfig, plan: MemoryPlan,
             mesh_shape: Mapping[str, int],
             policy: str = "") -> ExecutionPlan:
    """Promote a plan onto a FIXED mesh (forced CLI spec, legacy host
    mesh): EP by the default divisibility rule, schedule from the plan +
    pipe axis. The single promotion path the drivers share."""
    ms = dict(mesh_shape)
    ep = ST.resolved_ep(cfg, SP.Candidate(plan=plan), ms)
    sched = schedule_kind(shape.kind, plan.microbatches,
                          int(ms.get("pipe", 1)))
    return ExecutionPlan(plan=plan, mesh_axes=tuple(sorted(ms.items())),
                         ep=ep, schedule=sched, policy=policy)


def host_execution(cfg: ModelConfig, shape: ShapeConfig, plan: MemoryPlan,
                   n_devices: int, model_parallel: int = 1,
                   policy: str = "host") -> ExecutionPlan:
    """The legacy (data, model) host mesh as an ExecutionPlan (what
    `host_mesh_for` used to build): best-effort model axis over the
    surviving device count."""
    model = max(1, model_parallel)
    while n_devices % model:
        model -= 1
    return for_mesh(cfg, shape, plan,
                    {"data": n_devices // model, "model": model},
                    policy=policy)


def _axis_values(n_devices: int, cap: Optional[int] = None) -> Tuple[int, ...]:
    """1, 2, 4, ... up to n_devices (plus n_devices itself for non-powers)."""
    limit = min(n_devices, cap) if cap else n_devices
    vals = []
    v = 1
    while v <= limit:
        vals.append(v)
        v *= 2
    if limit not in vals:
        vals.append(limit)
    return tuple(vals)


def auto_mesh_space(cfg: ModelConfig, shape: ShapeConfig,
                    n_devices: int) -> SP.ConfigSpace:
    """The `--mesh auto` search space: every mesh axis searchable within the
    host's device budget, pipe candidates restricted to what the 1F1B
    runtime executes (executable=True)."""
    return SP.mesh_space(cfg, shape, max_devices=n_devices,
                         data=_axis_values(n_devices),
                         model=_axis_values(n_devices),
                         pipe=_axis_values(n_devices, cap=4),
                         executable=True)


def auto_plan(cfg: ModelConfig, shape: ShapeConfig, *, n_devices: int,
              strategy: str = "fastest", base_seq: int = 64,
              n_points: int = 2, factors: Optional[dict] = None,
              cache: Optional[MM.ProfileCache] = None,
              measurer: Optional[MM.MemoryMeasurer] = None):
    """The `--mesh auto` preamble shared by the train and serve drivers:
    classify the workload and plan a runnable execution. Returns
    (Classification, ExecutionPlan).

    `measurer` is the measurement backend for BOTH the classification
    ladder and the measured strategies — the drivers thread their
    `--backend` choice through here, so `--mesh auto --backend compile`
    classifies and verifies with real compiles instead of silently falling
    back to the simulator. Default (None) stays the compile-free simulator
    over the host's data axis."""
    from repro.core import profiler as PF
    if measurer is None:
        measurer = MM.SimulatedMeasurer({"data": n_devices}, cache=cache)
    cls = PF.classify_workload(cfg, shape, None, n_points=n_points,
                               base_seq=base_seq, measurer=measurer)
    eplan = plan_execution(cfg, shape, cls, n_devices=n_devices,
                           strategy=strategy, measurer=measurer, cache=cache,
                           factors=factors)
    return cls, eplan


# ---------------------------------------------------------------------------
# Serving: plan for maximum admitted concurrency under an HBM budget
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """A deployment configuration for the serving engine: the runnable
    ExecutionPlan plus the WSMC-predicted admission bound. `capacity` is
    the GLOBAL number of concurrent sequences the memory model says fit
    the per-device budget — the engine sizes its KV pool from it and
    queues everything beyond. Under paged KV (`kv_block > 0`) the budget
    governs a BLOCK pool instead of whole-sequence slots: `blocks` is the
    global block count `predictor.serving_block_capacity` admits at
    `capacity` decode lanes, and capacity itself is the EXPECTED
    concurrency under the trace's length distribution (short requests stop
    paying max-context bytes)."""
    execution: ExecutionPlan
    capacity: int
    hbm_budget: float
    considered: int = 0              # serving candidates scored
    kv_block: int = 0                # positions per KV block (0 = ring slots)
    blocks: int = 0                  # global paged-pool block budget
    admission: str = "optimistic"    # reservation discipline the capacity
                                     # inversion assumed (worst | optimistic)
    agreement: float = 1.0           # PRIOR token-agreement estimate of the
                                     # picked bend (1.0 = exact; measured
                                     # agreement comes from serving.quality)
    prefill_budget: int = 0          # prompt tokens per tick the inversion
                                     # charged the prefill transient for
                                     # (0 = prefill not modeled)
    prefill_kernel: str = "dense"    # prefill cost model the inversion
                                     # assumed: dense SDPA score matrix vs
                                     # tiled flash kernel (O(chunk·d))

    def slots(self, cap: Optional[int] = None) -> int:
        """Engine slot-pool size (ring) / decode-lane count (paged): the
        predicted capacity, optionally capped (CLI --max-slots, trace
        size)."""
        return self.capacity if cap is None else min(self.capacity, int(cap))

    def pool_blocks(self, lanes: int, context: int) -> int:
        """Physical block-pool size for an engine running `lanes` decode
        lanes over ring extent `context`: the planned block budget, capped
        at what the lanes can ever hold (a --max-slots-capped engine
        shouldn't allocate the full planned pool)."""
        if not self.kv_block:
            return 0
        per_seq = -(-int(context) // self.kv_block)
        return max(min(self.blocks, int(lanes) * per_seq), 1)

    def describe(self) -> str:
        paged = (f" kv_block={self.kv_block} blocks={self.blocks}"
                 if self.kv_block else "")
        if self.admission != "optimistic":
            paged += f" admission={self.admission}"
        if self.prefill_budget:
            paged += (f" prefill_budget={self.prefill_budget}"
                      f" prefill_kernel={self.prefill_kernel}")
        p = self.execution.plan
        if p.kv_quant != "none":
            paged += f" kv_quant={p.kv_quant}"
        if p.kv_retain:
            paged += f" kv_retain={p.kv_retain}"
        if self.agreement < 1.0:
            paged += f" agreement>={self.agreement:.3f}"
        return (f"{self.execution.describe()} capacity={self.capacity}"
                f"{paged} (budget={self.hbm_budget / 2**30:.1f} GiB, "
                f"considered={self.considered})")


DEFAULT_KV_BLOCKS = (8, 16, 32, 64, 128)

# Prior token-agreement estimates for the capacity-bending knobs: what the
# search GATE assumes before anything is measured. int8 per-row absmax is
# near-lossless on KV (error <= scale/2 per element); int4 and block
# dropping are real bends. The benchmark's quality harness
# (serving.quality.token_agreement) replaces these priors with measurement.
QUANT_AGREEMENT = {"none": 1.0, "int8": 0.995, "int4": 0.97}
RETAIN_AGREEMENT = 0.95


def predicted_agreement(plan: MemoryPlan, max_seq_blocks: int) -> float:
    """Prior token-agreement of a bent candidate vs exact greedy decode.
    Retention only costs quality when it would actually drop blocks —
    a reach cap wider than the longest sequence never fires."""
    a = QUANT_AGREEMENT[plan.kv_quant]
    if plan.kv_retain and plan.kv_retain + 1 < max_seq_blocks:
        a *= RETAIN_AGREEMENT
    return a


def ladder_priors(plan: "ServingPlan", max_seq_blocks: int,
                  min_agreement: float = 0.0) -> dict:
    """The degradation ladder's quality gate, priced by the SAME priors
    the serving search enforces: the agreement a `bend_retain` of
    `max_seq_blocks // 2` blocks would cost ON TOP of the plan's already-
    gated bend, and whether that clears `min_agreement`. The engine's
    rung-2 kv_bend only engages inside this gate (`LadderConfig(
    bend_retain=..., bend_agreement=..., min_agreement=...)`), so
    pressure never trades quality the planner wouldn't have."""
    base = plan.agreement
    retain = max(max_seq_blocks // 2, 1)
    bend = base * (RETAIN_AGREEMENT
                   if retain + 1 < max_seq_blocks else 1.0)
    return {"bend_retain": retain,
            "bend_agreement": bend,
            "min_agreement": float(min_agreement),
            "bend_allowed": bend >= float(min_agreement)}


def _expected_blocks(seq_lens: Sequence[int], block: int) -> float:
    """Mean paged-block demand per sequence under the trace's length
    distribution: `seq_lens` holds each request's written positions
    (prompt + generated - 1)."""
    lens = [max(int(s), 1) for s in seq_lens] or [1]
    return sum(-(-s // block) for s in lens) / len(lens)


def _bucket_cover(n: int, cap: int) -> int:
    """Smallest power-of-two >= n, capped at `cap` (the executor's lane
    bucket the compacting engine would actually run at)."""
    w = 1
    while w < min(n, cap):
        w *= 2
    return min(w, cap)


def _paged_concurrency(cfg, shape, cand, cls, budget, mode, hw, factors,
                       seq_lens, max_lanes: int = 1 << 14,
                       compact: bool = False, admission: str = "optimistic",
                       sigma_k: float = 0.0, prefill_tokens: int = 0,
                       prefill_kernel: str = "dense", chunk: int = 0):
    """Expected admitted concurrency for one paged serving candidate: the
    largest per-device lane count whose block pool still covers the
    EXPECTED per-sequence demand (blocks(lanes) >= lanes * E[blocks/seq]).
    blocks() falls as lanes rise (lane-fixed state eats the budget) while
    demand rises, so the balance point is an exact monotone search.
    With `compact`, the decode transient is charged at the bucketed
    EXPECTED active width (lanes scaled by the trace's mean/max length
    ratio — the same expected-case admission stance as avg_context)
    instead of the full lane width.

    `admission="worst"` sizes for a `reservation="worst"` engine: every
    lane is charged `max_seq_blocks` (no lane can be refused its full
    reservation) and the transient is charged at full context and width —
    deadlock-free by construction, the pre-PR-7 stance. The default
    "optimistic" covers expected demand plus `sigma_k` pooled standard
    deviations (per-sequence block std scaled by sqrt(lanes) — independent
    lengths concentrate), trusting the engine's eviction path on a miss.
    sigma_k=0 is the bare-expected sizing every pre-existing call pinned.

    `prefill_tokens` > 0 also charges each tick's prefill transient (a
    chunked engine under that token budget, spread over ceil(tokens /
    chunk) lanes) at the `prefill_kernel` cost model — the decode-or-
    prefill max governs the headroom (predictor.prefill_transient_bytes).
    Returns (global_concurrency, global_blocks)."""
    from repro.core import predictor as PR
    _, dp, _ = PR.mesh_factors(cand.mesh_shape)
    pwidth = (-(-int(prefill_tokens) // int(chunk))
              if prefill_tokens and chunk else 1)
    block = cand.plan.kv_block_size
    lens = [max(int(s), 1) for s in seq_lens] or [1]
    avg_context = -(-sum(lens) // len(lens))
    # the pool must also hold the LONGEST request outright, or the engine
    # could never admit it (expected demand alone would undersize the pool
    # on a short-heavy trace with a long tail). Retention does NOT lower
    # this floor: whole-prompt prefill lands every prompt block before the
    # first drop.
    max_seq_blocks = max(-(-s // block) for s in lens)
    e_frac = (sum(lens) / len(lens)) / max(lens)     # mean/max in (0, 1]
    nb = [-(-s // block) for s in lens]
    worst = admission == "worst"
    retain = cand.plan.kv_retain
    if retain and not worst:
        # block retention caps each lane's steady-state live blocks at
        # retain+1 (the engine drops the coldest past that). Worst-mode
        # engines still reserve the uncapped footprint (deadlock-free by
        # construction), so the cap only bends optimistic sizing.
        nb = [min(b, retain + 1) for b in nb]
    e_blocks = sum(nb) / len(nb)
    std_blocks = (sum((b - e_blocks) ** 2 for b in nb) / len(nb)) ** 0.5
    _blocks_memo: dict = {}

    def blocks_at(lanes: int) -> int:
        if lanes not in _blocks_memo:
            width = (_bucket_cover(max(1, int(-(-(lanes * e_frac) // 1))),
                                   lanes) if compact and not worst else None)
            _blocks_memo[lanes] = PR.serving_block_capacity(
                cfg, shape, cand.plan, cls, cand.mesh_shape, lanes=lanes,
                mode=mode, hw=hw, hbm_budget=budget, factors=factors,
                avg_context=avg_context, decode_width=width,
                admission=admission, prefill_tokens=int(prefill_tokens),
                prefill_kernel=prefill_kernel,
                prefill_width=pwidth) // dp
        return _blocks_memo[lanes]

    def feasible(lanes: int) -> bool:
        if worst:
            return blocks_at(lanes) >= lanes * max_seq_blocks
        demand = lanes * e_blocks + sigma_k * std_blocks * lanes ** 0.5
        return blocks_at(lanes) >= max(demand, max_seq_blocks)

    if not feasible(1):
        return 0, 0
    lo, hi = 1, 2
    while hi < max_lanes and feasible(hi):
        lo, hi = hi, hi * 2
    if hi >= max_lanes and feasible(max_lanes):
        lo = max_lanes
    else:
        while hi - lo > 1:          # invariant: feasible(lo), not feasible(hi)
            mid = (lo + hi) // 2
            lo, hi = (mid, hi) if feasible(mid) else (lo, mid)
    return lo * dp, blocks_at(lo) * dp


def plan_serving(cfg: ModelConfig, shape: ShapeConfig, *, n_devices: int,
                 hbm_budget: Optional[float] = None,
                 cls: Optional[Classification] = None,
                 measurer: Optional[MM.MemoryMeasurer] = None,
                 cache: Optional[MM.ProfileCache] = None,
                 base_seq: int = 64, n_points: int = 2, mode: str = "paper",
                 factors: Optional[dict] = None,
                 hw: HW.HardwareSpec = HW.TPU_V5E,
                 space: Optional[SP.ConfigSpace] = None,
                 kv: str = "ring",
                 kv_blocks: Sequence[int] = DEFAULT_KV_BLOCKS,
                 seq_lens: Optional[Sequence[int]] = None,
                 compact: bool = False, admission: str = "optimistic",
                 sigma_k: float = 0.0,
                 kv_quants: Sequence[str] = ("none",),
                 kv_retains: Sequence[int] = (0,),
                 min_agreement: float = 0.0,
                 prefill_budget: int = 0,
                 prefill_budgets: Sequence[int] = (),
                 prefill_kernel: str = "dense", chunk: int = 0):
    """The serving-engine planning entry: walk the serving lattice
    (kv_shard x kv_block_size x data x model, pipe pinned —
    space.serving_space) and pick the candidate that maximizes admitted
    concurrency under the per-device HBM budget, tie-broken fastest-first.
    This is the paper's configuration loop run in reverse: instead of
    sizing memory to a fixed workload, it sizes the admissible workload to
    a fixed memory budget.

    `kv="ring"` scores candidates by `predictor.serving_capacity`
    (worst-case whole-sequence slots). `kv="paged"` makes `kv_block_size`
    a searched knob and maximizes EXPECTED admitted concurrency under the
    trace's length distribution (`seq_lens`: written positions per
    request; defaults to worst-case `shape.context`), via
    `predictor.serving_block_capacity` — admit by actual footprint, not
    worst case. `compact` (paged only) charges the decode transient at the
    compacting engine's bucketed expected width instead of the full lane
    width. `admission` picks the reservation discipline the inversion
    assumes — "optimistic" (default; expected demand + `sigma_k` pooled
    sigmas, pairing with an eviction-capable engine) or "worst" (every
    lane charged the longest request, the deadlock-free sizing); a
    candidate's own `admission` extra (when `serving_space` searches it)
    overrides the call-level value per candidate.

    `kv_quants` / `kv_retains` (paged only) widen the lattice with the
    capacity-bending knobs; `min_agreement` is the quality floor on the
    bend — candidates whose `predicted_agreement` prior falls below it are
    dropped before scoring, so the planner walks the quality/capacity
    frontier instead of always taking the cheapest bytes. Exact candidates
    (kv_quant="none", kv_retain=0) always pass the gate.

    `prefill_budget` > 0 (paged only; needs `chunk`, the engine's
    chunk_prefill) makes the prefill transient a scored term: each tick
    is charged max(decode, prefill-at-budget) headroom under the
    `prefill_kernel` cost model ("dense" SDPA vs "tiled" flash-prefill).
    `prefill_budgets` makes the budget a searched knob (candidate extras
    override the call-level value, like `admission`). Returns
    (Classification, ServingPlan)."""
    from repro.core import predictor as PR   # lazy, like profiler below
    from repro.core import profiler as PF
    if kv not in ("ring", "paged"):
        raise ValueError(f"plan_serving: unknown kv mode {kv!r}")
    if admission not in ("optimistic", "worst"):
        raise ValueError(f"plan_serving: unknown admission {admission!r}")
    if prefill_kernel not in PR.PREFILL_KERNELS:
        raise ValueError(f"plan_serving: unknown prefill_kernel "
                         f"{prefill_kernel!r}; known: {PR.PREFILL_KERNELS}")
    if (prefill_budget or prefill_budgets) and not chunk:
        raise ValueError("plan_serving: prefill_budget needs chunk > 0 "
                         "(the budget schedules chunk_prefill-sized "
                         "pieces; whole-prompt prefill is all-or-nothing)")
    if measurer is None:
        measurer = MM.SimulatedMeasurer({"data": n_devices}, cache=cache)
    if cls is None:
        cls = PF.classify_workload(cfg, shape, None, n_points=n_points,
                                   base_seq=base_seq, measurer=measurer)
    budget = hw.hbm_bytes if hbm_budget is None else float(hbm_budget)
    if space is None:
        space = SP.serving_space(
            cfg, shape, max_devices=n_devices,
            data=_axis_values(n_devices), model=_axis_values(n_devices),
            kv_blocks=tuple(kv_blocks) if kv == "paged" else (0,),
            kv_quants=tuple(kv_quants) if kv == "paged" else ("none",),
            kv_retains=tuple(kv_retains) if kv == "paged" else (0,),
            prefill_budgets=(tuple(prefill_budgets)
                             if kv == "paged" else ()))
    if kv == "paged" and seq_lens is None:
        seq_lens = (shape.context,)
    cands = space.candidates(cfg, shape)
    if kv == "paged":
        cands = [c for c in cands if c.plan.kv_block_size > 0]
    if not cands:
        raise ValueError(f"{space.name}: no valid serving candidates")
    if min_agreement > 0 and kv == "paged":
        lens = [max(int(s), 1) for s in seq_lens]
        kept = []
        for c in cands:
            msb = max(-(-s // c.plan.kv_block_size) for s in lens)
            if predicted_agreement(c.plan, msb) >= min_agreement:
                kept.append(c)
        cands = kept
        if not cands:
            raise ValueError(f"{space.name}: no serving candidate meets "
                             f"min_agreement={min_agreement}")
    best, best_cap, best_blocks = None, -1, 0
    best_adm, best_pb = admission, int(prefill_budget)
    for cand in cands:                       # fastest-first => ties keep speed
        adm = cand.extra("admission", admission)
        pb = int(cand.extra("prefill_budget", prefill_budget) or 0)
        if kv == "paged":
            cap, blocks = _paged_concurrency(cfg, shape, cand, cls, budget,
                                             mode, hw, factors, seq_lens,
                                             compact=compact, admission=adm,
                                             sigma_k=sigma_k,
                                             prefill_tokens=pb,
                                             prefill_kernel=prefill_kernel,
                                             chunk=chunk)
        else:
            cap = PR.serving_capacity(cfg, shape, cand.plan, cls,
                                      cand.mesh_shape, mode=mode, hw=hw,
                                      hbm_budget=budget, factors=factors)
            blocks = 0
        if cap > best_cap:
            best, best_cap, best_blocks = cand, cap, blocks
            best_adm, best_pb = adm, pb
    eplan = for_mesh(cfg, shape, best.plan, best.mesh_shape,
                     policy="max_concurrency")
    agree = 1.0
    if kv == "paged":
        lens = [max(int(s), 1) for s in seq_lens]
        msb = max(-(-s // best.plan.kv_block_size) for s in lens)
        agree = predicted_agreement(best.plan, msb)
    return cls, ServingPlan(execution=eplan, capacity=best_cap,
                            hbm_budget=budget, considered=len(cands),
                            kv_block=best.plan.kv_block_size,
                            blocks=best_blocks, admission=best_adm,
                            agreement=agree,
                            prefill_budget=best_pb if kv == "paged" else 0,
                            prefill_kernel=prefill_kernel)


def plan_execution(cfg: ModelConfig, shape: ShapeConfig,
                   cls: Optional[Classification], *, n_devices: int,
                   strategy: str = "fastest",
                   measurer: Optional[MM.MemoryMeasurer] = None,
                   cache: Optional[MM.ProfileCache] = None,
                   factors: Optional[dict] = None,
                   hw: HW.HardwareSpec = HW.TPU_V5E, k: int = 5
                   ) -> ExecutionPlan:
    """`--mesh auto` in one call: search the runnable mesh_space with the
    named strategy and promote the winner to an ExecutionPlan. The measured
    strategies default to the compile-free simulator, so planning performs
    zero XLA compiles."""
    space = auto_mesh_space(cfg, shape, n_devices)
    if measurer is None and strategy not in ("fastest", "fastest_first",
                                             "wsmc"):
        measurer = MM.SimulatedMeasurer({"data": n_devices}, cache=cache)
    res = ST.plan_for(cfg, shape, cls, {"data": n_devices},
                      strategy=strategy, measurer=measurer, cache=cache,
                      k=k, hw=hw, factors=factors, space=space)
    return from_search_result(cfg, shape, res)
