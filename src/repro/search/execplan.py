"""Execution plans: the bridge from a SearchResult to something you RUN.

The paper's point (§III-E) is that the *predicted* memory requirement drives
the *actual* deployment configuration — Crispy (Will et al., 2022) showed
memory models only pay off when they emit runnable allocations. Before this
module the search subsystem could plan a pipe-axis mesh no driver could
execute; an `ExecutionPlan` closes that loop:

  plan       — the WSMC memory plan (remat x microbatches x optimizer x kv)
  mesh_axes  — the planned mesh as {axis: size}, a search OUTPUT
  ep         — the resolved expert-parallel mode (strategy-level knob)
  schedule   — the runtime schedule kind (single | scan | pipeline_1f1b)

`build(devices)` turns it into a live (jax Mesh, sharding.Strategy) pair via
launch.mesh.build_mesh; `plan_execution` is the one-call `--mesh auto`
entry: search a runnable mesh_space, promote the winner.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro import hw as HW
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import measure as MM
from repro.core.classifier import Classification
from repro.core.predictor import MemoryPlan
from repro.runtime.schedule_kinds import (SCHEDULE_PIPELINE, SCHEDULE_SCAN,  # noqa: F401 — re-exported schedule vocabulary
                                          SCHEDULE_SINGLE, SCHEDULES,
                                          schedule_kind)
from repro.search import space as SP
from repro.search import strategies as ST
from repro.search.strategies import SearchResult


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A runnable deployment configuration: memory plan + planned mesh +
    sharding mode + runtime schedule. The thing `--mesh auto` executes."""
    plan: MemoryPlan = MemoryPlan()
    mesh_axes: Tuple[Tuple[str, int], ...] = (("data", 1),)
    ep: bool = False
    schedule: str = SCHEDULE_SINGLE
    policy: str = ""                 # which search policy emitted it

    @property
    def mesh_shape(self) -> Dict[str, int]:
        return dict(self.mesh_axes)

    @property
    def n_devices(self) -> int:
        n = 1
        for _, size in self.mesh_axes:
            n *= int(size)
        return n

    @property
    def pipe(self) -> int:
        return int(self.mesh_shape.get("pipe", 1))

    def describe(self) -> str:
        p = self.plan
        mesh = "x".join(f"{a}:{n}" for a, n in self.mesh_axes)
        return (f"mesh={mesh} schedule={self.schedule} remat={p.remat} "
                f"micro={p.microbatches} opt={p.optimizer} kv={p.kv_shard} "
                f"ep={self.ep}"
                + (f" policy={self.policy}" if self.policy else ""))

    def strategy(self):
        """The matching sharding.Strategy (lazy import: keep this module
        usable without touching jax device state)."""
        from repro.parallel import sharding as S
        return S.Strategy(kv_shard=self.plan.kv_shard, ep=self.ep,
                          pipeline=self.pipe > 1)

    def build(self, devices: Optional[Sequence] = None):
        """Construct the planned mesh (over the first n_devices of
        `devices`) and the matching sharding Strategy. Returns
        (mesh, strategy)."""
        from repro.launch.mesh import build_mesh
        return build_mesh(self.mesh_shape, devices), self.strategy()


def from_search_result(cfg: ModelConfig, shape: ShapeConfig,
                       res: SearchResult,
                       mesh_shape: Optional[Mapping[str, int]] = None
                       ) -> ExecutionPlan:
    """Promote a SearchResult to an ExecutionPlan. `mesh_shape` is the
    fallback for results from fixed-mesh spaces whose candidates carry no
    mesh of their own."""
    ms = dict(res.mesh_shape or mesh_shape or {"data": 1})
    ep = ST.resolved_ep(cfg, res.candidate, ms)
    sched = schedule_kind(shape.kind, res.plan.microbatches,
                          int(ms.get("pipe", 1)))
    return ExecutionPlan(plan=res.plan,
                         mesh_axes=tuple(sorted(ms.items())),
                         ep=ep, schedule=sched, policy=res.policy)


def for_mesh(cfg: ModelConfig, shape: ShapeConfig, plan: MemoryPlan,
             mesh_shape: Mapping[str, int],
             policy: str = "") -> ExecutionPlan:
    """Promote a plan onto a FIXED mesh (forced CLI spec, legacy host
    mesh): EP by the default divisibility rule, schedule from the plan +
    pipe axis. The single promotion path the drivers share."""
    ms = dict(mesh_shape)
    ep = ST.resolved_ep(cfg, SP.Candidate(plan=plan), ms)
    sched = schedule_kind(shape.kind, plan.microbatches,
                          int(ms.get("pipe", 1)))
    return ExecutionPlan(plan=plan, mesh_axes=tuple(sorted(ms.items())),
                         ep=ep, schedule=sched, policy=policy)


def host_execution(cfg: ModelConfig, shape: ShapeConfig, plan: MemoryPlan,
                   n_devices: int, model_parallel: int = 1,
                   policy: str = "host") -> ExecutionPlan:
    """The legacy (data, model) host mesh as an ExecutionPlan (what
    `host_mesh_for` used to build): best-effort model axis over the
    surviving device count."""
    model = max(1, model_parallel)
    while n_devices % model:
        model -= 1
    return for_mesh(cfg, shape, plan,
                    {"data": n_devices // model, "model": model},
                    policy=policy)


def _axis_values(n_devices: int, cap: Optional[int] = None) -> Tuple[int, ...]:
    """1, 2, 4, ... up to n_devices (plus n_devices itself for non-powers)."""
    limit = min(n_devices, cap) if cap else n_devices
    vals = []
    v = 1
    while v <= limit:
        vals.append(v)
        v *= 2
    if limit not in vals:
        vals.append(limit)
    return tuple(vals)


def auto_mesh_space(cfg: ModelConfig, shape: ShapeConfig,
                    n_devices: int) -> SP.ConfigSpace:
    """The `--mesh auto` search space: every mesh axis searchable within the
    host's device budget, pipe candidates restricted to what the 1F1B
    runtime executes (executable=True)."""
    return SP.mesh_space(cfg, shape, max_devices=n_devices,
                         data=_axis_values(n_devices),
                         model=_axis_values(n_devices),
                         pipe=_axis_values(n_devices, cap=4),
                         executable=True)


def auto_plan(cfg: ModelConfig, shape: ShapeConfig, *, n_devices: int,
              strategy: str = "fastest", base_seq: int = 64,
              n_points: int = 2, factors: Optional[dict] = None,
              cache: Optional[MM.ProfileCache] = None,
              measurer: Optional[MM.MemoryMeasurer] = None):
    """The `--mesh auto` preamble shared by the train and serve drivers:
    classify the workload and plan a runnable execution. Returns
    (Classification, ExecutionPlan).

    `measurer` is the measurement backend for BOTH the classification
    ladder and the measured strategies — the drivers thread their
    `--backend` choice through here, so `--mesh auto --backend compile`
    classifies and verifies with real compiles instead of silently falling
    back to the simulator. Default (None) stays the compile-free simulator
    over the host's data axis."""
    from repro.core import profiler as PF
    if measurer is None:
        measurer = MM.SimulatedMeasurer({"data": n_devices}, cache=cache)
    cls = PF.classify_workload(cfg, shape, None, n_points=n_points,
                               base_seq=base_seq, measurer=measurer)
    eplan = plan_execution(cfg, shape, cls, n_devices=n_devices,
                           strategy=strategy, measurer=measurer, cache=cache,
                           factors=factors)
    return cls, eplan


# ---------------------------------------------------------------------------
# Serving: plan for maximum admitted concurrency under an HBM budget
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """A deployment configuration for the serving engine: the runnable
    ExecutionPlan plus the WSMC-predicted admission bound. `capacity` is
    the GLOBAL number of concurrent sequences `predictor.serving_capacity`
    says fit the per-device budget — the engine sizes its KV slot pool
    from it and queues everything beyond."""
    execution: ExecutionPlan
    capacity: int
    hbm_budget: float
    considered: int = 0              # serving candidates scored

    def slots(self, cap: Optional[int] = None) -> int:
        """Engine slot-pool size: the predicted capacity, optionally capped
        (CLI --max-slots, trace size)."""
        return self.capacity if cap is None else min(self.capacity, int(cap))

    def describe(self) -> str:
        return (f"{self.execution.describe()} capacity={self.capacity} "
                f"(budget={self.hbm_budget / 2**30:.1f} GiB, "
                f"considered={self.considered})")


def plan_serving(cfg: ModelConfig, shape: ShapeConfig, *, n_devices: int,
                 hbm_budget: Optional[float] = None,
                 cls: Optional[Classification] = None,
                 measurer: Optional[MM.MemoryMeasurer] = None,
                 cache: Optional[MM.ProfileCache] = None,
                 base_seq: int = 64, n_points: int = 2, mode: str = "paper",
                 factors: Optional[dict] = None,
                 hw: HW.HardwareSpec = HW.TPU_V5E,
                 space: Optional[SP.ConfigSpace] = None):
    """The serving-engine planning entry: walk the serving lattice
    (kv_shard x data x model, pipe pinned — space.serving_space) and pick
    the candidate that maximizes `predictor.serving_capacity` under the
    per-device HBM budget, tie-broken fastest-first. This is the paper's
    configuration loop run in reverse: instead of sizing memory to a fixed
    workload, it sizes the admissible workload to a fixed memory budget.
    Returns (Classification, ServingPlan)."""
    from repro.core import predictor as PR   # lazy, like profiler below
    from repro.core import profiler as PF
    if measurer is None:
        measurer = MM.SimulatedMeasurer({"data": n_devices}, cache=cache)
    if cls is None:
        cls = PF.classify_workload(cfg, shape, None, n_points=n_points,
                                   base_seq=base_seq, measurer=measurer)
    budget = hw.hbm_bytes if hbm_budget is None else float(hbm_budget)
    if space is None:
        space = SP.serving_space(cfg, shape, max_devices=n_devices,
                                 data=_axis_values(n_devices),
                                 model=_axis_values(n_devices))
    cands = space.candidates(cfg, shape)
    if not cands:
        raise ValueError(f"{space.name}: no valid serving candidates")
    best, best_cap = None, -1
    for cand in cands:                       # fastest-first => ties keep speed
        cap = PR.serving_capacity(cfg, shape, cand.plan, cls,
                                  cand.mesh_shape, mode=mode, hw=hw,
                                  hbm_budget=budget, factors=factors)
        if cap > best_cap:
            best, best_cap = cand, cap
    eplan = for_mesh(cfg, shape, best.plan, best.mesh_shape,
                     policy="max_concurrency")
    return cls, ServingPlan(execution=eplan, capacity=best_cap,
                            hbm_budget=budget, considered=len(cands))


def plan_execution(cfg: ModelConfig, shape: ShapeConfig,
                   cls: Optional[Classification], *, n_devices: int,
                   strategy: str = "fastest",
                   measurer: Optional[MM.MemoryMeasurer] = None,
                   cache: Optional[MM.ProfileCache] = None,
                   factors: Optional[dict] = None,
                   hw: HW.HardwareSpec = HW.TPU_V5E, k: int = 5
                   ) -> ExecutionPlan:
    """`--mesh auto` in one call: search the runnable mesh_space with the
    named strategy and promote the winner to an ExecutionPlan. The measured
    strategies default to the compile-free simulator, so planning performs
    zero XLA compiles."""
    space = auto_mesh_space(cfg, shape, n_devices)
    if measurer is None and strategy not in ("fastest", "fastest_first",
                                             "wsmc"):
        measurer = MM.SimulatedMeasurer({"data": n_devices}, cache=cache)
    res = ST.plan_for(cfg, shape, cls, {"data": n_devices},
                      strategy=strategy, measurer=measurer, cache=cache,
                      k=k, hw=hw, factors=factors, space=space)
    return from_search_result(cfg, shape, res)
