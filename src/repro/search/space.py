"""Declarative configuration space for plan search (paper §III-E,
generalized).

The paper's core loop — enumerate candidate memory configurations, predict
each one's capacity, pick the fastest that fits — used to be re-implemented
by every caller (planner lattice, hillclimb VARIANTS, dry-run sweeps). This
module makes the *space* a first-class object the strategies
(`repro.search.strategies`) walk:

  Knob        — one searchable dimension: plan knobs (remat, microbatches,
                optimizer, kv_shard), mesh axes (data / model / pipe / pod)
                and beyond-paper levers (embed_onehot, q_block, ep, …).
  Candidate   — one lattice point: a MemoryPlan + a mesh shape + extras.
  Constraint  — a named validity predicate (batch divisibility, kv-head
                divisibility, pipeline legality, mesh-size budget).
  ConfigSpace — knobs × constraints with a fastest-first ordering; supports
                subspacing (pin knobs) and single-point construction.

Builders: `paper_space` (the §III-E lattice over a fixed mesh — exactly the
old `planner.candidate_plans`), `mesh_space` (mesh axes become searchable,
so the mesh is a planned *output*), `hillclimb_space` (the perf-variant
lattice that used to live in launch/hillclimb.py's VARIANTS dict).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import (Callable, Dict, Iterator, List, Mapping, Optional,
                    Sequence, Tuple)

from repro.configs.base import TRAIN, ModelConfig, ShapeConfig
from repro.core.predictor import MemoryPlan

REMATS = ("none", "dots", "full")
OPTIMIZERS = ("adamw_f32", "adamw_bf16", "adafactor")
MICROBATCHES = (1, 2, 4, 8, 16, 32, 64)

# kv_shard value resolved per candidate from the model-axis size.
AUTO = "auto"

# Which override bucket each beyond-paper knob feeds when a launch driver
# materializes a candidate: ModelSettings, AttnSettings, or the sharding
# Strategy (see launch/hillclimb.run_variant).
EXTRA_GROUPS = {
    "embed_onehot": "settings",
    "moe_group": "settings",
    "q_block": "attn",
    "kv_block": "attn",
    "repeat_kv": "attn",
    "gather_weights": "attn",
    "ep": "strategy",
    "fsdp": "strategy",
    "admission": "serving",
    "prefill_budget": "serving",
}


def kv_auto(cfg: ModelConfig, model_size: int) -> str:
    """KV-head sharding only when heads divide the model axis; otherwise the
    ring cache shards its sequence dim (padding/replication would multiply
    the decode-resident cache — see musicgen kv=24 in EXPERIMENTS §Perf)."""
    return "heads" if model_size and cfg.n_kv_heads % model_size == 0 else "seq"


# ---------------------------------------------------------------------------
# Candidates
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of a ConfigSpace: the full configuration the planner may
    emit — knob plan, mesh shape (possibly searched), extra levers."""
    plan: MemoryPlan = MemoryPlan()
    mesh: Tuple[Tuple[str, int], ...] = ()      # sorted (axis, size) pairs
    extras: Tuple[Tuple[str, object], ...] = ()  # sorted (knob, value) pairs

    @property
    def mesh_shape(self) -> Dict[str, int]:
        return dict(self.mesh)

    def extra(self, name: str, default=None):
        return dict(self.extras).get(name, default)

    def step_time_penalty(self) -> float:
        """Fastest-first ordering key. The plan's roofline-validated penalty,
        a pipeline bubble term when the pipe axis is in play, a light
        TP-collective term so mesh search prefers the smallest model axis
        that fits, and — on spaces where the mesh is searchable — a
        compute-parallel speedup (1/dp, 1/pipe: more devices over the batch
        or the depth means less work per device), so mesh search fills the
        host before shrinking. On fixed-mesh spaces every candidate shares
        the mesh term, so plan ordering is unchanged. Extras are
        ordering-neutral (ties keep lattice order)."""
        pen = self.plan.step_time_penalty()
        ms = self.mesh_shape
        pipe = int(ms.get("pipe", 1))
        if pipe > 1:
            micro = max(self.plan.microbatches, 1)
            pen *= (micro + pipe - 1) / micro
        model = int(ms.get("model", 1))
        if model > 1:
            pen *= 1.0 + 0.02 * math.log2(model)
        dp = int(ms.get("pod", 1)) * int(ms.get("data", 1))
        pen /= dp * pipe
        return pen

    def describe(self) -> str:
        p = self.plan
        parts = [f"remat={p.remat}", f"micro={p.microbatches}",
                 f"opt={p.optimizer}", f"kv={p.kv_shard}"]
        if self.mesh:
            parts.append("mesh=" + "x".join(f"{a}:{n}" for a, n in self.mesh))
        parts += [f"{k}={v}" for k, v in self.extras]
        return " ".join(parts)


def candidate_overrides(cand: Candidate) -> Dict[str, Dict[str, object]]:
    """Split a candidate's extras into the launch override buckets
    (ModelSettings / AttnSettings / sharding Strategy kwargs). Strategy
    booleans valued None mean "keep the default_strategy choice" (e.g. the
    EP auto-rule) and are dropped."""
    out: Dict[str, Dict[str, object]] = {"settings": {}, "attn": {},
                                         "strategy": {}, "serving": {}}
    for name, value in cand.extras:
        bucket = EXTRA_GROUPS[name]
        if bucket == "strategy" and value is None:
            continue
        out[bucket][name] = value
    return out


# ---------------------------------------------------------------------------
# Knobs and constraints
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Knob:
    """One searchable dimension. The first value is the baseline (what
    `ConfigSpace.point()` assumes for unassigned knobs)."""
    name: str
    values: Tuple
    group: str = "plan"          # plan | mesh | extra


@dataclasses.dataclass(frozen=True)
class Constraint:
    name: str
    check: Callable[[ModelConfig, ShapeConfig, Candidate], bool]


def _check_micro(cfg, shape, cand) -> bool:
    return shape.global_batch % max(cand.plan.microbatches, 1) == 0

MICRO_DIVIDES_BATCH = Constraint("microbatches divide global batch",
                                 _check_micro)


def _check_dp(cfg, shape, cand) -> bool:
    ms = cand.mesh_shape
    dp = int(ms.get("pod", 1)) * int(ms.get("data", 1))
    per = shape.global_batch // max(cand.plan.microbatches, 1)
    if shape.kind == TRAIN:
        # strict: a per-micro batch below dp replicates compute/memory
        return per % dp == 0
    # serving: bs=1 long-context cells replicate the batch axis benignly
    return per % dp == 0 or per < dp

DP_DIVIDES_BATCH = Constraint("per-micro batch divides dp", _check_dp)


def _check_kv(cfg, shape, cand) -> bool:
    if cand.plan.kv_shard != "heads":
        return True
    model = int(cand.mesh_shape.get("model", 1))
    return model <= 1 or cfg.n_kv_heads % model == 0

KV_HEADS_DIVISIBLE = Constraint("kv heads divide model axis", _check_kv)


def _check_pipe(cfg, shape, cand) -> bool:
    pipe = int(cand.mesh_shape.get("pipe", 1))
    if pipe <= 1:
        return True
    if shape.kind != TRAIN:           # serving runtime has no pipe schedule
        return False
    # stages split the stacked unit REPEATS (tail blocks run outside the
    # pipeline) — the same quantity runtime.schedule.validate_pipeline tests
    if cfg.repeats <= 0 or cfg.repeats % pipe:
        return False
    return cand.plan.microbatches >= pipe    # else the pipeline never fills

PIPE_LEGAL = Constraint("pipe divides unit repeats and microbatches fill it",
                        _check_pipe)


def _check_pipe_executable(cfg, shape, cand) -> bool:
    """What the 1F1B runtime can actually execute today — the SAME
    predicate validate_pipeline raises on (runtime.schedule_kinds, jax-free
    so the compile-free planning path stays light), so a planned candidate
    IS a runnable one."""
    if int(cand.mesh_shape.get("pipe", 1)) <= 1:
        return True
    from repro.runtime.schedule_kinds import pipeline_executable
    return pipeline_executable(cfg, cand.plan.microbatches, cand.mesh_shape,
                               None if shape is None else shape.global_batch)

PIPE_EXECUTABLE = Constraint("pipe schedule executable by the 1F1B runtime",
                             _check_pipe_executable)


def _check_kv_block(cfg, shape, cand) -> bool:
    b = cand.plan.kv_block_size
    return b == 0 or 0 < b <= shape.context

KV_BLOCK_LEGAL = Constraint("kv block size within the context", _check_kv_block)


def _check_kv_quant(cfg, shape, cand) -> bool:
    """Quantized KV lives in the paged block pool (per-row scales ride the
    block leaves), and int4 packs nibble pairs over the head dim."""
    q = cand.plan.kv_quant
    if q == "none":
        return True
    if cand.plan.kv_block_size <= 0:
        return False
    return q != "int4" or cfg.resolved_head_dim % 2 == 0

KV_QUANT_LEGAL = Constraint("kv quantization needs a paged pool "
                            "(int4: even head_dim)", _check_kv_quant)


def _check_kv_retain(cfg, shape, cand) -> bool:
    """Block-granular retention evicts paged blocks — meaningless on ring
    slots; a reach cap must also leave at least one block of context."""
    r = cand.plan.kv_retain
    return r == 0 or cand.plan.kv_block_size > 0

KV_RETAIN_LEGAL = Constraint("kv retention needs a paged pool",
                             _check_kv_retain)


def mesh_budget(max_devices: int) -> Constraint:
    def check(cfg, shape, cand) -> bool:
        n = 1
        for _, size in cand.mesh:
            n *= int(size)
        return n <= max_devices
    return Constraint(f"mesh size <= {max_devices}", check)


# ---------------------------------------------------------------------------
# The space
# ---------------------------------------------------------------------------

class ConfigSpace:
    """A declarative knob lattice + validity constraints + ordering."""

    def __init__(self, name: str, knobs: Sequence[Knob],
                 constraints: Sequence[Constraint] = ()):
        self.name = name
        self.knobs = tuple(knobs)
        self.constraints = tuple(constraints)
        self._by_name = {}
        for k in self.knobs:
            if k.name in self._by_name:
                raise ValueError(f"{name}: duplicate knob {k.name!r}")
            self._by_name[k.name] = k

    def __len__(self) -> int:
        n = 1
        for k in self.knobs:
            n *= len(k.values)
        return n

    def knob(self, name: str) -> Knob:
        if name not in self._by_name:
            raise KeyError(f"{self.name}: unknown knob {name!r}; "
                           f"have {sorted(self._by_name)}")
        return self._by_name[name]

    def subspace(self, name: Optional[str] = None, **pins) -> "ConfigSpace":
        """Pin knobs to a single value (or a subset of values)."""
        knobs = []
        for k in self.knobs:
            if k.name in pins:
                v = pins.pop(k.name)
                vals = tuple(v) if isinstance(v, (tuple, list)) else (v,)
                for val in vals:
                    if val not in k.values:
                        raise ValueError(
                            f"{self.name}.{k.name}: {val!r} not in {k.values}")
                knobs.append(dataclasses.replace(k, values=vals))
            else:
                knobs.append(k)
        if pins:
            raise KeyError(f"{self.name}: unknown knobs {sorted(pins)}")
        return ConfigSpace(name or f"{self.name}/sub", knobs, self.constraints)

    # -- candidate construction -------------------------------------------

    def _build(self, cfg: Optional[ModelConfig],
               assignment: Mapping[str, object]) -> Candidate:
        plan_kwargs: Dict[str, object] = {}
        mesh: List[Tuple[str, int]] = []
        extras: List[Tuple[str, object]] = []
        for k in self.knobs:
            v = assignment[k.name]
            if k.group == "plan":
                plan_kwargs[k.name] = v
            elif k.group == "mesh":
                mesh.append((k.name, int(v)))
            else:
                extras.append((k.name, v))
        if plan_kwargs.get("kv_shard") == AUTO:
            if cfg is None:
                raise ValueError(f"{self.name}: kv_shard='auto' needs a "
                                 "ModelConfig to resolve against")
            model = dict(mesh).get("model", 1)
            plan_kwargs["kv_shard"] = kv_auto(cfg, model)
        plan = dataclasses.replace(MemoryPlan(), **plan_kwargs)
        return Candidate(plan=plan, mesh=tuple(sorted(mesh)),
                         extras=tuple(sorted(extras, key=lambda kv: kv[0])))

    def value_of(self, cand: Candidate, name: str):
        k = self.knob(name)
        if k.group == "plan":
            return getattr(cand.plan, name)
        if k.group == "mesh":
            return cand.mesh_shape.get(name, k.values[0])
        return cand.extra(name, k.values[0])

    def point(self, cfg: Optional[ModelConfig] = None,
              base: Optional[Candidate] = None, **assign) -> Candidate:
        """One candidate from a (partial) knob assignment. Unassigned knobs
        take their value from `base` (e.g. a CLI-provided plan) or the
        knob's first (baseline) value. Explicit assignments are validated
        against the knob's declared values."""
        unknown = set(assign) - set(self._by_name)
        if unknown:
            raise KeyError(f"{self.name}: unknown knobs {sorted(unknown)}")
        full = {}
        for k in self.knobs:
            if k.name in assign:
                v = assign[k.name]
                if v not in k.values:
                    raise ValueError(
                        f"{self.name}.{k.name}: {v!r} not in {k.values}")
            elif base is not None:
                v = self.value_of(base, k.name)
            else:
                v = k.values[0]
            full[k.name] = v
        return self._build(cfg, full)

    # -- enumeration -------------------------------------------------------

    def points(self, cfg: Optional[ModelConfig] = None) -> Iterator[Candidate]:
        """Raw lattice in declared knob order (pre-constraint)."""
        names = [k.name for k in self.knobs]
        for combo in itertools.product(*(k.values for k in self.knobs)):
            yield self._build(cfg, dict(zip(names, combo)))

    def violations(self, cfg: ModelConfig, shape: ShapeConfig,
                   cand: Candidate) -> List[str]:
        return [c.name for c in self.constraints
                if not c.check(cfg, shape, cand)]

    def is_valid(self, cfg: ModelConfig, shape: ShapeConfig,
                 cand: Candidate) -> bool:
        return all(c.check(cfg, shape, cand) for c in self.constraints)

    def candidates(self, cfg: ModelConfig,
                   shape: ShapeConfig) -> List[Candidate]:
        """Valid lattice points, fastest-first (stable: ties keep the
        declared enumeration order — the paper's walk)."""
        valid = [c for c in self.points(cfg) if self.is_valid(cfg, shape, c)]
        return sorted(valid, key=lambda c: c.step_time_penalty())


# ---------------------------------------------------------------------------
# Space builders
# ---------------------------------------------------------------------------

def _mesh_knobs(mesh_shape: Mapping[str, int]) -> List[Knob]:
    return [Knob(axis, (int(n),), group="mesh")
            for axis, n in sorted(mesh_shape.items())]


def paper_space(cfg: ModelConfig, shape: ShapeConfig,
                mesh_shape: Optional[Mapping[str, int]] = None,
                model_size: Optional[int] = None) -> ConfigSpace:
    """The paper's §III-E lattice over a FIXED mesh: remat × microbatches ×
    optimizer with kv sharding resolved from the model-axis size. This is
    exactly the old `planner.candidate_plans` lattice (decision parity is
    pinned by tests/test_search.py)."""
    ms = dict(mesh_shape or {})
    if model_size is None:
        model_size = int(ms.get("model", 16))
    kv = kv_auto(cfg, model_size)
    if shape.kind != TRAIN:
        knobs = [Knob("remat", ("none",)), Knob("microbatches", (1,)),
                 Knob("optimizer", ("adamw_f32",)), Knob("kv_shard", (kv,))]
    else:
        knobs = [Knob("remat", REMATS), Knob("microbatches", MICROBATCHES),
                 Knob("optimizer", OPTIMIZERS), Knob("kv_shard", (kv,))]
    knobs += _mesh_knobs(ms)
    return ConfigSpace(f"paper[{cfg.name}|{shape.name}]", knobs,
                       (MICRO_DIVIDES_BATCH,))


def mesh_space(cfg: ModelConfig, shape: ShapeConfig, *,
               max_devices: int = 256,
               data: Sequence[int] = (1, 2, 4, 8, 16, 32),
               model: Sequence[int] = (1, 2, 4, 8, 16),
               pipe: Sequence[int] = (1, 2, 4),
               executable: bool = False) -> ConfigSpace:
    """Beyond-paper: the mesh axes are searchable dimensions, so the planner
    emits the mesh instead of taking it as a CLI input. kv_shard resolves
    per candidate ('auto') against the candidate's own model-axis size.
    `executable=True` additionally restricts pipe candidates to what the
    1F1B runtime schedule can run today (the `--mesh auto` drivers set it:
    the plan must be the thing you run)."""
    if shape.kind != TRAIN:
        plan_knobs = [Knob("remat", ("none",)), Knob("microbatches", (1,)),
                      Knob("optimizer", ("adamw_f32",)),
                      Knob("kv_shard", (AUTO,))]
        pipe = (1,)
    else:
        plan_knobs = [Knob("remat", REMATS),
                      Knob("microbatches", MICROBATCHES),
                      Knob("optimizer", OPTIMIZERS), Knob("kv_shard", (AUTO,))]
    mesh_knobs = [Knob("data", tuple(data), group="mesh"),
                  Knob("model", tuple(model), group="mesh"),
                  Knob("pipe", tuple(pipe), group="mesh")]
    constraints = [MICRO_DIVIDES_BATCH, DP_DIVIDES_BATCH, KV_HEADS_DIVISIBLE,
                   PIPE_LEGAL, mesh_budget(max_devices)]
    if executable:
        constraints.append(PIPE_EXECUTABLE)
    return ConfigSpace(
        f"mesh[{cfg.name}|{shape.name}]", plan_knobs + mesh_knobs,
        tuple(constraints))


def serving_space(cfg: ModelConfig, shape: ShapeConfig, *,
                  max_devices: int = 256,
                  data: Sequence[int] = (1, 2, 4, 8, 16, 32),
                  model: Sequence[int] = (1, 2, 4, 8, 16),
                  kv_blocks: Sequence[int] = (0,),
                  admission: Sequence[str] = (),
                  kv_quants: Sequence[str] = ("none",),
                  kv_retains: Sequence[int] = (0,),
                  prefill_budgets: Sequence[int] = ()) -> ConfigSpace:
    """The serving-engine planning lattice: mesh axes searchable (pipe
    pinned to 1 — the serving runtime is single-shot) and kv_shard a REAL
    knob rather than auto-resolved, because the admission controller cares:
    `heads` replicates the ring cache when kv heads don't divide the model
    axis, while `seq` shards its length — different per-sequence bytes,
    hence different admitted concurrency. `kv_block_size` is the paged-KV
    allocation granule (0 = whole-sequence ring slots): smaller blocks
    track short sequences' true footprint more tightly but pay more
    block-table indirection. `admission` is the engine reservation
    discipline the capacity inversion assumes ("optimistic" expected-case
    vs "worst" deadlock-free-by-construction) — ABSENT by default so
    `plan_serving(admission=...)` governs; pass a non-empty tuple to make
    it a searched knob (candidate extras then override the argument).
    `kv_quant` / `kv_retain` are the capacity-bending knobs (int8/int4
    block storage, top-k block retention) — legal only over a paged pool,
    and `plan_serving(min_agreement=...)` gates how aggressive a bend the
    planner may pick. `prefill_budgets` (absent by default, like
    `admission`) makes the engine's prefill token budget a searched knob:
    a tighter budget shrinks the prefill-tick transient the capacity
    inversion must hold headroom for, admitting more blocks at tight HBM
    budgets at the cost of slower prompt ramp-in. `plan_serving` scores
    each candidate by `predictor.serving_capacity` (ring) or expected
    admitted concurrency over the block pool (paged) instead of step
    time."""
    knobs = [Knob("remat", ("none",)), Knob("microbatches", (1,)),
             Knob("optimizer", ("adamw_f32",)),
             Knob("kv_shard", ("heads", "seq")),
             Knob("kv_block_size", tuple(kv_blocks)),
             Knob("kv_quant", tuple(kv_quants)),
             Knob("kv_retain", tuple(int(r) for r in kv_retains)),
             *([Knob("admission", tuple(admission), group="extra")]
               if admission else []),
             *([Knob("prefill_budget",
                     tuple(int(p) for p in prefill_budgets), group="extra")]
               if prefill_budgets else []),
             Knob("data", tuple(data), group="mesh"),
             Knob("model", tuple(model), group="mesh"),
             Knob("pipe", (1,), group="mesh")]
    return ConfigSpace(f"serving[{cfg.name}|{shape.name}]", knobs,
                       (KV_HEADS_DIVISIBLE, KV_BLOCK_LEGAL, KV_QUANT_LEGAL,
                        KV_RETAIN_LEGAL, mesh_budget(max_devices)))


def hillclimb_space(
        mesh_shape: Optional[Mapping[str, int]] = None) -> ConfigSpace:
    """The perf-hillclimbing lattice: the WSMC plan knobs plus the
    beyond-paper levers the old launch/hillclimb.py VARIANTS dict hand-rolled
    (one-hot embedding, EP, DP-replicated weights, attention block sizes,
    MoE routing group). The first value of each knob is the baseline;
    `repro.search.strategies.greedy_coordinate` walks one knob at a time.
    `mesh_shape` pins the (fixed) mesh the candidates are scored against."""
    knobs = [
        Knob("remat", REMATS),
        Knob("microbatches", MICROBATCHES),
        Knob("optimizer", OPTIMIZERS),
        Knob("kv_shard", ("heads", "seq")),
        Knob("embed_onehot", (True, False), group="extra"),
        Knob("moe_group", (2048, 512, 1024), group="extra"),
        Knob("q_block", (512, 256, 1024), group="extra"),
        Knob("kv_block", (1024, 256), group="extra"),
        Knob("repeat_kv", (None, True, False), group="extra"),
        Knob("gather_weights", (False, True), group="extra"),
        Knob("ep", (None, True, False), group="extra"),
        Knob("fsdp", (True, False), group="extra"),
    ]
    knobs += _mesh_knobs(mesh_shape or {})
    return ConfigSpace("hillclimb", knobs, (MICRO_DIVIDES_BATCH,))
