"""Unified plan-search subsystem: a declarative config space (knobs,
constraints, ordering) + pluggable strategies (fastest-first prediction,
exhaustive verification, staged simulate→compile screening, greedy
hillclimbing). The planner, hillclimb, serve, dryrun and the benchmarks all
search through this one API."""
from repro.search.space import (  # noqa: F401
    AUTO, Candidate, ConfigSpace, Constraint, Knob, candidate_overrides,
    hillclimb_space, kv_auto, mesh_space, paper_space, serving_space,
)
from repro.search.strategies import (  # noqa: F401
    CLI_STRATEGIES, CandidateScorer, SearchResult, exhaustive_verified,
    fastest_first, get_strategy, greedy_coordinate, plan_budget, plan_for,
    staged,
)
from repro.search.execplan import (  # noqa: F401
    ExecutionPlan, ServingPlan, auto_mesh_space, auto_plan, for_mesh,
    from_search_result, host_execution, plan_execution, plan_serving,
)
