"""Gradient compression for DP all-reduce: int8 with per-tensor scale and
stochastic rounding (unbiased — property-tested).

At 1000-node scale the DP gradient reduce-scatter is the cross-pod
bottleneck; int8 payloads cut its collective-bytes term 4x (roofline §Perf
measures this on the pod axis). The quantize -> psum(int32) -> dequantize
schedule avoids int8 overflow by accumulating in int32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, key):
    """Stochastic-rounding int8 quantization. Returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax / 127.0, 1e-30)
    y = xf / scale
    lo = jnp.floor(y)
    p_up = y - lo
    u = jax.random.uniform(key, x.shape)
    q = lo + (u < p_up).astype(jnp.float32)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_roundtrip(tree, key):
    """Quantize+dequantize every leaf (simulates the compressed all-reduce
    payload inside a jit train step; the wire collective itself is exercised
    by the shard_map path below)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        q, s = quantize_int8(leaf, k)
        out.append(dequantize_int8(q, s, leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def compressed_psum(x, axis_name: str, key):
    """int8-payload mean over a mesh axis, inside shard_map.

    Schedule: (1) scalar pmax agrees on a shared scale, (2) int8 payload is
    accumulated as int32 psum (no overflow for <= 2^23 participants),
    (3) dequantize by the shared scale. Payload bytes: 1/4 of f32.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name) / 127.0,
                        1e-30)
    y = xf / scale
    lo = jnp.floor(y)
    u = jax.random.uniform(key, x.shape)
    q = jnp.clip(lo + (u < (y - lo)).astype(jnp.float32),
                 -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total.astype(jnp.float32) * scale / n).astype(x.dtype)
