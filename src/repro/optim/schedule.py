"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, base_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = base_lr * (s + 1.0) / max(warmup_steps, 1)
    prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                     (1.0 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup_steps, warm, cos)
