"""Optimizers with capacity-relevant state layouts.

The WSMC planner treats the optimizer as a memory knob (DESIGN.md §2):
  adamw_f32  — m, v in f32 (8 bytes/param of state)        fastest, largest
  adamw_bf16 — m, v in bf16 (4 bytes/param)                minor quality cost
  adafactor  — factored second moment (~0 bytes/param)     cheapest

All states mirror parameter sharding (ZeRO: FSDP-sharded params => sharded
optimizer state for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw_f32"          # adamw_f32 | adamw_bf16 | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # adafactor
    decay_rate: float = 0.8
    clip_threshold: float = 1.0

    @property
    def state_bytes_per_param(self) -> float:
        """Closed-form state footprint (the predictor's Eq.7 'retrievable'
        term; excludes the params themselves)."""
        return {"adamw_f32": 8.0, "adamw_bf16": 4.0, "adafactor": 0.05}[self.kind]


def _acc_dtype(ocfg: OptimizerConfig):
    return jnp.bfloat16 if ocfg.kind == "adamw_bf16" else jnp.float32


# ---------------------------------------------------------------------------

def init_state(ocfg: OptimizerConfig, params) -> Any:
    if ocfg.kind in ("adamw_f32", "adamw_bf16"):
        dt = _acc_dtype(ocfg)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}
    if ocfg.kind == "adafactor":
        def factored(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(factored, params),
                "count": jnp.zeros((), jnp.int32)}
    raise ValueError(ocfg.kind)


def state_specs(ocfg: OptimizerConfig, param_spec_tree):
    """PartitionSpecs for the optimizer state, mirroring the params."""
    from jax.sharding import PartitionSpec as P
    if ocfg.kind in ("adamw_f32", "adamw_bf16"):
        return {"m": param_spec_tree, "v": param_spec_tree, "count": P()}
    def factored(spec):
        return {"vr": P(*spec[:-1]), "vc": P(*(tuple(spec[:-2]) + (spec[-1],)))
                if len(spec) >= 2 else P(*spec)}
    def one(spec):
        if len(spec) >= 2:
            return {"vr": P(*spec[:-1]),
                    "vc": P(*(tuple(spec[:-2]) + (spec[-1],)))}
        return {"v": P(*spec)}
    return {"f": jax.tree.map(one, param_spec_tree,
                              is_leaf=lambda x: isinstance(x, P)),
            "count": P()}


# ---------------------------------------------------------------------------

def apply_updates(ocfg: OptimizerConfig, params, grads, state, lr):
    """Returns (new_params, new_state). grads/params pytrees; lr scalar."""
    if ocfg.kind in ("adamw_f32", "adamw_bf16"):
        return _adamw(ocfg, params, grads, state, lr)
    return _adafactor(ocfg, params, grads, state, lr)


def _adamw(ocfg, params, grads, state, lr):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - ocfg.b1 ** cf
    bc2 = 1.0 - ocfg.b2 ** cf
    dt = _acc_dtype(ocfg)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = ocfg.b1 * m.astype(jnp.float32) + (1 - ocfg.b1) * gf
        vf = ocfg.b2 * v.astype(jnp.float32) + (1 - ocfg.b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        step = mhat / (jnp.sqrt(vhat) + ocfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + ocfg.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(dt), vf.astype(dt)

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = jax.tree.unflatten(tree, [o[0] for o in out])
    new_m = jax.tree.unflatten(tree, [o[1] for o in out])
    new_v = jax.tree.unflatten(tree, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}


def _adafactor(ocfg, params, grads, state, lr):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    rho = jnp.minimum(1.0 - cf ** (-ocfg.decay_rate), 0.999)

    def upd(p, g, f):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + 1e-30
        if p.ndim >= 2:
            vr = rho * f["vr"] + (1 - rho) * g2.mean(axis=-1)
            vc = rho * f["vc"] + (1 - rho) * g2.mean(axis=-2)
            denom = (vr[..., None] / jnp.maximum(
                vr.mean(axis=-1, keepdims=True)[..., None], 1e-30)) * vc[..., None, :]
            step = gf / jnp.sqrt(jnp.maximum(denom, 1e-30))
            nf = {"vr": vr, "vc": vc}
        else:
            v = rho * f["v"] + (1 - rho) * g2
            step = gf / jnp.sqrt(jnp.maximum(v, 1e-30))
            nf = {"v": v}
        # update clipping (RMS)
        rms = jnp.sqrt(jnp.mean(step * step) + 1e-30)
        step = step / jnp.maximum(1.0, rms / ocfg.clip_threshold)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + ocfg.weight_decay * pf)
        return pf.astype(p.dtype), nf

    is_f = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_f = jax.tree.flatten(state["f"], is_leaf=is_f)[0]
    out = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
    new_p = jax.tree.unflatten(tree, [o[0] for o in out])
    new_f = jax.tree.unflatten(tree, [o[1] for o in out])
    return new_p, {"f": new_f, "count": count}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), norm
