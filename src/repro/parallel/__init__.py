from repro.parallel.axes import (  # noqa: F401
    DEFAULT_RULES, axis_rules, current_mesh, current_rules, logical_to_spec,
    shard, shard_map,
)
