"""Logical-axis system: models annotate tensors with *logical* axis names;
a strategy maps logical names onto physical mesh axes (MaxText-style).

Activations call `shard(x, "batch", "seq", "embed")`; weights get their
PartitionSpec from `parallel.sharding` path rules. Outside a mesh context the
hooks are identity, so the same model code runs on 1 CPU device in tests.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma=None):
    """jax.shard_map across JAX versions: newer releases expose it at the
    top level with `check_vma`; older ones live in jax.experimental with
    `check_rep` (same meaning)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as sm
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

Axis = Union[None, str, Tuple[str, ...]]

# Default logical->physical rules for the production 2-D/3-D meshes.
# "pod" is present only in the multi-pod mesh; missing axes are dropped.
DEFAULT_RULES: Dict[str, Axis] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,             # sequence kept local by default (SP overrides)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "inner": "model",        # xLSTM inner (v/output) dim
    "lru": "model",          # RG-LRU width
    "mlp_act": "model",
    "kv_seq": None,          # KV-cache sequence dim (SP decode overrides -> "model")
    # weights
    "embed_w": "data",       # FSDP axis for the d_model dim of weights
    "mlp": "model",          # TP axis for FFN hidden
    "q_w": "model",          # TP for flattened q/o projection dim (heads*hd)
    "kv_w": "model",         # TP for flattened k/v projection dim
    "vocab": "model",
    "experts": None,         # experts dim (EP strategy overrides -> "model")
    "layers": None,          # stacked-scan leading dim
    "conv": None,
}

_state = threading.local()


def current_rules() -> Dict[str, Axis]:
    return getattr(_state, "rules", DEFAULT_RULES)


def current_mesh() -> Optional[Mesh]:
    m = getattr(_state, "mesh", None)
    if m is not None:
        return m
    # fall back to the ambient jax mesh context if one is active
    env = jax._src.mesh.thread_resources.env  # noqa: SLF001
    phys = env.physical_mesh
    return phys if phys and not phys.empty else None


@contextlib.contextmanager
def suspend_annotations():
    """Disable shard()/gather_fsdp() for code traced inside this context.

    Needed for manual-parallelism regions (shard_map bodies, e.g. the 1F1B
    pipeline stage): the per-device code is already local, and a
    with_sharding_constraint naming a manual mesh axis is an error there.
    Trace-time only — the flag is read while jax traces, not at run time.
    """
    old = getattr(_state, "suspended", False)
    _state.suspended = True
    try:
        yield
    finally:
        _state.suspended = old


def annotations_suspended() -> bool:
    return getattr(_state, "suspended", False)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Axis], mesh: Optional[Mesh] = None):
    old_rules = getattr(_state, "rules", None)
    old_mesh = getattr(_state, "mesh", None)
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        if old_rules is None:
            del _state.rules
        else:
            _state.rules = old_rules
        if old_mesh is None:
            if hasattr(_state, "mesh"):
                del _state.mesh
        else:
            _state.mesh = old_mesh


def logical_to_spec(logical: Sequence[Optional[str]],
                    rules: Optional[Dict[str, Axis]] = None,
                    mesh: Optional[Mesh] = None,
                    shape: Optional[Sequence[int]] = None) -> P:
    """Map logical axis names to a PartitionSpec under `rules` and `mesh`.

    With `shape`, mesh axes that do not evenly divide their dimension are
    dropped (jit in_shardings demand exact divisibility; GSPMD propagation
    still finds split tilings internally — DESIGN.md §4 head-divisibility).
    """
    rules = rules if rules is not None else current_rules()
    mesh = mesh if mesh is not None else current_mesh()
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    used = set()
    out = []
    for i, name in enumerate(logical):
        ax = rules.get(name) if name is not None else None
        if ax is None:
            out.append(None)
            continue
        cands = (ax,) if isinstance(ax, str) else tuple(ax)
        picked = []
        dim = shape[i] if shape is not None else None
        for a in cands:
            if a not in mesh_axes or a in used:
                continue
            if dim is not None:
                size = mesh.shape[a]
                if dim % (size * int(np_prod([mesh.shape[p] for p in picked])
                                     or 1)):
                    continue
            picked.append(a)
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    return P(*out)


def np_prod(xs):
    r = 1
    for x in xs:
        r *= x
    return r


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op outside a mesh)."""
    if annotations_suspended():
        return x
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical, mesh=mesh, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def gather_fsdp(w: jax.Array, *logical: Optional[str]) -> jax.Array:
    """ZeRO-3 gather-on-use: re-constrain a weight with its FSDP ("embed_w")
    axis dropped, so GSPMD all-gathers the (small) weight over "data" instead
    of psum-ing the (large) activation partials — EXPERIMENTS §Perf iter 2."""
    if annotations_suspended():
        return w
    mesh = current_mesh()
    if mesh is None:
        return w
    rules = dict(current_rules())
    rules["embed_w"] = None
    spec = logical_to_spec(logical, rules=rules, mesh=mesh, shape=w.shape)
    return jax.lax.with_sharding_constraint(w, NamedSharding(mesh, spec))
