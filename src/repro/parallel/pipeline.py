"""Pipeline parallelism over a dedicated mesh axis.

For 1000+-node deployments the cross-pod ("pod") axis has the weakest links
(DCN/optical vs intra-pod ICI); pipelining over it replaces per-layer
collectives with one boundary `ppermute` per microbatch per stage
(DESIGN.md §5). This module implements the schedule as a differentiable
lax.scan inside shard_map:

  tick t ∈ [0, n_micro + n_stages - 1):
      stage s computes microbatch (t - s) when 0 <= t-s < n_micro,
      then ppermutes its boundary activation to stage s+1.

Uniform compute per tick (masked when idle) keeps SPMD happy; autodiff
through ppermute/scan gives the full-stash backward — the runtime's 1F1B
schedule (`repro.runtime.schedule`) wraps `stage_fn` with jax.checkpoint so
only the boundary carries stay resident, matching the planner's in-flight
transient model. Bubble fraction is the usual (S-1)/(T+S-1); the runtime
requires n_micro >= S so the pipeline fills.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel import axes as pax


def pipeline_apply(stage_fn: Callable, stage_params, x_micro, *,
                   mesh: Mesh, axis: str = "pipe",
                   x_spec: Optional[P] = None):
    """Run `stage_fn` as a pipeline over mesh axis `axis`.

    stage_fn(params_slice, x: [mb, ...]) -> [mb, ...]   (uniform stages)
    stage_params: pytree stacked on a leading n_stages dim (sharded on axis)
    x_micro: [n_micro, mb, ...]; `x_spec` is its shard_map spec (default
    fully replicated — pass e.g. P(None, "data") to keep the microbatch
    batch dim data-sharded through the pipeline).
    Returns [n_micro, mb, ...] outputs of the final stage (same spec).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
    x_spec = P() if x_spec is None else x_spec

    def per_device(params_local, xs_local):
        # params_local: [1, ...] — this device's stage; xs_local is this
        # device's batch shard of every microbatch
        params_one = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs_local.shape[1:]
        # Carry dtype comes from the stage OUTPUT, not the input: a stage_fn
        # whose output dtype differs (bf16 activations -> fp32 head) must not
        # poison the scan carry with the input dtype.
        y_abs = jax.eval_shape(
            stage_fn, params_one,
            jax.ShapeDtypeStruct(mb_shape, xs_local.dtype))
        if y_abs.shape != mb_shape:
            raise ValueError(
                f"pipeline stage_fn must preserve the microbatch shape "
                f"(stage input feeds the next stage): {mb_shape} -> "
                f"{y_abs.shape}")
        carry_dtype = y_abs.dtype

        def tick(carry, t):
            inbound, outputs = carry
            # stage 0 reads microbatch t (clamped); others read inbound
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            first_in = jax.lax.dynamic_index_in_dim(
                xs_local, mb_idx, 0, keepdims=False).astype(carry_dtype)
            x = jnp.where(stage == 0, first_in, inbound)
            active = (t - stage >= 0) & (t - stage < n_micro)
            y = stage_fn(params_one, x).astype(carry_dtype)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # stash final-stage output at slot (t - (n_stages - 1))
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            is_last = stage == n_stages - 1
            take = active & is_last
            upd = jnp.where(take, y,
                            jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                                         keepdims=False))
            outputs = jax.lax.dynamic_update_index_in_dim(outputs, upd,
                                                          out_idx, 0)
            # hand off to the next stage
            inbound = jax.lax.ppermute(y, axis, perm)
            return (inbound, outputs), None

        inbound0 = jnp.zeros(y_abs.shape, carry_dtype)
        outputs0 = jnp.zeros((n_micro,) + y_abs.shape, carry_dtype)
        (_, outputs), _ = jax.lax.scan(tick, (inbound0, outputs0),
                                       jnp.arange(n_ticks))
        # replicate final outputs to all stages: only the last stage's
        # buffer is nonzero, so a psum is a broadcast
        outputs = jax.lax.psum(outputs, axis)
        return outputs

    stacked_spec = jax.tree.map(lambda _: P(axis), stage_params)
    fn = pax.shard_map(per_device, mesh=mesh,
                       in_specs=(stacked_spec, x_spec), out_specs=x_spec,
                       check_vma=False)
    return fn(stage_params, x_micro)


def split_stages(stacked_params, n_stages: int):
    """Reshape unit-stacked params [R, ...] -> [n_stages, R/n_stages, ...]
    so each pipeline stage owns a contiguous depth range."""
    if n_stages < 1:
        raise ValueError(f"split_stages: n_stages must be >= 1, got "
                         f"{n_stages}")

    def resh(a):
        r = a.shape[0]
        if r % n_stages:
            raise ValueError(
                f"split_stages: stacked depth {r} does not divide into "
                f"{n_stages} pipeline stages")
        return a.reshape((n_stages, r // n_stages) + a.shape[1:])

    return jax.tree.map(resh, stacked_params)
