"""GPipe-style pipeline parallelism over a dedicated mesh axis.

For 1000+-node deployments the cross-pod ("pod") axis has the weakest links
(DCN/optical vs intra-pod ICI); pipelining over it replaces per-layer
collectives with one boundary `ppermute` per microbatch per stage
(DESIGN.md §5). This module implements the schedule as a differentiable
lax.scan inside shard_map:

  tick t ∈ [0, n_micro + n_stages - 1):
      stage s computes microbatch (t - s) when 0 <= t-s < n_micro,
      then ppermutes its boundary activation to stage s+1.

Uniform compute per tick (masked when idle) keeps SPMD happy; autodiff
through ppermute/scan gives GPipe's full-stash backward — wrap `stage_fn`
with jax.checkpoint for the standard remat variant. Bubble fraction is the
usual (S-1)/(T+S-1); the runtime chooses n_micro >= 4*S.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel import axes as pax


def pipeline_apply(stage_fn: Callable, stage_params, x_micro, *,
                   mesh: Mesh, axis: str = "pipe"):
    """Run `stage_fn` as a pipeline over mesh axis `axis`.

    stage_fn(params_slice, x: [mb, ...]) -> [mb, ...]   (uniform stages)
    stage_params: pytree stacked on a leading n_stages dim (sharded on axis)
    x_micro: [n_micro, mb, ...] (replicated)
    Returns [n_micro, mb, ...] outputs of the final stage (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_device(params_local, xs_local):
        # params_local: [1, ...] — this device's stage; xs_local replicated
        params_one = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs_local.shape[1:]

        def tick(carry, t):
            inbound, outputs = carry
            # stage 0 reads microbatch t (clamped); others read inbound
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            first_in = jax.lax.dynamic_index_in_dim(xs_local, mb_idx, 0,
                                                    keepdims=False)
            x = jnp.where(stage == 0, first_in, inbound)
            active = (t - stage >= 0) & (t - stage < n_micro)
            y = stage_fn(params_one, x)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # stash final-stage output at slot (t - (n_stages - 1))
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            is_last = stage == n_stages - 1
            take = active & is_last
            upd = jnp.where(take, y,
                            jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                                         keepdims=False))
            outputs = jax.lax.dynamic_update_index_in_dim(outputs, upd,
                                                          out_idx, 0)
            # hand off to the next stage
            inbound = jax.lax.ppermute(y, axis, perm)
            return (inbound, outputs), None

        inbound0 = jnp.zeros(mb_shape, xs_local.dtype)
        outputs0 = jnp.zeros((n_micro,) + mb_shape, xs_local.dtype)
        (_, outputs), _ = jax.lax.scan(tick, (inbound0, outputs0),
                                       jnp.arange(n_ticks))
        # replicate final outputs to all stages: only the last stage's
        # buffer is nonzero, so a psum is a broadcast
        outputs = jax.lax.psum(outputs, axis)
        return outputs

    stacked_spec = jax.tree.map(lambda _: P(axis), stage_params)
    fn = pax.shard_map(per_device, mesh=mesh,
                       in_specs=(stacked_spec, P()), out_specs=P(),
                       check_vma=False)
    return fn(stage_params, x_micro)


def split_stages(stacked_params, n_stages: int):
    """Reshape unit-stacked params [R, ...] -> [n_stages, R/n_stages, ...]
    so each pipeline stage owns a contiguous depth range."""
    def resh(a):
        r = a.shape[0]
        assert r % n_stages == 0, (r, n_stages)
        return a.reshape((n_stages, r // n_stages) + a.shape[1:])
    return jax.tree.map(resh, stacked_params)
