"""Sharding strategies: map every parameter / cache / input leaf to a
PartitionSpec via path-based logical-axis rules.

Default strategy (DESIGN.md §5): batch over ("pod","data"), FSDP (ZeRO-3
style weight sharding, gather-on-use by GSPMD) over "data" on the d_model
dim of every weight, TP over "model" on heads/FFN/vocab/experts-inner dims.
Optimizer state inherits parameter specs (ZeRO).

Strategy knobs the WSMC planner can flip:
  ep       — shard the expert dim over "model" (EP) instead of intra-expert TP
  kv_shard — "heads" | "seq": decode KV-cache layout. kv_heads < 16 pads on
             the model axis, so small-kv archs default to sequence sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.configs.base import ModelConfig
from repro.parallel import axes as pax


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str = "fsdp_tp"
    ep: bool = False
    kv_shard: str = "heads"          # heads | seq
    fsdp: bool = True                # False => pure DP + TP (weights replicated
                                     # over "data"; small models)
    pipeline: bool = False           # layer stack split over the "pipe" axis
                                     # (1F1B runtime schedule owns the stages)

    def rules(self) -> Dict[str, Any]:
        rules = dict(pax.DEFAULT_RULES)
        if self.ep:
            rules["experts"] = "model"
            rules["mlp"] = None
            rules["mlp_act"] = None
        if self.kv_shard == "seq":
            rules["kv_seq"] = "model"
            rules["kv_heads"] = None
        if not self.fsdp:
            rules["embed_w"] = None
        if self.pipeline:
            rules["layers"] = "pipe"
        return rules


def default_strategy(cfg: ModelConfig, mesh: Optional[Mesh] = None) -> Strategy:
    """Pick kv layout, EP and pipelining from the mesh axes."""
    model_size = 16
    pipeline = False
    if mesh is not None and "model" in mesh.axis_names:
        model_size = mesh.shape["model"]
    if mesh is not None and "pipe" in mesh.axis_names:
        pipeline = mesh.shape["pipe"] > 1
    kv = "heads" if cfg.n_kv_heads % model_size == 0 else "seq"
    # EP when the expert count tiles the axis (EXPERIMENTS §Perf llama4:
    # -56% compute vs intra-expert TP); otherwise dense TP inside experts.
    ep = cfg.is_moe and cfg.n_experts % model_size == 0
    return Strategy(kv_shard=kv, ep=ep, pipeline=pipeline)


# ---------------------------------------------------------------------------
# Path-rule resolution
# ---------------------------------------------------------------------------

def _path_names(path) -> Tuple[Tuple[str, ...], bool]:
    """(names along path, stacked?) — stacked = under params['units']."""
    names = []
    stacked = False
    for i, p in enumerate(path):
        if isinstance(p, DictKey):
            names.append(str(p.key))
        elif isinstance(p, SequenceKey):
            names.append(f"[{p.idx}]")
    if names and names[0] == "units":
        stacked = True
    return tuple(names), stacked


# (context, name, effective_ndim) -> logical axes (no leading "layers")
def _param_axes(names, ndim) -> Tuple[Optional[str], ...]:
    name = names[-1]
    in_mixer = "mixer" in names
    in_mlp = "mlp" in names
    if name == "table":
        return ("vocab", "embed_w")
    if name in ("norm", "norm2", "final_norm", "b", "b_i", "b_f"):
        return (None,) * ndim
    if name in ("gate_r", "gate_i", "a_param"):
        return ("lru",)
    if name == "gnorm":
        return ("inner",)
    if name == "router":
        return ("embed_w", "experts")
    if in_mlp and name == "wi":
        return ("experts", "embed_w", "mlp") if ndim == 3 else ("embed_w", "mlp")
    if in_mlp and name == "wo":
        return ("experts", "mlp", "embed_w") if ndim == 3 else ("mlp", "embed_w")
    if in_mixer:
        if name == "wq" and ndim == 3:        # mLSTM block-diagonal q/k
            return ("inner", None, None)
        if name == "wk" and ndim == 3:
            return ("inner", None, None)
        if name == "wq":
            return ("embed_w", "q_w")
        if name in ("wk", "wv"):
            return ("embed_w", "kv_w")
        if name == "wo":
            return ("q_w", "embed_w")
        if name == "w_up":
            return ("embed_w", "inner")
        if name == "w_down":
            return ("inner", "embed_w")
        if name == "conv":
            return (None, "inner") if ndim == 2 else (None,) * ndim
        if name in ("w_i", "w_f"):
            return ("inner", None)
        if name == "w":                       # sLSTM input projection
            return ("embed_w", None)
        if name == "r":                       # sLSTM per-head recurrence
            return (None, None, None)
        if name == "w_ff":
            return ("embed_w", "mlp")
        if name == "w_ff_out":
            return ("mlp", "embed_w")
        if name in ("w_x", "w_y"):
            return ("embed_w", "lru")
        if name == "w_out":
            return ("lru", "embed_w")
    # rglru conv lives under mixer too ("conv" handled above); fallback:
    return (None,) * ndim


def _cache_axes(name, ndim) -> Tuple[Optional[str], ...]:
    if name in ("k", "v"):
        return ("batch", "kv_seq", "kv_heads", None)
    if name == "pos":
        return ("batch", "kv_seq")
    # recurrent states: batch-sharded only (small vs KV caches)
    return ("batch",) + (None,) * (ndim - 1)


# ---------------------------------------------------------------------------
# Spec-tree builders
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, abstract_params, strategy: Strategy,
                mesh: Mesh):
    rules = strategy.rules()

    def leaf_spec(path, leaf):
        names, stacked = _path_names(path)
        ndim = leaf.ndim - (1 if stacked else 0)
        logical = _param_axes(names, ndim)
        if stacked:
            logical = ("layers",) + logical
        return pax.logical_to_spec(logical, rules=rules, mesh=mesh,
                                   shape=leaf.shape)

    return jax.tree_util.tree_map_with_path(leaf_spec, abstract_params)


def cache_specs(cfg: ModelConfig, abstract_cache, strategy: Strategy,
                mesh: Mesh):
    rules = strategy.rules()

    def leaf_spec(path, leaf):
        names, stacked = _path_names(path)
        ndim = leaf.ndim - (1 if stacked else 0)
        logical = _cache_axes(names[-1], ndim)
        if stacked:
            logical = ("layers",) + logical
        return pax.logical_to_spec(logical, rules=rules, mesh=mesh,
                                   shape=leaf.shape)

    return jax.tree_util.tree_map_with_path(leaf_spec, abstract_cache)


def input_specs_sharding(inputs, strategy: Strategy, mesh: Mesh):
    rules = strategy.rules()

    def spec_for(name, leaf):
        if name in ("tokens", "targets"):
            logical = ("batch", None)
        elif name == "positions":
            logical = ("batch",)
        elif name == "prefix_embeds":
            logical = ("batch", None, None)
        else:
            logical = (None,) * leaf.ndim
        return pax.logical_to_spec(logical, rules=rules, mesh=mesh,
                                   shape=leaf.shape)

    return {k: spec_for(k, v) for k, v in inputs.items()}


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def scalar_spec(mesh: Mesh):
    return NamedSharding(mesh, P())
