"""The serving engine: WSMC-governed continuous batching over a slotted
KV pool.

The scheduler is deliberately jax-free: it speaks to the model through an
executor protocol (``prefill(slot, prompt) -> first_token``,
``decode(tokens, positions) -> next_tokens``) so the admission /
claim-free / accounting core is a deterministic state machine the hermetic
test tier can drive with a scripted executor, while the real
`serving.executor.JaxExecutor` runs jitted prefill-into-slot and batched
heterogeneous-position decode over the ring-cache pool.

Memory governance (the paper's loop run backwards): the engine never holds
more concurrent sequences than its slot count, and the slot count is
derived from `predictor.serving_capacity` — the capacity model's
prediction of how many sequences fit the per-device HBM budget
(`search.execplan.plan_serving`). Oversubscribed requests wait in the
queue; admission is the memory model acting as the runtime's admission
controller rather than an offline advisor.

Two admission policies share every other line of the loop:

  continuous — claim any free slot the moment a queued request can take it
               (per-slot backfill; this is continuous batching).
  static     — the fixed-batch baseline: admit a full batch only when the
               pool is completely idle, then run it to completion. Mixed
               generation lengths leave stragglers pinning idle slots,
               which is exactly the occupancy gap the benchmark reports.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional, Sequence, Tuple

from repro.serving.trace import Request

POLICIES = ("continuous", "static")


@dataclasses.dataclass
class _Active:
    """One claimed slot: the request plus its decode cursor."""
    req: Request
    admitted: int                # engine tick of admission
    pos: int                     # next decode position (== tokens emitted + prompt)
    remaining: int               # decode steps still owed
    tokens: List[int]            # generated so far (first from prefill)


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    tokens: Tuple[int, ...] = ()
    arrival: int = 0
    admitted: int = 0
    finished: int = 0

    @property
    def latency(self) -> int:
        """Ticks from arrival to last token (queueing + decode)."""
        return self.finished - self.arrival

    @property
    def queue_delay(self) -> int:
        return self.admitted - self.arrival


@dataclasses.dataclass
class ServeReport:
    """Deterministic step-counted serving metrics for one trace replay."""
    policy: str
    n_slots: int
    completions: List[Completion]
    ticks: int                   # total engine ticks elapsed
    decode_ticks: int            # ticks that executed a batched decode step
    useful_slot_tokens: int      # sum over decode ticks of active slots
    idle_ticks: int              # ticks that neither admitted nor decoded
    peak_queue: int
    max_concurrent: int
    prefills: int

    @property
    def generated_tokens(self) -> int:
        return sum(len(c.tokens) for c in self.completions)

    def occupancy(self) -> float:
        """Useful-token fraction of decode-step slots: of all the slot
        positions the batched decode steps computed, how many produced a
        token a request actually wanted."""
        denom = self.decode_ticks * self.n_slots
        return self.useful_slot_tokens / denom if denom else 0.0

    def throughput(self) -> float:
        """Generated tokens per engine tick."""
        return self.generated_tokens / self.ticks if self.ticks else 0.0

    def mean_latency(self) -> float:
        if not self.completions:
            return 0.0
        return sum(c.latency for c in self.completions) / len(self.completions)

    def describe(self) -> str:
        return (f"[{self.policy}] slots={self.n_slots} "
                f"completed={len(self.completions)} "
                f"tokens={self.generated_tokens} ticks={self.ticks} "
                f"occupancy={self.occupancy():.3f} "
                f"throughput={self.throughput():.2f} tok/tick "
                f"mean_latency={self.mean_latency():.1f} ticks "
                f"peak_queue={self.peak_queue} "
                f"max_concurrent={self.max_concurrent}")


class ScriptedExecutor:
    """Deterministic jax-free executor: closed-form token functions stand in
    for the model so the scheduler core (admission, claim/free, metrics)
    can be pinned by the hermetic test tier and compared across policies
    without a single compile."""

    def __init__(self, vocab_size: int = 97):
        self.vocab_size = vocab_size
        self.prefills = 0
        self.decodes = 0

    def prefill(self, slot: int, prompt: Sequence[int]) -> int:
        self.prefills += 1
        return (sum(prompt) + 31 * len(prompt)) % self.vocab_size

    def decode(self, tokens: Sequence[int], positions: Sequence[int]
               ) -> List[int]:
        self.decodes += 1
        return [(17 * t + 7 * p + 13) % self.vocab_size
                for t, p in zip(tokens, positions)]


class Engine:
    """Continuous-batching serving engine over a slotted KV pool.

    `n_slots` is the admission bound — by construction the engine never
    runs more concurrent sequences than slots, so sizing it from
    `ServingPlan.slots()` makes `predictor.serving_capacity` the admission
    controller. One `run()` call replays one trace to completion.
    """

    def __init__(self, executor, n_slots: int, policy: str = "continuous"):
        if n_slots < 1:
            raise ValueError(f"Engine needs n_slots >= 1, got {n_slots} "
                             "(serving_capacity said nothing fits — lower "
                             "the context or raise the budget)")
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
        self.executor = executor
        self.n_slots = int(n_slots)
        self.policy = policy

    # -- scheduling core ---------------------------------------------------

    def _admit(self, queue: Deque[Request], slots: List[Optional[_Active]],
               tick: int) -> int:
        """Claim free slots for queued requests under the active policy.
        Returns the number of admissions (each one a prefill)."""
        if self.policy == "static" and any(s is not None for s in slots):
            return 0                      # fixed batch: wait for the pool
        admitted = 0
        for i in range(self.n_slots):
            if not queue:
                break
            if slots[i] is not None:
                continue
            req = queue.popleft()
            first = int(self.executor.prefill(i, req.prompt))
            slots[i] = _Active(req=req, admitted=tick, pos=len(req.prompt),
                               remaining=req.max_new - 1, tokens=[first])
            admitted += 1
        return admitted

    def run(self, trace: Sequence[Request],
            max_ticks: int = 1_000_000) -> ServeReport:
        for r in trace:                      # fail fast, not at max_ticks
            if r.max_new < 1 or not r.prompt:
                raise ValueError(f"request {r.rid}: needs a non-empty "
                                 f"prompt and max_new >= 1 (got "
                                 f"prompt_len={len(r.prompt)}, "
                                 f"max_new={r.max_new})")
        pending: Deque[Request] = collections.deque(
            sorted(trace, key=lambda r: (r.arrival, r.rid)))
        queue: Deque[Request] = collections.deque()
        slots: List[Optional[_Active]] = [None] * self.n_slots
        completions: List[Completion] = []
        tick = decode_ticks = useful = idle = 0
        peak_queue = max_concurrent = prefills = 0

        def finish(i: int, when: int) -> None:
            a = slots[i]
            completions.append(Completion(
                rid=a.req.rid, tokens=tuple(a.tokens),
                arrival=a.req.arrival, admitted=a.admitted, finished=when))
            slots[i] = None

        while pending or queue or any(s is not None for s in slots):
            if tick >= max_ticks:
                raise RuntimeError(f"engine exceeded max_ticks={max_ticks}")
            while pending and pending[0].arrival <= tick:
                queue.append(pending.popleft())
            prefills += self._admit(queue, slots, tick)
            peak_queue = max(peak_queue, len(queue))
            concurrent = sum(s is not None for s in slots)
            max_concurrent = max(max_concurrent, concurrent)
            # single-token requests complete at admission (prefill emitted
            # their only token)
            for i in range(self.n_slots):
                if slots[i] is not None and slots[i].remaining == 0:
                    finish(i, tick)
            active = [i for i in range(self.n_slots) if slots[i] is not None]
            if active:
                tokens = [slots[i].tokens[-1] if slots[i] is not None else 0
                          for i in range(self.n_slots)]
                positions = [slots[i].pos if slots[i] is not None else 0
                             for i in range(self.n_slots)]
                nxt = self.executor.decode(tokens, positions)
                decode_ticks += 1
                useful += len(active)
                for i in active:
                    a = slots[i]
                    a.tokens.append(int(nxt[i]))
                    a.pos += 1
                    a.remaining -= 1
                    if a.remaining == 0:
                        finish(i, tick)
            elif concurrent == 0:
                idle += 1        # nothing admitted or decoding this tick
            tick += 1

        completions.sort(key=lambda c: c.rid)
        return ServeReport(policy=self.policy, n_slots=self.n_slots,
                           completions=completions, ticks=tick,
                           decode_ticks=decode_ticks,
                           useful_slot_tokens=useful, idle_ticks=idle,
                           peak_queue=peak_queue,
                           max_concurrent=max_concurrent, prefills=prefills)
