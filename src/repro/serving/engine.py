"""The serving engine: WSMC-governed continuous batching over a slotted
KV pool.

The scheduler is deliberately jax-free: it speaks to the model through an
executor protocol (``prefill_batch(slots, prompts, tables=None) ->
first_tokens``, ``decode(tokens, positions, tables=None, lanes=None) ->
next_tokens``, ``fresh_blocks(ids)``, plus the optional
``decode_width(n_active)`` width probe and ``prefill_chunks(lanes,
chunks, starts, tables, final)`` for chunked prefill) so the admission /
claim-free /
accounting core is a deterministic state machine the hermetic test tier
can drive with a scripted executor, while the real
`serving.executor.JaxExecutor` / `PagedJaxExecutor` run jitted batched
prefill and batched heterogeneous-position decode over the ring-slot or
paged block pool.

Memory governance (the paper's loop run backwards): the engine never holds
more concurrent sequences than its slot count, and the slot count is
derived from `predictor.serving_capacity` — the capacity model's
prediction of how many sequences fit the per-device HBM budget
(`search.execplan.plan_serving`). Oversubscribed requests wait in the
queue; admission is the memory model acting as the runtime's admission
controller rather than an offline advisor.

Two admission policies share every other line of the loop:

  continuous — claim any free slot the moment a queued request can take it
               (per-slot backfill; this is continuous batching).
  static     — the fixed-batch baseline: admit a full batch only when the
               pool is completely idle, then run it to completion. Mixed
               generation lengths leave stragglers pinning idle slots,
               which is exactly the occupancy gap the benchmark reports.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.serving.trace import Request

POLICIES = ("continuous", "static")


class BlockAllocator:
    """jax-free free-list allocator over the paged KV block pool.

    Physical ids run 1..n_blocks (id 0 is the executor's scratch block for
    inactive decode lanes — never handed out). Admission reserves a
    request's WORST-CASE OWN footprint up front (`blocks_for`: the blocks
    its prompt + max_new positions can ever write — short requests reserve
    few blocks, which is the whole win over whole-context ring slots) and
    physical blocks are allocated lazily as decode crosses block
    boundaries, so `alloc` inside a reservation can never fail and the
    engine can never deadlock mid-decode. `free` returns a completed
    request's blocks to the pool for immediate reuse.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 1:
            raise ValueError(f"BlockAllocator needs n_blocks >= 1, got "
                             f"{n_blocks} (serving_block_capacity said "
                             "nothing fits — raise the budget)")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self._free: Deque[int] = collections.deque(range(1, n_blocks + 1))
        self._owned: Dict[int, List[int]] = {}     # rid -> physical ids
        self._reserved: Dict[int, int] = {}        # rid -> total reservation
        self.committed = 0                         # sum of live reservations
        self.peak_in_use = 0
        self.peak_committed = 0

    def blocks_for(self, req: Request) -> int:
        """Worst-case blocks `req` can ever hold: its written positions are
        0..prompt+max_new-2 (the last generated token is never cached)."""
        written = len(req.prompt) + req.max_new - 1
        return max(-(-written // self.block_size), 1)

    def can_admit(self, n: int) -> bool:
        return self.committed + n <= self.n_blocks

    def reserve(self, rid: int, n: int) -> None:
        if not self.can_admit(n):
            raise RuntimeError(f"reserve({rid}) over-commits the pool")
        if rid in self._reserved:
            raise RuntimeError(f"request {rid} already holds a reservation")
        self._reserved[rid] = n
        self._owned[rid] = []
        self.committed += n
        self.peak_committed = max(self.peak_committed, self.committed)

    def alloc(self, rid: int) -> int:
        if len(self._owned[rid]) >= self._reserved[rid]:
            raise RuntimeError(f"request {rid} exceeded its reservation")
        bid = self._free.popleft()       # cannot be empty: see class doc
        self._owned[rid].append(bid)
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return bid

    def free(self, rid: int) -> List[int]:
        ids = self._owned.pop(rid)
        self.committed -= self._reserved.pop(rid)
        self._free.extend(ids)           # FIFO reuse: deterministic
        return ids

    @property
    def in_use(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def free_blocks(self) -> int:
        return len(self._free)


@dataclasses.dataclass
class _Active:
    """One claimed slot: the request plus its decode cursor."""
    req: Request
    admitted: int                # engine tick of admission
    pos: int                     # next decode position (== tokens emitted + prompt)
    remaining: int               # decode steps still owed
    tokens: List[int]            # generated so far (first from prefill)
    table: List[int] = dataclasses.field(default_factory=list)  # paged: phys block ids
    pending: Tuple[int, ...] = ()  # prompt tail not yet prefilled (chunked)


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    tokens: Tuple[int, ...] = ()
    arrival: int = 0
    admitted: int = 0
    finished: int = 0

    @property
    def latency(self) -> int:
        """Ticks from arrival to last token (queueing + decode)."""
        return self.finished - self.arrival

    @property
    def queue_delay(self) -> int:
        return self.admitted - self.arrival


@dataclasses.dataclass
class ServeReport:
    """Deterministic step-counted serving metrics for one trace replay."""
    policy: str
    n_slots: int
    completions: List[Completion]
    ticks: int                   # total engine ticks elapsed
    decode_ticks: int            # ticks that executed a batched decode step
    useful_slot_tokens: int      # sum over decode ticks of active slots
    idle_ticks: int              # ticks with no admission, prefill chunk,
                                 # or decode (pure waiting)
    peak_queue: int
    max_concurrent: int
    prefills: int
    prefill_calls: int = 0       # batched prefill invocations (<= prefills)
    n_blocks: int = 0            # paged pool size (0 = ring slots)
    peak_blocks: int = 0         # peak physical blocks in use (paged)
    admit_ticks: int = 0         # ticks that only admitted / chunked a
                                 # prompt (no decode) — the invariant is
                                 # ticks == decode + admit + idle
    decode_lane_tokens: int = 0  # sum over decode ticks of the width the
                                 # executor actually computed at (== n_slots
                                 # x decode_ticks without lane compaction)
    chunk_calls: int = 0         # batched chunk-prefill invocations

    @property
    def generated_tokens(self) -> int:
        return sum(len(c.tokens) for c in self.completions)

    def occupancy(self) -> float:
        """Useful-token fraction of decode-step slots: of all the slot
        positions the batched decode steps computed, how many produced a
        token a request actually wanted. Lane compaction shrinks the
        denominator to the widths actually run."""
        denom = self.decode_lane_tokens or self.decode_ticks * self.n_slots
        return self.useful_slot_tokens / denom if denom else 0.0

    def throughput(self) -> float:
        """Generated tokens per engine tick."""
        return self.generated_tokens / self.ticks if self.ticks else 0.0

    def mean_latency(self) -> float:
        if not self.completions:
            return 0.0
        return sum(c.latency for c in self.completions) / len(self.completions)

    def block_occupancy(self) -> float:
        """Paged pools: peak fraction of physical blocks in use."""
        return self.peak_blocks / self.n_blocks if self.n_blocks else 0.0

    def describe(self) -> str:
        paged = (f" blocks={self.peak_blocks}/{self.n_blocks}"
                 if self.n_blocks else "")
        if self.decode_ticks and self.decode_lane_tokens:
            paged += (f" mean_width="
                      f"{self.decode_lane_tokens / self.decode_ticks:.1f}")
        if self.chunk_calls:
            paged += f" chunk_calls={self.chunk_calls}"
        return (f"[{self.policy}] slots={self.n_slots} "
                f"completed={len(self.completions)} "
                f"tokens={self.generated_tokens} ticks={self.ticks} "
                f"occupancy={self.occupancy():.3f} "
                f"throughput={self.throughput():.2f} tok/tick "
                f"mean_latency={self.mean_latency():.1f} ticks "
                f"peak_queue={self.peak_queue} "
                f"max_concurrent={self.max_concurrent}"
                f"{paged}")


class ScriptedExecutor:
    """Deterministic jax-free executor: closed-form token functions stand in
    for the model so the scheduler core (admission, claim/free, metrics)
    can be pinned by the hermetic test tier and compared across policies
    (and ring vs paged, compacted vs full-width, chunked vs whole-prompt
    prefill) without a single compile. `buckets` emulates the paged
    executor's lane compaction: decode_width returns the smallest covering
    bucket and every decode tick's width is recorded in `tick_widths`."""

    def __init__(self, vocab_size: int = 97,
                 buckets: Optional[Sequence[int]] = None):
        self.vocab_size = vocab_size
        self.buckets = tuple(sorted(buckets)) if buckets else None
        self.prefills = 0
        self.prefill_batches = 0
        self.decodes = 0
        self.chunk_calls = 0
        self.tick_widths: List[int] = []
        self._partial: Dict[int, List[int]] = {}   # lane -> prompt so far

    def prefill(self, slot: int, prompt: Sequence[int]) -> int:
        self.prefills += 1
        return (sum(prompt) + 31 * len(prompt)) % self.vocab_size

    def prefill_batch(self, slots: Sequence[int],
                      prompts: Sequence[Sequence[int]],
                      tables: Optional[Sequence[Sequence[int]]] = None
                      ) -> List[int]:
        self.prefill_batches += 1
        return [self.prefill(s, p) for s, p in zip(slots, prompts)]

    def prefill_chunks(self, lanes: Sequence[int],
                       chunks: Sequence[Sequence[int]],
                       starts: Sequence[int],
                       tables: Optional[Sequence[Sequence[int]]] = None,
                       final: Optional[Sequence[bool]] = None) -> List[int]:
        """Accumulate chunks per lane; on a lane's final chunk emit exactly
        what a whole-prompt prefill of the accumulated tokens would — so
        chunked and unchunked scheduling are token-identical by
        construction, like the real executor."""
        self.chunk_calls += 1
        out: List[int] = []
        for j, lane in enumerate(lanes):
            acc = self._partial.setdefault(lane, [])
            acc.extend(chunks[j])
            if final is not None and final[j]:
                out.append(self.prefill(lane, self._partial.pop(lane)))
            else:
                out.append(0)
        return out

    def fresh_blocks(self, ids: Sequence[int]) -> None:
        pass                                 # no physical pool to invalidate

    def decode_width(self, n_active: int) -> Optional[int]:
        """None = no compaction (the engine charges full pool width)."""
        if self.buckets is None:
            return None
        for b in self.buckets:
            if b >= n_active:
                return b
        return self.buckets[-1]

    def decode(self, tokens: Sequence[int], positions: Sequence[int],
               tables: Optional[Sequence[Sequence[int]]] = None,
               lanes: Optional[Sequence[int]] = None) -> List[int]:
        self.decodes += 1
        n_active = len(lanes) if lanes is not None else len(tokens)
        width = self.decode_width(n_active)
        self.tick_widths.append(width if width is not None else len(tokens))
        return [(17 * t + 7 * p + 13) % self.vocab_size
                for t, p in zip(tokens, positions)]


class Engine:
    """Continuous-batching serving engine over a slotted or paged KV pool.

    `n_slots` is the admission bound — by construction the engine never
    runs more concurrent sequences than slots, so sizing it from
    `ServingPlan.slots()` makes `predictor.serving_capacity` the admission
    controller. With a `BlockAllocator` the slots become decode LANES and
    admission additionally requires the request's block reservation to fit
    the pool (`serving_block_capacity` run as the admission controller):
    short requests reserve few blocks, so many more of them fit the same
    HBM budget than worst-case ring slots would admit. One `run()` call
    replays one trace to completion.
    """

    def __init__(self, executor, n_slots: int, policy: str = "continuous",
                 allocator: Optional[BlockAllocator] = None,
                 chunk_prefill: int = 0):
        if n_slots < 1:
            raise ValueError(f"Engine needs n_slots >= 1, got {n_slots} "
                             "(serving_capacity said nothing fits — lower "
                             "the context or raise the budget)")
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
        if chunk_prefill < 0:
            raise ValueError(f"chunk_prefill must be >= 0, got "
                             f"{chunk_prefill}")
        if (chunk_prefill and allocator is not None
                and chunk_prefill % allocator.block_size):
            raise ValueError(f"chunk_prefill={chunk_prefill} must be a "
                             f"multiple of the kv block size "
                             f"{allocator.block_size}")
        self.executor = executor
        self.n_slots = int(n_slots)
        self.policy = policy
        self.allocator = allocator
        # prompts longer than this prefill `chunk_prefill` positions per
        # tick (0 = whole-prompt prefill at admission)
        self.chunk_prefill = int(chunk_prefill)

    # -- scheduling core ---------------------------------------------------

    def _admit(self, queue: Deque[Request], slots: List[Optional[_Active]],
               tick: int) -> Tuple[int, int]:
        """Claim free slots for queued requests under the active policy.
        Admissions landing in the same tick and prompt bucket share ONE
        padded prefill call (engine-level batched prefill). Returns
        (admissions, prefill calls)."""
        if self.policy == "static" and any(s is not None for s in slots):
            return 0, 0                   # fixed batch: wait for the pool
        alloc = self.allocator
        picked: List[Tuple[int, Request]] = []
        for i in range(self.n_slots):
            if not queue:
                break
            if slots[i] is not None:
                continue
            req = queue[0]
            if alloc is not None:
                need = alloc.blocks_for(req)
                if not alloc.can_admit(need):
                    break                 # FIFO: no overtaking the head
                alloc.reserve(req.rid, need)
            picked.append((i, queue.popleft()))
        if not picked:
            return 0, 0
        by_len: Dict[int, List[Tuple[int, Request]]] = {}
        for i, req in picked:
            if self.chunk_prefill and len(req.prompt) > self.chunk_prefill:
                # chunked admission: the lane is claimed now but its prompt
                # is appended chunk-by-chunk by _advance_chunks (no decode
                # cursor yet — remaining counts ALL owed tokens)
                slots[i] = _Active(req=req, admitted=tick, pos=0,
                                   remaining=req.max_new, tokens=[],
                                   pending=tuple(req.prompt))
                continue
            by_len.setdefault(len(req.prompt), []).append((i, req))
        if not by_len:
            return len(picked), 0
        calls = 0
        for plen in sorted(by_len):
            group = by_len[plen]
            lanes = [i for i, _ in group]
            prompts = [req.prompt for _, req in group]
            tables = None
            if alloc is not None:
                tables = []
                for i, req in group:
                    nb0 = max(-(-plen // alloc.block_size), 1)
                    tables.append([alloc.alloc(req.rid)
                                   for _ in range(nb0)])
            firsts = self.executor.prefill_batch(lanes, prompts,
                                                 tables=tables)
            calls += 1
            for gi, (i, req) in enumerate(group):
                slots[i] = _Active(req=req, admitted=tick, pos=plen,
                                   remaining=req.max_new - 1,
                                   tokens=[int(firsts[gi])],
                                   table=(tables[gi] if tables is not None
                                          else []))
        return len(picked), calls

    def _advance_chunks(self, slots: List[Optional[_Active]]) -> int:
        """Advance every mid-prefill lane by one prompt chunk in ONE
        batched call (blocks allocated lazily per chunk, freshly re-linked
        ones invalidated first). A lane whose final chunk lands gets its
        first token and decode cursor. Returns chunk calls made (0/1)."""
        lanes = [i for i in range(self.n_slots)
                 if slots[i] is not None and slots[i].pending]
        if not lanes:
            return 0
        alloc = self.allocator
        chunks, starts, tables, final = [], [], [], []
        fresh: List[int] = []
        for i in lanes:
            a = slots[i]
            start = len(a.req.prompt) - len(a.pending)
            c = a.pending[:self.chunk_prefill]
            a.pending = a.pending[self.chunk_prefill:]
            if alloc is not None:
                last = start + len(c) - 1
                while last // alloc.block_size >= len(a.table):
                    bid = alloc.alloc(a.req.rid)
                    a.table.append(bid)
                    fresh.append(bid)
            chunks.append(c)
            starts.append(start)
            tables.append(list(a.table))
            final.append(not a.pending)
        if fresh:
            self.executor.fresh_blocks(fresh)
        firsts = self.executor.prefill_chunks(
            lanes, chunks, starts,
            tables=(tables if alloc is not None else None), final=final)
        for j, i in enumerate(lanes):
            a = slots[i]
            if final[j]:
                a.tokens = [int(firsts[j])]
                a.pos = len(a.req.prompt)
                a.remaining = a.req.max_new - 1
        return 1

    def run(self, trace: Sequence[Request],
            max_ticks: int = 1_000_000) -> ServeReport:
        for r in trace:                      # fail fast, not at max_ticks
            if r.max_new < 1 or not r.prompt:
                raise ValueError(f"request {r.rid}: needs a non-empty "
                                 f"prompt and max_new >= 1 (got "
                                 f"prompt_len={len(r.prompt)}, "
                                 f"max_new={r.max_new})")
            if (self.allocator is not None
                    and self.allocator.blocks_for(r) > self.allocator.n_blocks):
                raise ValueError(
                    f"request {r.rid} needs {self.allocator.blocks_for(r)} "
                    f"KV blocks but the pool holds "
                    f"{self.allocator.n_blocks} — it could never be "
                    "admitted (raise the budget or shrink the context)")
        pending: Deque[Request] = collections.deque(
            sorted(trace, key=lambda r: (r.arrival, r.rid)))
        queue: Deque[Request] = collections.deque()
        slots: List[Optional[_Active]] = [None] * self.n_slots
        completions: List[Completion] = []
        tick = decode_ticks = useful = idle = 0
        admit_only = lane_tokens = chunk_calls = 0
        peak_queue = max_concurrent = prefills = prefill_calls = 0
        alloc = self.allocator

        def finish(i: int, when: int) -> None:
            a = slots[i]
            completions.append(Completion(
                rid=a.req.rid, tokens=tuple(a.tokens),
                arrival=a.req.arrival, admitted=a.admitted, finished=when))
            if alloc is not None:
                alloc.free(a.req.rid)
            slots[i] = None

        while pending or queue or any(s is not None for s in slots):
            if tick >= max_ticks:
                raise RuntimeError(f"engine exceeded max_ticks={max_ticks}")
            while pending and pending[0].arrival <= tick:
                queue.append(pending.popleft())
            admitted, calls = self._admit(queue, slots, tick)
            prefills += admitted
            prefill_calls += calls
            chunked = (self._advance_chunks(slots) if self.chunk_prefill
                       else 0)
            chunk_calls += chunked
            peak_queue = max(peak_queue, len(queue))
            concurrent = sum(s is not None for s in slots)
            max_concurrent = max(max_concurrent, concurrent)
            # single-token requests complete at admission (prefill emitted
            # their only token)
            for i in range(self.n_slots):
                if slots[i] is not None and slots[i].remaining == 0:
                    finish(i, tick)
            # mid-prefill lanes hold a slot but have no decode cursor yet
            active = [i for i in range(self.n_slots)
                      if slots[i] is not None and not slots[i].pending]
            if active:
                tokens = [slots[i].tokens[-1]
                          if slots[i] is not None and slots[i].tokens else 0
                          for i in range(self.n_slots)]
                positions = [slots[i].pos if slots[i] is not None else 0
                             for i in range(self.n_slots)]
                if alloc is not None:
                    # allocate-on-decode-tick: a lane crossing into a new
                    # logical block gets a physical block from the free
                    # list (its reservation guarantees one) — freshly
                    # re-linked blocks are invalidated first so a previous
                    # owner's positions can't leak through the mask
                    fresh: List[int] = []
                    for i in active:
                        a = slots[i]
                        while a.pos // alloc.block_size >= len(a.table):
                            bid = alloc.alloc(a.req.rid)
                            a.table.append(bid)
                            fresh.append(bid)
                    if fresh:
                        self.executor.fresh_blocks(fresh)
                    tables = [slots[i].table if slots[i] is not None else []
                              for i in range(self.n_slots)]
                    nxt = self.executor.decode(tokens, positions,
                                               tables=tables, lanes=active)
                else:
                    nxt = self.executor.decode(tokens, positions,
                                               lanes=active)
                decode_ticks += 1
                useful += len(active)
                width_fn = getattr(self.executor, "decode_width", None)
                width = width_fn(len(active)) if width_fn else None
                lane_tokens += width if width is not None else self.n_slots
                for i in active:
                    a = slots[i]
                    a.tokens.append(int(nxt[i]))
                    a.pos += 1
                    a.remaining -= 1
                    if a.remaining == 0:
                        finish(i, tick)
            elif admitted or chunked:
                # at-admission completions / prompt chunks did real work
                # this tick even though no decode ran — the taxonomy
                # invariant is ticks == decode + admit + idle
                admit_only += 1
            else:
                idle += 1        # pure waiting on arrivals
            tick += 1

        completions.sort(key=lambda c: c.rid)
        return ServeReport(policy=self.policy, n_slots=self.n_slots,
                           completions=completions, ticks=tick,
                           decode_ticks=decode_ticks,
                           useful_slot_tokens=useful, idle_ticks=idle,
                           peak_queue=peak_queue,
                           max_concurrent=max_concurrent, prefills=prefills,
                           prefill_calls=prefill_calls,
                           n_blocks=(alloc.n_blocks if alloc else 0),
                           peak_blocks=(alloc.peak_in_use if alloc else 0),
                           admit_ticks=admit_only,
                           decode_lane_tokens=lane_tokens,
                           chunk_calls=chunk_calls)
