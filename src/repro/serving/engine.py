"""The serving engine: WSMC-governed continuous batching over a slotted
KV pool.

The scheduler is deliberately jax-free: it speaks to the model through an
executor protocol (``prefill_batch(slots, prompts, tables=None) ->
first_tokens``, ``decode(tokens, positions, tables=None, lanes=None) ->
next_tokens``, ``fresh_blocks(ids)``, plus the optional
``decode_width(n_active)`` width probe and ``prefill_chunks(lanes,
chunks, starts, tables, final)`` for chunked prefill) so the admission /
claim-free /
accounting core is a deterministic state machine the hermetic test tier
can drive with a scripted executor, while the real
`serving.executor.JaxExecutor` / `PagedJaxExecutor` run jitted batched
prefill and batched heterogeneous-position decode over the ring-slot or
paged block pool.

Memory governance (the paper's loop run backwards): the engine never holds
more concurrent sequences than its slot count, and the slot count is
derived from `predictor.serving_capacity` — the capacity model's
prediction of how many sequences fit the per-device HBM budget
(`search.execplan.plan_serving`). Oversubscribed requests wait in the
queue; admission is the memory model acting as the runtime's admission
controller rather than an offline advisor.

Overload governance rides on the `BlockAllocator` reservation ledger:

  reservation="worst"    — admission reserves every block the request can
                           ever write (`blocks_for`), so lazy per-tick
                           allocation can never fail and nothing is ever
                           preempted. Deadlock-free by construction; the
                           expected-vs-worst-case headroom goes unadmitted.
  reservation="expected" — optimistic admission: reserve `E[blocks] +
                           k·sigma` from the trace's length distribution
                           (`trace.length_stats` — the paper's
                           workload-specific prediction applied online)
                           and let decode overdraft. When the free list
                           runs dry the engine EVICTS the victim chosen by
                           SLO class then lowest progress, frees its
                           non-shared blocks, and requeues it for chunked
                           re-prefill from its already-emitted tokens —
                           greedy decode is deterministic, so the replayed
                           request emits the same stream it would have.

Refcounted prefix sharing (`prefix_share=True`): requests carrying a
common system prompt (`Request.prefix_id`) map their leading
`prefix_len // block_size` table entries to shared physical blocks — one
prefill per unique prefix; the boundary partial block is private per
request (copy-on-write by recompute: the suffix chunk rewrites it into
an owned block). Decode can never write a shared block: a sharer's write
positions satisfy `pos >= prefix_len >= shared_blocks * block_size`.

Two admission policies share every other line of the loop:

  continuous — claim any free slot the moment a queued request can take it
               (per-slot backfill; this is continuous batching).
  static     — the fixed-batch baseline: admit a full batch only when the
               pool is completely idle, then run it to completion. Mixed
               generation lengths leave stragglers pinning idle slots,
               which is exactly the occupancy gap the benchmark reports.
"""
from __future__ import annotations

import collections
import dataclasses
import json
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

from repro.serving.trace import LengthStats, Request

POLICIES = ("continuous", "static")
RESERVATIONS = ("worst", "expected")
AUDIT_MODES = ("off", "strict", "count")


class PoolExhausted(RuntimeError):
    """The free list is empty and no unreferenced cached prefix remains to
    reclaim. Reachable under reservation="expected" (worst-case
    reservations guarantee a free block for every legal alloc) or after a
    mid-run `shrink` retired blocks out from under worst-case
    reservations — the engine answers by evicting a victim and retrying."""


class DoubleFree(RuntimeError):
    """A block (or a request's whole holding) was returned to the free
    list twice — the ledger corruption `BlockAllocator.free`/`free_block`
    refuse to commit silently."""


class NegativeRefcount(RuntimeError):
    """A prefix release would drive its refcount negative (released more
    times than acquired)."""


class AllocationFault(RuntimeError):
    """A TRANSIENT allocation failure injected by the chaos harness
    (`serving.faults.ChaosAllocator`): the allocator refused a block it
    may well have. Unlike `PoolExhausted` this is not a capacity signal —
    the engine defers the lane (or rolls back the admission) and retries
    next tick instead of evicting."""


class TransientExecutorError(RuntimeError):
    """One executor call failed transiently (chaos-injected or a real
    device hiccup). Raised BEFORE the executor mutates any state, so the
    engine's bounded retry-with-backoff replays the exact same call."""


class EngineFault(RuntimeError):
    """The engine gave up: more consecutive transient executor faults
    than `max_exec_retries` allows."""


class LedgerCorruption(RuntimeError):
    """The every-tick ledger auditor found a broken invariant (audit
    mode "strict" — production mode "count" degrades this to a
    counter in `ServeReport.audit_failures`)."""


class BlockAllocator:
    """jax-free free-list allocator over the paged KV block pool.

    Physical ids run 1..n_blocks (id 0 is the executor's scratch block for
    inactive decode lanes — never handed out). Admission reserves a
    request's OWN footprint up front and physical blocks are allocated
    lazily as decode crosses block boundaries. Under the default
    `reservation="worst"` the reservation is `blocks_for` (every block the
    request can ever write), so `alloc` inside a reservation can never
    fail and the engine can never deadlock mid-decode; `free` returns a
    completed request's blocks to the pool for immediate reuse. Under
    `reservation="expected"` the engine reserves its safety-margined
    expected footprint instead and `alloc` may overdraft past it — when
    the free list is empty and no cached prefix is reclaimable, `alloc`
    raises `PoolExhausted` and the engine evicts.

    Shared prefixes are refcounted side ledgers: `create_prefix` carves
    blocks out of the free list, `acquire_prefix`/`release_prefix` track
    the requests reading through them, and a prefix at refcount 0 stays
    CACHED (a later request re-acquires it without re-prefilling) but is
    reclaimable under pressure. `committed` counts reservations plus
    referenced prefix blocks — cached-but-unreferenced prefixes are free
    capacity as far as admission is concerned.
    """

    def __init__(self, n_blocks: int, block_size: int,
                 reservation: str = "worst"):
        if n_blocks < 1:
            raise ValueError(f"BlockAllocator needs n_blocks >= 1, got "
                             f"{n_blocks} (serving_block_capacity said "
                             "nothing fits — raise the budget)")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if reservation not in RESERVATIONS:
            raise ValueError(f"unknown reservation mode {reservation!r}; "
                             f"known: {RESERVATIONS}")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self.reservation = reservation
        self._free: Deque[int] = collections.deque(range(1, n_blocks + 1))
        self._owned: Dict[int, List[int]] = {}     # rid -> physical ids
        self._reserved: Dict[int, int] = {}        # rid -> reservation
        # prefix key -> {"blocks": [...], "refs": int}; insertion order is
        # the (deterministic) reclaim order
        self._prefix: Dict[object, Dict] = {}
        # mid-run budget shrink (`shrink`): permanently retired block ids,
        # plus the retirement debt collected as live blocks are freed
        self._retired_ids: set = set()
        self._shrink_debt = 0
        self.peak_in_use = 0
        self.peak_committed = 0

    def blocks_for(self, req: Request) -> int:
        """Worst-case blocks `req` can ever hold: its written positions are
        0..prompt+max_new-2 (the last generated token is never cached)."""
        written = len(req.prompt) + req.max_new - 1
        return max(-(-written // self.block_size), 1)

    @property
    def committed(self) -> int:
        """Blocks promised or held: per-request max(reservation, owned)
        (expected-mode overdrafts count at their real size) plus every
        REFERENCED prefix block."""
        own = sum(max(n, len(self._owned[rid]))
                  for rid, n in self._reserved.items())
        pfx = sum(len(p["blocks"]) for p in self._prefix.values()
                  if p["refs"] > 0)
        return own + pfx

    def can_admit(self, n: int) -> bool:
        return self.committed + n <= self.n_blocks

    def reserve(self, rid: int, n: int) -> None:
        if not self.can_admit(n):
            raise RuntimeError(f"reserve({rid}) over-commits the pool")
        if rid in self._reserved:
            raise RuntimeError(f"request {rid} already holds a reservation")
        self._reserved[rid] = n
        self._owned[rid] = []
        self.peak_committed = max(self.peak_committed, self.committed)

    def alloc(self, rid: int) -> int:
        if rid not in self._owned:
            raise RuntimeError(f"request {rid} holds no reservation")
        if (self.reservation == "worst"
                and len(self._owned[rid]) >= self._reserved[rid]):
            raise RuntimeError(f"request {rid} exceeded its reservation")
        while not self._free:       # a reclaim can be swallowed whole by
            if not self._reclaim():  # shrink debt, so keep reclaiming
                raise PoolExhausted(f"no free block for request {rid}: "
                                    f"{self.in_use}/{self.n_blocks} in use, "
                                    "no cached prefix to reclaim")
        bid = self._free.popleft()
        self._owned[rid].append(bid)
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        self.peak_committed = max(self.peak_committed, self.committed)
        return bid

    def _absorb(self, ids: Sequence[int]) -> List[int]:
        """Route freed blocks to outstanding shrink debt first (they
        retire instead of recirculating); return the survivors."""
        out: List[int] = []
        for bid in ids:
            if self._shrink_debt > 0:
                self._shrink_debt -= 1
                self._retired_ids.add(bid)
                self.n_blocks -= 1
            else:
                out.append(bid)
        return out

    def free(self, rid: int) -> List[int]:
        if rid not in self._owned:
            raise DoubleFree(f"request {rid} owns no blocks "
                             "(double free, or never reserved)")
        ids = self._owned.pop(rid)
        del self._reserved[rid]
        self._free.extend(self._absorb(ids))  # FIFO reuse: deterministic
        return ids

    def free_block(self, rid: int, bid: int) -> None:
        """Return ONE of `rid`'s own blocks to the free list mid-flight
        (the retention policy dropping a cold block). The reservation
        stays — only the physical block is recycled. Shared prefix blocks
        are never in a request's owned list, so retention can't free one
        through here; freeing a block twice (or one the request never
        owned) raises `DoubleFree` instead of corrupting the free list."""
        owned = self._owned.get(rid)
        if owned is None:
            raise DoubleFree(f"request {rid} owns no blocks (double free)")
        if bid not in owned:
            raise DoubleFree(f"request {rid} does not own block {bid} "
                             "(double free, or a shared prefix block)")
        owned.remove(bid)
        self._free.extend(self._absorb([bid]))

    def shrink(self, n: int) -> int:
        """Permanently retire up to `n` blocks — the mid-run HBM budget
        shrink (a co-located tenant claiming memory, or the capacity
        model having over-promised). Free blocks retire immediately (from
        the cold end of the free list); the remainder becomes retirement
        DEBT collected as live blocks are freed, so in-flight lanes are
        never yanked — pressure surfaces through `committed > n_blocks`
        and the engine's degradation ladder works it off. At least one
        block always survives. Returns the count retired immediately."""
        if n < 0:
            raise ValueError(f"shrink needs n >= 0, got {n}")
        n = min(n, self.n_blocks - 1)
        take = min(n, len(self._free))
        for _ in range(take):
            bid = self._free.pop()      # FIFO alloc side is popleft
            self._retired_ids.add(bid)
        self.n_blocks -= take
        self._shrink_debt += n - take
        return take

    @property
    def retired_blocks(self) -> int:
        """Blocks permanently lost to `shrink` so far (debt not yet
        collected is not counted — those blocks are still live)."""
        return len(self._retired_ids)

    @property
    def shrink_debt(self) -> int:
        return self._shrink_debt

    def audit(self) -> List[str]:
        """The ledger auditor: every invariant that, when broken, turns
        into silent KV corruption later. Returns problem strings (empty =
        clean). O(pool) — cheap enough for the engine's every-tick
        `audit="strict"` test mode.

          * free + owned + live-prefix blocks partition the pool exactly
          * no physical id appears in two ledgers (or twice in one)
          * retired blocks never re-enter circulation
          * prefix refcounts are never negative
          * reservations and owned ledgers exist in pairs, and in worst
            mode every owned holding is covered by its reservation
        """
        problems: List[str] = []
        free = list(self._free)
        owned = [b for ids in self._owned.values() for b in ids]
        pfx = [b for p in self._prefix.values() for b in p["blocks"]]
        every = free + owned + pfx
        if len(set(every)) != len(every):
            problems.append("a physical block appears twice across the "
                            "free/owned/prefix ledgers")
        if len(every) != self.n_blocks:
            problems.append(f"ledger partition broken: free({len(free)}) "
                            f"+ owned({len(owned)}) + prefix({len(pfx)}) "
                            f"!= pool({self.n_blocks})")
        back = self._retired_ids.intersection(every)
        if back:
            problems.append(f"retired blocks back in circulation: "
                            f"{sorted(back)}")
        for key, p in self._prefix.items():
            if p["refs"] < 0:
                problems.append(f"prefix {key!r} refcount {p['refs']} < 0")
        for rid in self._owned:
            if rid not in self._reserved:
                problems.append(f"request {rid} owns blocks without a "
                                "reservation")
        for rid, n in self._reserved.items():
            if rid not in self._owned:
                problems.append(f"request {rid} reserved without an owned "
                                "ledger")
            elif (self.reservation == "worst"
                    and len(self._owned[rid]) > n):
                problems.append(f"request {rid} owns "
                                f"{len(self._owned[rid])} blocks past its "
                                f"worst-case reservation {n}")
        return problems

    # -- shared prefixes ----------------------------------------------------

    def create_prefix(self, key, n: int) -> Optional[List[int]]:
        """Carve `n` blocks for a shared prefix (refcount 0 until
        acquired). Returns None — without mutating anything — if the pool
        can't physically supply them even after reclaiming cached
        prefixes."""
        if n < 1:
            raise ValueError(f"create_prefix needs n >= 1, got {n}")
        if key in self._prefix:
            raise RuntimeError(f"prefix {key!r} already cached")
        while len(self._free) < n:
            if not self._reclaim():
                return None
        blocks = [self._free.popleft() for _ in range(n)]
        self._prefix[key] = {"blocks": blocks, "refs": 0}
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return list(blocks)

    def acquire_prefix(self, key) -> List[int]:
        p = self._prefix[key]
        p["refs"] += 1
        self.peak_committed = max(self.peak_committed, self.committed)
        return list(p["blocks"])

    def release_prefix(self, key, missing_ok: bool = False) -> None:
        """Drop one reference on a cached prefix. `missing_ok=True` makes
        the release idempotent — a prefix already reclaimed under
        pressure, or already fully released (the eviction-requeue /
        cancellation race), is a no-op instead of a corruption. Without
        it an unbalanced release raises `NegativeRefcount`."""
        p = self._prefix.get(key)
        if p is None or p["refs"] <= 0:
            if missing_ok:
                return
            raise NegativeRefcount(
                f"prefix {key!r} refcount would go negative")
        p["refs"] -= 1

    def prefix_refs(self, key) -> int:
        """Refcount of a cached prefix; -1 if not cached (never created,
        or reclaimed under pressure)."""
        p = self._prefix.get(key)
        return -1 if p is None else p["refs"]

    def _reclaim(self) -> bool:
        """Drop the oldest refcount-0 cached prefix back to the free list
        (shrink debt may swallow some or all of its blocks)."""
        for key, p in self._prefix.items():
            if p["refs"] == 0:
                self._free.extend(self._absorb(p["blocks"]))
                del self._prefix[key]
                return True
        return False

    @property
    def in_use(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def available_blocks(self) -> int:
        """Physically obtainable blocks: the free list plus every cached
        prefix an `alloc` could reclaim."""
        return len(self._free) + sum(len(p["blocks"])
                                     for p in self._prefix.values()
                                     if p["refs"] == 0)


@dataclasses.dataclass
class _Active:
    """One claimed slot: the request plus its decode cursor."""
    req: Request
    admitted: int                # engine tick of FIRST admission
    pos: int                     # next decode position (== tokens emitted + prompt)
    remaining: int               # decode steps still owed
    tokens: List[int]            # ALL generated so far (first from prefill)
    table: List[int] = dataclasses.field(default_factory=list)  # paged: phys block ids
    pending: Tuple[int, ...] = ()  # prompt tail not yet prefilled (chunked)
    prior: Tuple[int, ...] = ()  # tokens emitted before an eviction (the
                                 # re-prefill appends them to the prompt)
    prefix_key: Optional[object] = None   # shared prefix this lane reads
    first_token: int = -1        # tick the FIRST token was emitted (-1: none)
    shared: int = 0              # leading table entries that are SHARED
                                 # prefix blocks (never retention-dropped)


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    tokens: Tuple[int, ...] = ()
    arrival: int = 0
    admitted: int = 0
    finished: int = 0
    first_token: int = 0

    @property
    def latency(self) -> int:
        """Ticks from arrival to last token (queueing + decode)."""
        return self.finished - self.arrival

    @property
    def queue_delay(self) -> int:
        return self.admitted - self.arrival

    @property
    def ttft(self) -> int:
        """Time to first token in ticks (the tail metric eviction and
        chunked prefill move)."""
        return self.first_token - self.arrival


@dataclasses.dataclass(frozen=True)
class Cancellation:
    """A request the engine gave up on, with its resources cleanly
    returned (blocks freed, prefix refs released) and the cause
    surfaced. Reasons: "deadline" (per-request deadline expired), "shed"
    (the backpressure rung rejected the arrival), "chaos" (fault-plan
    injected cancel), "capacity" (after a budget shrink the request can
    never fit the pool again)."""
    rid: int
    tick: int
    reason: str
    arrival: int = 0
    tokens: Tuple[int, ...] = ()     # emitted before the cut (never sent)


# Degradation-ladder rungs, mildest first. Rung k being engaged means
# rungs 1..k are all active.
RUNG_TIGHTEN_PREFILL = 1
RUNG_KV_BEND = 2
RUNG_EVICT = 3
RUNG_SHED = 4
RUNG_NAMES = {0: "normal", 1: "tighten_prefill", 2: "kv_bend",
              3: "evict", 4: "shed"}


@dataclasses.dataclass(frozen=True)
class LadderConfig:
    """The graceful-degradation ladder: what the engine trades away, in
    order, when the capacity model turns out wrong (sustained pool
    pressure — a budget shrink, optimistic admission overshooting, a
    burst past the plan). Pressure = `committed >= high * pool` held for
    `patience` consecutive ticks escalates one rung; the same patience
    without pressure de-escalates. Rungs:

      1 tighten_prefill — halve the prefill token budget (floored at one
        chunk / `prefill_floor`): TTFT degrades, decode goodput survives.
      2 kv_bend — engage block retention at `bend_retain` blocks per
        lane, but ONLY if `bend_agreement` (the plan's prior for that
        bend) clears `min_agreement`: quality is traded inside the same
        gate the planner enforces, never blindly.
      3 evict — proactively evict (SLO order) while commitments overhang
        the shrunken pool.
      4 shed — reject new arrivals with an explicit `Cancellation`
        (reason "shed"): backpressure instead of silent queue growth.
    """
    patience: int = 3
    high: float = 0.95
    prefill_floor: int = 0
    bend_retain: int = 0
    bend_agreement: float = 1.0
    min_agreement: float = 0.0
    max_rung: int = RUNG_SHED


@dataclasses.dataclass
class EngineSnapshot:
    """Drain-and-serialize engine state between two ticks — the
    preemption / re-mesh hook (ROADMAP item 5). In-flight lanes are
    serialized as resume records (request + emitted tokens): restore
    re-enters them through the same chunked re-prefill path eviction
    uses, so NO physical pool contents cross the snapshot — the
    suffix-consistent executor regenerates the KV token-identically on
    any fresh allocator/executor (even a different lane count). JSON
    round-trips via `to_json`/`from_json`."""
    tick: int
    requests: List[Dict]             # serialized unfinished Requests
    pending: List[int]               # rids not yet arrived, in order
    queue: List[int]                 # rids queued, in order
    lanes: List[Optional[Dict]]      # per-slot resume record or None
    resume: Dict[int, Dict]          # evicted-and-requeued resume records
    completions: List[Dict]
    cancellations: List[Dict]
    counters: Dict[str, int]
    evictions: int = 0
    ladder: Optional[Dict] = None    # rung/hot/cool/events/rung_ticks
    stats: Optional[Dict] = None     # OnlineLengthStats state
    config: Dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "EngineSnapshot":
        d = json.loads(s)
        d["resume"] = {int(k): v for k, v in d.get("resume", {}).items()}
        if d.get("ladder") and d["ladder"].get("rung_ticks"):
            d["ladder"]["rung_ticks"] = {
                int(k): v for k, v in d["ladder"]["rung_ticks"].items()}
        return cls(**d)


# counters mirrored between _RunState and EngineSnapshot/ServeReport
_COUNTER_FIELDS = (
    "decode_ticks", "useful", "idle", "admit_only", "lane_tokens",
    "chunk_calls", "block_drops", "peak_queue", "max_concurrent",
    "prefills", "prefill_calls", "prefill_tokens", "shed", "exec_faults",
    "backoff_ticks", "alloc_faults", "shrunk", "audits", "audit_failures")


@dataclasses.dataclass
class _RunState:
    """All mutable state of one trace replay — the unit snapshot/restore
    serializes and `_step` advances one tick at a time."""
    pending: Deque[Request]
    queue: Deque[Request]
    slots: List[Optional[_Active]]
    completions: List[Completion]
    cancellations: List[Cancellation]
    tick: int = 0
    decode_ticks: int = 0
    useful: int = 0
    idle: int = 0
    admit_only: int = 0
    lane_tokens: int = 0
    chunk_calls: int = 0
    block_drops: int = 0
    peak_queue: int = 0
    max_concurrent: int = 0
    prefills: int = 0
    prefill_calls: int = 0
    prefill_tokens: int = 0
    # fault handling
    exec_wait: int = 0               # backoff ticks left before retrying
    exec_fails: int = 0              # CONSECUTIVE transient exec faults
    exec_faults: int = 0             # total transient exec faults absorbed
    backoff_ticks: int = 0           # ticks spent waiting out backoff
    alloc_faults: int = 0            # transient allocation faults absorbed
    shed: int = 0                    # arrivals rejected by rung 4
    shrunk: int = 0                  # blocks retired by budget shrinks
    audits: int = 0
    audit_failures: int = 0
    stalled: Dict[int, int] = dataclasses.field(default_factory=dict)
    # degradation ladder
    rung: int = 0
    max_rung: int = 0
    hot: int = 0                     # consecutive pressured ticks
    cool: int = 0                    # consecutive unpressured ticks
    ladder_events: List[Dict] = dataclasses.field(default_factory=list)
    rung_ticks: Dict[int, int] = dataclasses.field(default_factory=dict)


def _percentile(vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not vals:
        return 0.0
    s = sorted(vals)
    rank = max(1, min(len(s), -(-int(q * len(s)) // 100)))
    return float(s[rank - 1])


@dataclasses.dataclass
class ServeReport:
    """Deterministic step-counted serving metrics for one trace replay."""
    policy: str
    n_slots: int
    completions: List[Completion]
    ticks: int                   # total engine ticks elapsed
    decode_ticks: int            # ticks that executed a batched decode step
    useful_slot_tokens: int      # sum over decode ticks of active slots
    idle_ticks: int              # ticks with no admission, prefill chunk,
                                 # or decode (pure waiting)
    peak_queue: int
    max_concurrent: int
    prefills: int
    prefill_calls: int = 0       # batched prefill invocations (<= prefills)
    n_blocks: int = 0            # paged pool size (0 = ring slots)
    peak_blocks: int = 0         # peak physical blocks in use (paged)
    admit_ticks: int = 0         # ticks that only admitted / chunked /
                                 # evicted (no decode) — the invariant is
                                 # ticks == decode + admit + idle
    decode_lane_tokens: int = 0  # sum over decode ticks of the width the
                                 # executor actually computed at (== n_slots
                                 # x decode_ticks without lane compaction)
    chunk_calls: int = 0         # batched chunk-prefill invocations
    evictions: int = 0           # evict-and-requeue events (expected mode)
    block_drops: int = 0         # cold blocks freed by the retention policy
    prefill_tokens: int = 0      # prompt tokens prefilled (whole-prompt +
                                 # chunked), so prefill work is visible in
                                 # occupancy accounting instead of folded
                                 # into admit ticks
    # fault tolerance (all zero / empty on a fault-free run)
    cancellations: List[Cancellation] = dataclasses.field(
        default_factory=list)    # deadline / shed / chaos / capacity
    shed: int = 0                # arrivals rejected by the backpressure rung
    exec_faults: int = 0         # transient executor faults absorbed
    backoff_ticks: int = 0       # ticks spent waiting out retry backoff
    alloc_faults: int = 0        # transient allocation faults absorbed
    shrunk_blocks: int = 0       # blocks retired by mid-run budget shrinks
    audits: int = 0              # ledger audits run
    audit_failures: int = 0      # audits that found a broken invariant
    degradation: Dict = dataclasses.field(default_factory=dict)
                                 # ladder engagement: max/final rung,
                                 # per-rung tick counts, cause-tagged events
    observed_lengths: Dict = dataclasses.field(default_factory=dict)
                                 # OnlineLengthStats.summary() — the live
                                 # sigma_k feedback loop (ROADMAP item 2)

    @property
    def generated_tokens(self) -> int:
        return sum(len(c.tokens) for c in self.completions)

    def occupancy(self) -> float:
        """Useful-token fraction of decode-step slots: of all the slot
        positions the batched decode steps computed, how many produced a
        token a request actually wanted. Lane compaction shrinks the
        denominator to the widths actually run."""
        denom = self.decode_lane_tokens or self.decode_ticks * self.n_slots
        return self.useful_slot_tokens / denom if denom else 0.0

    def throughput(self) -> float:
        """Generated tokens per engine tick."""
        return self.generated_tokens / self.ticks if self.ticks else 0.0

    def prefill_throughput(self) -> float:
        """Prompt tokens prefilled per engine tick."""
        return self.prefill_tokens / self.ticks if self.ticks else 0.0

    def mean_latency(self) -> float:
        if not self.completions:
            return 0.0
        return sum(c.latency for c in self.completions) / len(self.completions)

    def latency_percentiles(self, qs: Sequence[int] = (50, 95, 99)) -> Dict[str, float]:
        """Empty dict when nothing completed (an overload trace can evict
        every request before its first token) — callers probe `.get`."""
        if not self.completions:
            return {}
        lat = [c.latency for c in self.completions]
        return {f"p{q}": _percentile(lat, q) for q in qs}

    def ttft_percentiles(self, qs: Sequence[int] = (50, 95, 99)) -> Dict[str, float]:
        """Empty dict when nothing completed, like latency_percentiles."""
        if not self.completions:
            return {}
        t = [c.ttft for c in self.completions]
        return {f"p{q}": _percentile(t, q) for q in qs}

    def mean_ttft(self) -> float:
        if not self.completions:
            return 0.0
        return sum(c.ttft for c in self.completions) / len(self.completions)

    def block_occupancy(self) -> float:
        """Paged pools: peak fraction of physical blocks in use."""
        return self.peak_blocks / self.n_blocks if self.n_blocks else 0.0

    def describe(self) -> str:
        paged = (f" blocks={self.peak_blocks}/{self.n_blocks}"
                 if self.n_blocks else "")
        if self.decode_ticks and self.decode_lane_tokens:
            paged += (f" mean_width="
                      f"{self.decode_lane_tokens / self.decode_ticks:.1f}")
        if self.chunk_calls:
            paged += f" chunk_calls={self.chunk_calls}"
        if self.evictions:
            paged += f" evictions={self.evictions}"
        if self.block_drops:
            paged += f" block_drops={self.block_drops}"
        if self.prefill_tokens:
            paged += (f" prefill_tokens={self.prefill_tokens} "
                      f"({self.prefill_throughput():.2f} tok/tick)")
        if self.cancellations:
            by = {}
            for c in self.cancellations:
                by[c.reason] = by.get(c.reason, 0) + 1
            paged += " cancelled=" + ",".join(
                f"{k}:{v}" for k, v in sorted(by.items()))
        if self.exec_faults or self.alloc_faults:
            paged += (f" faults(exec={self.exec_faults},"
                      f"alloc={self.alloc_faults})"
                      f" backoff={self.backoff_ticks}")
        if self.shrunk_blocks:
            paged += f" shrunk={self.shrunk_blocks}"
        if self.degradation.get("max_rung"):
            paged += (f" rung_max={self.degradation.get('max_rung_name')}"
                      f"({self.degradation.get('max_rung')})")
        if self.audit_failures:
            paged += f" AUDIT_FAILURES={self.audit_failures}"
        elif self.audits:
            paged += f" audits={self.audits}:clean"
        lp = self.latency_percentiles()
        tp = self.ttft_percentiles()
        tails = (f"lat_p50/p95/p99={lp['p50']:.0f}/{lp['p95']:.0f}/"
                 f"{lp['p99']:.0f} ttft_p95={tp['p95']:.0f} "
                 if lp else "lat_p50/p95/p99=-/-/- ttft_p95=- ")
        return (f"[{self.policy}] slots={self.n_slots} "
                f"completed={len(self.completions)} "
                f"tokens={self.generated_tokens} ticks={self.ticks} "
                f"occupancy={self.occupancy():.3f} "
                f"throughput={self.throughput():.2f} tok/tick "
                f"mean_latency={self.mean_latency():.1f} ticks "
                f"{tails}"
                f"peak_queue={self.peak_queue} "
                f"max_concurrent={self.max_concurrent}"
                f"{paged}")


class ScriptedExecutor:
    """Deterministic jax-free executor: closed-form token functions stand in
    for the model so the scheduler core (admission, claim/free, metrics,
    eviction, prefix sharing) can be pinned by the hermetic test tier and
    compared across policies without a single compile.

    The token functions are SUFFIX-CONSISTENT, mirroring the property the
    real executor gets from its KV cache: `prefill(prompt)` equals
    `decode(prompt[-1], len(prompt) - 1)`, so prefilling `prompt +
    emitted` reproduces exactly the token the interrupted decode would
    have produced next — which is what makes evict-and-requeue and
    prefix-suffix prefill token-identical by construction. `buckets`
    emulates the paged executor's lane compaction: decode_width returns
    the smallest covering bucket and every decode tick's width is
    recorded in `tick_widths`."""

    def __init__(self, vocab_size: int = 97,
                 buckets: Optional[Sequence[int]] = None):
        self.vocab_size = vocab_size
        self.buckets = tuple(sorted(buckets)) if buckets else None
        self.prefills = 0
        self.prefill_batches = 0
        self.decodes = 0
        self.chunk_calls = 0
        self.chunk_tokens = 0
        self.tick_widths: List[int] = []
        # lane -> (start of accumulation, tokens accumulated so far)
        self._partial: Dict[int, Tuple[int, List[int]]] = {}
        # lane -> per-logical-block mass from the last decode (scripted
        # stand-in for attention mass: later blocks are hotter, so the
        # retention policy deterministically drops oldest-first)
        self._last_mass: Dict[int, List[float]] = {}

    def _token_at(self, last: int, pos: int) -> int:
        """The token emitted after consuming token `last` at position
        `pos` — shared by prefill and decode (suffix consistency)."""
        return (17 * last + 7 * pos + 13) % self.vocab_size

    def prefill(self, slot: int, prompt: Sequence[int]) -> int:
        self.prefills += 1
        return self._token_at(prompt[-1], len(prompt) - 1)

    def prefill_batch(self, slots: Sequence[int],
                      prompts: Sequence[Sequence[int]],
                      tables: Optional[Sequence[Sequence[int]]] = None
                      ) -> List[int]:
        self.prefill_batches += 1
        return [self.prefill(s, p) for s, p in zip(slots, prompts)]

    def prefill_chunks(self, lanes: Sequence[int],
                       chunks: Sequence[Sequence[int]],
                       starts: Sequence[int],
                       tables: Optional[Sequence[Sequence[int]]] = None,
                       final: Optional[Sequence[bool]] = None) -> List[int]:
        """Accumulate chunks per lane; on a lane's final chunk emit exactly
        what a whole-prompt prefill reaching the same last (token,
        position) would — so chunked, prefix-suffix and re-prefill
        scheduling are all token-identical by construction, like the real
        executor. A start that doesn't continue the lane's accumulation
        resets it (the engine evicted and re-admitted that lane)."""
        self.chunk_calls += 1
        out: List[int] = []
        for j, lane in enumerate(lanes):
            state = self._partial.get(lane)
            if state is None or state[0] + len(state[1]) != starts[j]:
                state = (starts[j], [])
                self._partial[lane] = state
            state[1].extend(chunks[j])
            self.chunk_tokens += len(chunks[j])
            if final is not None and final[j]:
                start, acc = self._partial.pop(lane)
                self.prefills += 1
                out.append(self._token_at(acc[-1], start + len(acc) - 1))
            else:
                out.append(0)
        return out

    def fresh_blocks(self, ids: Sequence[int]) -> None:
        pass                                 # no physical pool to invalidate

    def decode_width(self, n_active: int) -> Optional[int]:
        """None = no compaction (the engine charges full pool width)."""
        if self.buckets is None:
            return None
        for b in self.buckets:
            if b >= n_active:
                return b
        return self.buckets[-1]

    def decode(self, tokens: Sequence[int], positions: Sequence[int],
               tables: Optional[Sequence[Sequence[int]]] = None,
               lanes: Optional[Sequence[int]] = None) -> List[int]:
        self.decodes += 1
        n_active = len(lanes) if lanes is not None else len(tokens)
        width = self.decode_width(n_active)
        self.tick_widths.append(width if width is not None else len(tokens))
        if tables is not None:
            act = lanes if lanes is not None else range(len(tokens))
            self._last_mass = {
                int(i): [float(j + 1) for j in range(len(tables[i]))]
                for i in act}
        return [self._token_at(t, p) for t, p in zip(tokens, positions)]

    def block_masses(self) -> Dict[int, List[float]]:
        return self._last_mass


class Engine:
    """Continuous-batching serving engine over a slotted or paged KV pool.

    `n_slots` is the admission bound — by construction the engine never
    runs more concurrent sequences than slots, so sizing it from
    `ServingPlan.slots()` makes `predictor.serving_capacity` the admission
    controller. With a `BlockAllocator` the slots become decode LANES and
    admission additionally requires the request's block reservation to fit
    the pool (`serving_block_capacity` run as the admission controller):
    short requests reserve few blocks, so many more of them fit the same
    HBM budget than worst-case ring slots would admit. One `run()` call
    replays one trace to completion.

    With an `reservation="expected"` allocator, pass the trace's
    `length_stats` as `stats`: admission reserves
    `ceil((E[written | prompt bucket] + sigma_k·sigma) / block_size)`
    own blocks instead of the worst case, and pool misses are handled by
    SLO-then-progress eviction (see module docstring). `prefix_share=True`
    (needs `chunk_prefill` — suffixes ride the chunked path) maps common
    system-prompt blocks to shared refcounted physical blocks.
    """

    def __init__(self, executor, n_slots: int, policy: str = "continuous",
                 allocator: Optional[BlockAllocator] = None,
                 chunk_prefill: int = 0, prefill_budget: int = 0,
                 prefix_share: bool = False,
                 stats: Optional[LengthStats] = None,
                 sigma_k: float = 1.0, kv_retain: int = 0,
                 deadline: int = 0, faults=None,
                 ladder: Optional[LadderConfig] = None,
                 audit: str = "off", max_exec_retries: int = 6):
        if n_slots < 1:
            raise ValueError(f"Engine needs n_slots >= 1, got {n_slots} "
                             "(serving_capacity said nothing fits — lower "
                             "the context or raise the budget)")
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
        if chunk_prefill < 0:
            raise ValueError(f"chunk_prefill must be >= 0, got "
                             f"{chunk_prefill}")
        if (chunk_prefill and allocator is not None
                and chunk_prefill % allocator.block_size):
            raise ValueError(f"chunk_prefill={chunk_prefill} must be a "
                             f"multiple of the kv block size "
                             f"{allocator.block_size}")
        if prefill_budget < 0:
            raise ValueError(f"prefill_budget must be >= 0, got "
                             f"{prefill_budget}")
        if prefill_budget and not chunk_prefill:
            raise ValueError("prefill_budget needs chunk_prefill > 0 (the "
                             "token budget schedules prompt CHUNKS; "
                             "whole-prompt prefill is all-or-nothing)")
        if prefix_share and allocator is None:
            raise ValueError("prefix_share needs a BlockAllocator (shared "
                             "prefixes live in the paged block pool)")
        if prefix_share and not chunk_prefill:
            raise ValueError("prefix_share needs chunk_prefill > 0 (a "
                             "sharer's suffix prefill rides the chunked "
                             "path)")
        if prefix_share and getattr(executor, "has_recurrent", False):
            raise ValueError("prefix_share is attention-only: shared "
                             "prefix blocks carry KV, not the recurrent "
                             "scan state at the prefix boundary, so a "
                             "sharer cannot resume mid-prompt")
        if sigma_k < 0:
            raise ValueError(f"sigma_k must be >= 0, got {sigma_k}")
        if kv_retain < 0:
            raise ValueError(f"kv_retain must be >= 0, got {kv_retain}")
        if kv_retain and allocator is None:
            raise ValueError("kv_retain needs a BlockAllocator (retention "
                             "drops paged blocks back to the free list)")
        if deadline < 0:
            raise ValueError(f"deadline must be >= 0 ticks, got {deadline}")
        if audit not in AUDIT_MODES:
            raise ValueError(f"unknown audit mode {audit!r}; known: "
                             f"{AUDIT_MODES}")
        if audit != "off" and allocator is None:
            raise ValueError("audit needs a BlockAllocator (the auditor "
                             "checks the block ledger)")
        if max_exec_retries < 1:
            raise ValueError(f"max_exec_retries must be >= 1, got "
                             f"{max_exec_retries}")
        if ladder is not None and allocator is None:
            raise ValueError("the degradation ladder reads pool pressure "
                             "off a BlockAllocator (committed vs n_blocks)")
        if (faults is not None and allocator is None
                and getattr(faults, "shrinks", ())):
            raise ValueError("fault-plan budget shrinks need a "
                             "BlockAllocator (they retire pool blocks)")
        self.executor = executor
        self.n_slots = int(n_slots)
        self.policy = policy
        self.allocator = allocator
        # prompts longer than this prefill `chunk_prefill` positions per
        # tick (0 = whole-prompt prefill at admission)
        self.chunk_prefill = int(chunk_prefill)
        # cap on prompt tokens prefilled per tick across ALL mid-prefill
        # lanes (0 = advance every lane one chunk per tick). The budget is
        # fair-shared over SLO classes — see _advance_chunks — and one
        # chunk always lands per tick so TTFT can never stall outright.
        self.prefill_budget = int(prefill_budget)
        self.prefix_share = bool(prefix_share)
        self.stats = stats
        self.sigma_k = float(sigma_k)
        # keep only the kv_retain most-attended own blocks per lane (plus
        # the tail block being written); 0 = keep everything
        self.kv_retain = int(kv_retain)
        # fault tolerance: per-request deadline in ticks from arrival
        # (0 = none), a duck-typed FaultPlan (serving.faults) whose
        # shrinks/cancels/stalls the engine applies at their ticks, the
        # degradation ladder, the ledger audit mode, and the consecutive
        # transient-executor-fault budget before EngineFault
        self.deadline = int(deadline)
        self.faults = faults
        self.ladder = ladder
        self.audit_mode = audit
        self.max_exec_retries = int(max_exec_retries)
        # per-run state (reset by run()): rid -> resume record after an
        # eviction; prefix key -> {"ready": bool, "writer": rid|None}
        self._resume: Dict[int, Dict] = {}
        self._prefix_state: Dict[object, Dict] = {}
        self._evictions = 0
        self._st: Optional[_RunState] = None

    # -- admission sizing ---------------------------------------------------

    def _own_reservation(self, req: Request, n_shared: int, eff_len: int,
                         chunked: bool, resumed: bool) -> int:
        """Blocks to reserve for a request's OWN (non-shared) footprint.
        Worst mode: everything it can ever write beyond the shared prefix.
        Expected mode: the safety-margined expected footprint, floored at
        what admission allocates immediately (whole-prompt prefill) and
        capped at the worst case. Re-admitted requests reserve worst-case
        — their length is no longer a prediction, and a full reservation
        keeps them from thrashing back out."""
        alloc = self.allocator
        worst_own = max(alloc.blocks_for(req) - n_shared, 0)
        if (alloc.reservation != "expected" or self.stats is None
                or resumed):
            return worst_own
        exp = self.stats.expected_written(len(req.prompt), self.sigma_k)
        exp_own = -(-int(exp) // alloc.block_size) - n_shared
        if self.kv_retain:
            # retention bounds steady-state own blocks at retain+1; the
            # floor below still covers the whole-prompt prefill burst
            # (drops begin only after the first decode tick)
            exp_own = min(exp_own, self.kv_retain + 1)
        now_own = 0 if chunked else (-(-eff_len // alloc.block_size)
                                     - n_shared)
        return max(now_own, min(worst_own, max(exp_own, 0)))

    # -- scheduling core ---------------------------------------------------

    def _admit(self, queue: Deque[Request], slots: List[Optional[_Active]],
               tick: int) -> Tuple[int, int, int]:
        """Claim free slots for queued requests under the active policy.
        Admissions landing in the same tick and prompt bucket share ONE
        padded prefill call (engine-level batched prefill). Returns
        (admissions, prefill calls, prompt tokens prefilled)."""
        if self.policy == "static" and any(s is not None for s in slots):
            return 0, 0, 0                # fixed batch: wait for the pool
        alloc = self.allocator
        # physical blocks this tick's admissions may immediately consume —
        # pre-checked so the admission path can never hit PoolExhausted
        # (only decode/chunk overdrafts evict)
        avail = alloc.available_blocks if alloc is not None else 0
        picked: List[Tuple] = []   # (slot, req, eff_prompt, meta, seed, key)
        for i in range(self.n_slots):
            # a mid-run shrink can leave a queued request that no longer
            # fits the pool at ANY occupancy — cancel it (reason
            # "capacity") instead of deadlocking the FIFO head
            while (queue and alloc is not None and self._st is not None
                    and alloc.blocks_for(queue[0]) > alloc.n_blocks):
                bad = queue.popleft()
                self._cancel_queued(self._st, bad, "capacity")
            if not queue:
                break
            if slots[i] is not None:
                continue
            req = queue[0]
            meta = self._resume.get(req.rid)
            prior = tuple(meta["tokens"]) if meta else ()
            eff = req.prompt + prior
            # shared-prefix plumbing: the first request naming a prefix
            # becomes its WRITER (prefills it into freshly carved blocks);
            # later ones only attach once the prefix KV is real
            key = None
            n_cached = 0          # blocks the prefix cache entry holds
            n_shared = 0          # prefix blocks seeded into THIS table
            writer = False
            if (self.prefix_share and alloc is not None
                    and req.prefix_id is not None
                    and req.prefix_len >= alloc.block_size):
                key = req.prefix_id
                n_cached = req.prefix_len // alloc.block_size
                state = self._prefix_state.get(key)
                if state is not None and alloc.prefix_refs(key) < 0:
                    # reclaimed under pressure while unreferenced
                    del self._prefix_state[key]
                    state = None
                if (state is not None and not state["ready"]
                        and state["writer"] is not None):
                    break         # writer mid-prefill: hold FIFO until real
                writer = state is None or not state["ready"]
                # a sharer never maps a block it would have to write: its
                # private suffix starts in block (eff_len-1)//B at the latest
                n_shared = (n_cached if writer
                            else min(n_cached,
                                     (len(eff) - 1) // alloc.block_size))
                if n_shared < 1 and not writer:
                    key = None    # degenerate: nothing shareable
            chunked = bool(self.chunk_prefill) and (
                len(eff) > self.chunk_prefill
                or (key is not None and not writer))
            seed: List[int] = []
            if alloc is not None:
                own = self._own_reservation(req, n_shared, len(eff),
                                            chunked, bool(meta))
                pfx_cost = (n_cached if key is not None
                            and alloc.prefix_refs(key) <= 0 else 0)
                if not alloc.can_admit(own + pfx_cost):
                    break                 # FIFO: no overtaking the head
                now = 0 if chunked else (-(-len(eff) // alloc.block_size)
                                         - n_shared)
                if key is not None and alloc.prefix_refs(key) < 0:
                    now += n_cached       # prefix blocks carved this tick
                if now > avail:
                    break                 # physically can't land this tick
                if key is not None and alloc.prefix_refs(key) < 0:
                    blocks = alloc.create_prefix(key, n_cached)
                    if blocks is None:
                        break
                    self._prefix_state[key] = {"ready": False,
                                               "writer": req.rid}
                    # stale data in carved blocks must not leak through the
                    # position mask while the writer is still mid-chunk
                    self.executor.fresh_blocks(blocks)
                pfx_blocks: List[int] = []
                if key is not None:
                    pfx_blocks = alloc.acquire_prefix(key)
                    if writer:
                        self._prefix_state[key]["writer"] = req.rid
                        self._prefix_state[key]["ready"] = False
                    seed = pfx_blocks[:n_shared]
                alloc.reserve(req.rid, own)
                avail -= now
            queue.popleft()
            if meta is not None:
                del self._resume[req.rid]
            picked.append((i, req, eff, meta, seed, key, writer, chunked))
        if not picked:
            return 0, 0, 0
        by_len: Dict[int, List[Tuple]] = {}
        for item in picked:
            i, req, eff, meta, seed, key, writer, chunked = item
            if chunked:
                # chunked admission: the lane is claimed now but its prompt
                # (or private suffix, for a prefix sharer) is appended
                # chunk-by-chunk by _advance_chunks (no decode cursor yet).
                # A prefix WRITER prefills from position 0 — it is the one
                # writing the shared prefix KV — so only a SHARER skips the
                # seeded blocks.
                skip = (0 if writer else
                        len(seed) * (self.allocator.block_size
                                     if self.allocator else 0))
                prior = tuple(meta["tokens"]) if meta else ()
                slots[i] = _Active(
                    req=req, admitted=(meta["admitted"] if meta else tick),
                    pos=0, remaining=req.max_new - len(prior), tokens=[],
                    table=list(seed), pending=eff[skip:], prior=prior,
                    prefix_key=key, shared=len(seed),
                    first_token=(meta["first_token"] if meta else -1))
                continue
            by_len.setdefault(len(eff), []).append(item)
        if not by_len:
            return len(picked), 0, 0
        alloc = self.allocator
        calls = tokens = 0
        failed: List[Request] = []     # rolled-back picks, requeued at head
        for plen in sorted(by_len):
            group = by_len[plen]
            tables = None
            if alloc is not None:
                kept, tables = [], []
                for item in group:
                    i, req, eff, meta, seed, key, writer, _ = item
                    nb0 = max(-(-plen // alloc.block_size), 1)
                    tbl = list(seed)
                    try:
                        while len(tbl) < nb0:
                            tbl.append(alloc.alloc(req.rid))
                    except AllocationFault:
                        # transient refusal: unwind THIS pick exactly and
                        # retry it from the queue head next tick
                        if self._st is not None:
                            self._st.alloc_faults += 1
                        self._unadmit(req, meta, key, writer)
                        failed.append(req)
                        continue
                    kept.append(item)
                    tables.append(tbl)
                group = kept
                if not group:
                    continue
            lanes = [item[0] for item in group]
            prompts = [item[2] for item in group]
            try:
                firsts = self.executor.prefill_batch(lanes, prompts,
                                                     tables=tables)
            except TransientExecutorError:
                # raised before the executor mutated anything: unwind the
                # whole group, arm backoff, replay the identical calls later
                for i, req, eff, meta, seed, key, writer, _ in group:
                    self._unadmit(req, meta, key, writer)
                    failed.append(req)
                if self._st is not None:
                    self._exec_fault(self._st)
                continue
            if self._st is not None:
                self._st.exec_fails = 0
            calls += 1
            tokens += plen * len(group)
            for gi, (i, req, eff, meta, seed, key, writer, _) \
                    in enumerate(group):
                prior = tuple(meta["tokens"]) if meta else ()
                ft = (meta["first_token"] if meta
                      and meta["first_token"] >= 0 else tick)
                slots[i] = _Active(
                    req=req, admitted=(meta["admitted"] if meta else tick),
                    pos=plen, remaining=req.max_new - len(prior) - 1,
                    tokens=list(prior) + [int(firsts[gi])],
                    table=(tables[gi] if tables is not None else []),
                    prior=prior, prefix_key=key, shared=len(seed),
                    first_token=ft)
                if key is not None and writer:
                    # whole-prompt prefill wrote the prefix blocks in full
                    self._prefix_state[key]["ready"] = True
        for req in reversed(failed):
            queue.appendleft(req)
        return len(picked) - len(failed), calls, tokens

    # -- fault handling -----------------------------------------------------

    def _unadmit(self, req: Request, meta: Optional[Dict], key,
                 writer: bool) -> None:
        """Unwind one `_admit` pick (transient fault mid-admission): drop
        the reservation and any blocks it already took, release the prefix
        reference, restore the resume record — exactly as if the pick
        never happened. The caller requeues the request at the head."""
        alloc = self.allocator
        if alloc is not None:
            alloc.free(req.rid)
            if key is not None:
                alloc.release_prefix(key, missing_ok=True)
                stp = self._prefix_state.get(key)
                if (writer and stp is not None
                        and stp["writer"] == req.rid and not stp["ready"]):
                    stp["writer"] = None
        if meta is not None:
            self._resume[req.rid] = meta

    def _exec_fault(self, st: _RunState) -> None:
        """One executor call failed transiently. Arm exponential backoff —
        the engine skips ALL executor work for 2^(k-1) ticks (capped at
        32) after the k-th consecutive fault — and give up with
        `EngineFault` past `max_exec_retries` consecutive failures. Any
        success resets the streak."""
        st.exec_faults += 1
        st.exec_fails += 1
        if st.exec_fails > self.max_exec_retries:
            raise EngineFault(
                f"{st.exec_fails} consecutive transient executor faults "
                f"(max_exec_retries={self.max_exec_retries})")
        st.exec_wait = min(2 ** (st.exec_fails - 1), 32)

    def _cancel_queued(self, st: _RunState, req: Request,
                       reason: str) -> None:
        """Cancel a request that holds no lane (queued, shed at arrival,
        or evicted-and-requeued — its resume record is dropped too)."""
        meta = self._resume.pop(req.rid, None)
        toks = tuple(meta["tokens"]) if meta else ()
        st.cancellations.append(Cancellation(
            rid=req.rid, tick=st.tick, reason=reason,
            arrival=req.arrival, tokens=toks))

    def _cancel_lane(self, st: _RunState, i: int, reason: str) -> None:
        """Cancel the request on lane `i` cleanly: blocks freed, prefix
        reference released (idempotently — the prefix may already be
        reclaimed), writer handoff if it was mid-prefix-prefill."""
        a = st.slots[i]
        alloc = self.allocator
        if alloc is not None:
            alloc.free(a.req.rid)
            if a.prefix_key is not None:
                alloc.release_prefix(a.prefix_key, missing_ok=True)
                stp = self._prefix_state.get(a.prefix_key)
                if (stp is not None and stp["writer"] == a.req.rid
                        and not stp["ready"]):
                    stp["writer"] = None
        emitted = tuple(a.tokens) if a.tokens else tuple(a.prior)
        st.cancellations.append(Cancellation(
            rid=a.req.rid, tick=st.tick, reason=reason,
            arrival=a.req.arrival, tokens=emitted))
        st.slots[i] = None

    def _cancel_rid(self, st: _RunState, rid: int, reason: str) -> bool:
        """Cancel a request wherever it currently lives (lane, queue, or
        not-yet-arrived). False if already finished/cancelled/unknown."""
        for i in range(self.n_slots):
            a = st.slots[i]
            if a is not None and a.req.rid == rid:
                self._cancel_lane(st, i, reason)
                return True
        for q in (st.queue, st.pending):
            for req in q:
                if req.rid == rid:
                    q.remove(req)
                    self._cancel_queued(st, req, reason)
                    return True
        return False

    def _sweep_deadlines(self, st: _RunState) -> None:
        """Cancel every request whose per-request deadline (ticks since
        arrival) has expired, wherever it lives."""
        for i in range(self.n_slots):
            a = st.slots[i]
            if a is not None and st.tick - a.req.arrival >= self.deadline:
                self._cancel_lane(st, i, "deadline")
        expired = [r for r in st.queue
                   if st.tick - r.arrival >= self.deadline]
        for r in expired:
            st.queue.remove(r)
            self._cancel_queued(st, r, "deadline")

    # -- degradation ladder -------------------------------------------------

    def _ladder_update(self, st: _RunState) -> None:
        """Escalate/de-escalate the rung on sustained pool pressure
        (committed >= high * pool for `patience` ticks either way)."""
        lad = self.ladder
        alloc = self.allocator
        if lad is None or alloc is None:
            return
        pressured = alloc.committed >= lad.high * alloc.n_blocks
        if pressured:
            st.hot += 1
            st.cool = 0
            if st.hot >= lad.patience and st.rung < lad.max_rung:
                st.hot = 0
                st.rung += 1
                st.max_rung = max(st.max_rung, st.rung)
                st.ladder_events.append({
                    "tick": st.tick, "rung": st.rung,
                    "name": RUNG_NAMES[st.rung], "cause": "pressure",
                    "committed": alloc.committed, "pool": alloc.n_blocks})
        else:
            st.cool += 1
            st.hot = 0
            if st.cool >= lad.patience and st.rung > 0:
                st.cool = 0
                st.rung -= 1
                st.ladder_events.append({
                    "tick": st.tick, "rung": st.rung,
                    "name": RUNG_NAMES[st.rung], "cause": "recovered",
                    "committed": alloc.committed, "pool": alloc.n_blocks})
        if st.rung:
            st.rung_ticks[st.rung] = st.rung_ticks.get(st.rung, 0) + 1

    def _eff_retain(self, st: Optional[_RunState]) -> int:
        """The retention cap in force: the configured `kv_retain`, or the
        ladder's `bend_retain` once rung 2 is engaged AND its agreement
        prior clears the `min_agreement` gate (quality is only ever traded
        inside the gate the planner enforces)."""
        if self.kv_retain:
            return self.kv_retain
        lad = self.ladder
        if (lad is not None and st is not None
                and st.rung >= RUNG_KV_BEND and lad.bend_retain > 0
                and lad.bend_agreement >= lad.min_agreement):
            return lad.bend_retain
        return 0

    def _eff_budget(self, st: Optional[_RunState]) -> int:
        """The prefill token budget in force: halved (floored at one chunk
        / `prefill_floor`) once rung 1 is engaged. An unbudgeted engine
        under rung 1 gets 4 chunks halved to 2 — TTFT degrades before
        decode goodput does."""
        lad = self.ladder
        if (lad is None or st is None or st.rung < RUNG_TIGHTEN_PREFILL
                or not self.chunk_prefill):
            return self.prefill_budget
        base = self.prefill_budget or 4 * self.chunk_prefill
        floor = max(self.chunk_prefill, lad.prefill_floor)
        return max(floor, base // 2)

    def _audit(self, st: _RunState) -> None:
        """The every-tick ledger audit: "strict" fails fast (tests),
        "count" degrades to a `ServeReport.audit_failures` counter
        (production), "off" skips entirely."""
        if self.audit_mode == "off" or self.allocator is None:
            return
        st.audits += 1
        problems = self.allocator.audit()
        if problems:
            st.audit_failures += 1
            if self.audit_mode == "strict":
                raise LedgerCorruption(
                    f"tick {st.tick}: " + "; ".join(problems))

    def _retain(self, a: _Active, mass: Optional[Sequence[float]],
                retain: int) -> int:
        """Enforce the retention cap on one lane: keep the `retain`
        most-attended OWN blocks plus the tail block being written, free
        the rest back to the allocator (their table entries go -1 =
        unassigned, so decode masks them — H2O-style block dropping).
        Shared prefix blocks (leading `a.shared` entries) are untouchable:
        the allocator doesn't own-list them and other lanes read through
        them. Ranking: lowest attention mass first, ties oldest-first
        (lowest logical index) — with no mass signal everything ties, so
        the policy degenerates to drop-oldest."""
        alloc = self.allocator
        tail = max(a.pos - 1, 0) // alloc.block_size
        live = [j for j in range(len(a.table))
                if a.table[j] >= 0 and j >= a.shared and j != tail]
        if len(live) <= retain:
            return 0

        def key(j):
            m = (float(mass[j]) if mass is not None and j < len(mass)
                 else 0.0)
            return (m, j)

        drop = sorted(live, key=key)[:len(live) - retain]
        for j in drop:
            alloc.free_block(a.req.rid, a.table[j])
            a.table[j] = -1
        return len(drop)

    def _pick_victim(self, slots: List[Optional[_Active]]) -> int:
        """The lane to evict under pool pressure: loosest SLO class first
        (highest `Request.slo`), then least progress (fewest tokens
        emitted — the cheapest re-prefill), then most recently admitted,
        then highest rid. Deterministic."""
        occ = [i for i in range(self.n_slots) if slots[i] is not None]
        if not occ:
            raise RuntimeError("pool exhausted with no lane to evict "
                               "(allocator invariant broken)")

        def key(i):
            a = slots[i]
            progress = len(a.tokens) if a.tokens else len(a.prior)
            return (-a.req.slo, progress, -a.admitted, -a.req.rid)
        return min(occ, key=key)

    def _evict(self, slots: List[Optional[_Active]], i: int,
               queue: Deque[Request]) -> None:
        """Free lane `i`'s own blocks (shared prefix blocks only lose a
        reference), remember its emitted tokens, and requeue it at the
        queue head for chunked re-prefill of prompt + emitted — greedy
        decode is deterministic, so the replay emits the same stream."""
        a = slots[i]
        alloc = self.allocator
        alloc.free(a.req.rid)
        if a.prefix_key is not None:
            alloc.release_prefix(a.prefix_key)
            st = self._prefix_state.get(a.prefix_key)
            if (st is not None and st["writer"] == a.req.rid
                    and not st["ready"]):
                st["writer"] = None      # next matching request re-writes
        emitted = list(a.tokens) if a.tokens else list(a.prior)
        self._resume[a.req.rid] = {"tokens": emitted, "admitted": a.admitted,
                                   "first_token": a.first_token}
        queue.appendleft(a.req)
        slots[i] = None
        self._evictions += 1

    def _alloc_through(self, slots: List[Optional[_Active]], i: int,
                       last_block: int, queue: Deque[Request],
                       fresh: List[int]) -> int:
        """Grow lane `i`'s table until it covers logical block
        `last_block`, evicting on pool exhaustion. Returns 1 on success,
        0 if lane `i` evicted ITSELF (the caller must drop it this tick),
        -1 if a transient `AllocationFault` DEFERRED the lane to the next
        tick (its table is left short; nothing was lost — compare against
        these constants, not truthiness)."""
        a = slots[i]
        alloc = self.allocator
        while last_block >= len(a.table):
            try:
                bid = alloc.alloc(a.req.rid)
            except AllocationFault:
                if self._st is not None:
                    self._st.alloc_faults += 1
                return -1
            except PoolExhausted:
                v = self._pick_victim(slots)
                self._evict(slots, v, queue)
                if v == i:
                    return 0
                continue
            a.table.append(bid)
            fresh.append(bid)
        return 1

    def _schedule_chunks(self, slots: List[Optional[_Active]],
                         lanes: List[int], budget: int) -> List[int]:
        """Pick which mid-prefill lanes advance this tick under the token
        budget (the configured `prefill_budget`, or the ladder-tightened
        one). No budget: all of them. With one: interleave chunks
        round-robin over SLO classes (tightest class leads each round,
        FIFO by admission within a class) and grant whole chunks in that
        order until the budget is spent — the first grant is unconditional
        so a budget below the chunk size still makes progress."""
        if not budget:
            return lanes
        by_class: Dict[int, List[int]] = {}
        for i in lanes:
            by_class.setdefault(slots[i].req.slo, []).append(i)
        classes = sorted(by_class)
        rr = {c: collections.deque(
                 sorted(by_class[c],
                        key=lambda i: (slots[i].admitted, slots[i].req.rid)))
              for c in classes}
        order: List[int] = []
        while any(rr[c] for c in classes):
            for c in classes:
                if rr[c]:
                    order.append(rr[c].popleft())
        picked: List[int] = []
        spent = 0
        for i in order:
            cost = min(len(slots[i].pending), self.chunk_prefill)
            if picked and spent + cost > budget:
                break
            picked.append(i)
            spent += cost
        return sorted(picked)

    def _advance_chunks(self, slots: List[Optional[_Active]],
                        queue: Deque[Request]) -> Tuple[int, int]:
        """Advance mid-prefill lanes by one prompt chunk each in ONE
        batched call (blocks allocated lazily per chunk, freshly re-linked
        ones invalidated first) — every pending lane, or the
        `prefill_budget`-token fair share picked by _schedule_chunks. A
        lane whose final chunk lands gets its first token and decode
        cursor. Returns (chunk calls made (0/1), chunk tokens issued)."""
        stalled = self._st.stalled if self._st is not None else {}
        lanes = [i for i in range(self.n_slots)
                 if slots[i] is not None and slots[i].pending
                 and stalled.get(i, 0) <= 0]
        if not lanes:
            return 0, 0
        lanes = self._schedule_chunks(slots, lanes,
                                      self._eff_budget(self._st))
        alloc = self.allocator
        chunks, starts, tables, final, live = [], [], [], [], []
        fresh: List[int] = []
        for i in lanes:
            a = slots[i]
            if a is None or not a.pending:
                continue                 # evicted by an earlier lane's
                                         # pool pressure this same tick
            eff_len = len(a.req.prompt) + len(a.prior)
            start = eff_len - len(a.pending)
            c = a.pending[:self.chunk_prefill]
            if alloc is not None:
                last = start + len(c) - 1
                if self._alloc_through(slots, i, last // alloc.block_size,
                                       queue, fresh) != 1:
                    continue             # evicted itself (0) or deferred
                                         # by a transient fault (-1):
                                         # chunk not issued this tick
            a.pending = a.pending[self.chunk_prefill:]
            live.append(i)
            chunks.append(c)
            starts.append(start)
            tables.append(list(a.table))
            final.append(not a.pending)
        if not live:
            return 0, 0
        if fresh:
            self.executor.fresh_blocks(fresh)
        try:
            firsts = self.executor.prefill_chunks(
                live, chunks, starts,
                tables=(tables if alloc is not None else None), final=final)
        except TransientExecutorError:
            # raised before the executor consumed the chunks: push them
            # back onto their lanes and arm backoff — the identical call
            # replays after the wait (blocks already grown stay grown)
            for j, i in enumerate(live):
                a = slots[i]
                a.pending = tuple(chunks[j]) + tuple(a.pending)
            if self._st is not None:
                self._exec_fault(self._st)
            return 0, 0
        if self._st is not None:
            self._st.exec_fails = 0
        for j, i in enumerate(live):
            a = slots[i]
            if final[j]:
                a.tokens = list(a.prior) + [int(firsts[j])]
                a.pos = len(a.req.prompt) + len(a.prior)
                a.remaining = a.req.max_new - len(a.prior) - 1
                if a.prefix_key is not None:
                    st = self._prefix_state.get(a.prefix_key)
                    if st is not None and st["writer"] == a.req.rid:
                        st["ready"] = True   # prefix KV fully written
        return 1, sum(len(c) for c in chunks)

    def run(self, trace: Sequence[Request], max_ticks: int = 1_000_000,
            stop_tick: Optional[int] = None) -> ServeReport:
        """Replay `trace` to completion. `stop_tick` suspends the run at
        that tick instead (the partial report is returned and the state
        stays live for `snapshot()`)."""
        for r in trace:                      # fail fast, not at max_ticks
            if r.max_new < 1 or not r.prompt:
                raise ValueError(f"request {r.rid}: needs a non-empty "
                                 f"prompt and max_new >= 1 (got "
                                 f"prompt_len={len(r.prompt)}, "
                                 f"max_new={r.max_new})")
            if (self.allocator is not None
                    and self.allocator.blocks_for(r) > self.allocator.n_blocks):
                raise ValueError(
                    f"request {r.rid} needs {self.allocator.blocks_for(r)} "
                    f"KV blocks but the pool holds "
                    f"{self.allocator.n_blocks} — it could never be "
                    "admitted (raise the budget or shrink the context)")
        st = self._start(trace)
        return self._loop(st, max_ticks, stop_tick)

    def _start(self, trace: Sequence[Request]) -> _RunState:
        pending: Deque[Request] = collections.deque(
            sorted(trace, key=lambda r: (r.arrival, r.rid)))
        st = _RunState(pending=pending, queue=collections.deque(),
                       slots=[None] * self.n_slots, completions=[],
                       cancellations=[])
        self._resume = {}
        self._prefix_state = {}
        self._evictions = 0
        self._st = st
        return st

    def _loop(self, st: _RunState, max_ticks: int,
              stop_tick: Optional[int]) -> ServeReport:
        while st.pending or st.queue or any(s is not None
                                            for s in st.slots):
            if stop_tick is not None and st.tick >= stop_tick:
                break
            if st.tick >= max_ticks:
                raise RuntimeError(f"engine exceeded max_ticks={max_ticks}")
            self._step(st)
        return self._report(st)

    def _finish(self, st: _RunState, i: int, when: int) -> None:
        a = st.slots[i]
        ft = a.first_token if a.first_token >= 0 else when
        st.completions.append(Completion(
            rid=a.req.rid, tokens=tuple(a.tokens),
            arrival=a.req.arrival, admitted=a.admitted, finished=when,
            first_token=ft))
        alloc = self.allocator
        if alloc is not None:
            alloc.free(a.req.rid)
            if a.prefix_key is not None:
                alloc.release_prefix(a.prefix_key)
        st.slots[i] = None
        if self.stats is not None and hasattr(self.stats, "observe"):
            # observed completion lengths feed the optimistic-admission
            # stats online — the next reservation's E[blocks] + k·sigma
            # tracks the live workload, not just the profiled trace
            self.stats.observe(len(a.req.prompt),
                               len(a.req.prompt) + a.req.max_new - 1)

    def _step(self, st: _RunState) -> None:
        """One engine tick: fault-plan events → deadline sweep → arrivals
        (shed under rung 4) → ladder update → proactive eviction →
        admission / prefill chunks / batched decode (all executor work
        skipped while retry backoff is armed) → retention → audit."""
        alloc = self.allocator
        ev0 = self._evictions
        canc0 = len(st.cancellations)
        af0 = st.alloc_faults
        event = False
        fp = self.faults
        if fp is not None:
            for t, frac in (getattr(fp, "shrinks", ()) or ()):
                if t == st.tick and alloc is not None:
                    n = min(int(frac * alloc.n_blocks), alloc.n_blocks - 1)
                    if n > 0:
                        alloc.shrink(n)
                        st.shrunk += n
                        event = True
            for t, rid in (getattr(fp, "cancels", ()) or ()):
                if t == st.tick and self._cancel_rid(st, rid, "chaos"):
                    event = True
            for t, lane, dur in (getattr(fp, "stalls", ()) or ()):
                if t == st.tick and 0 <= lane < self.n_slots and dur > 0:
                    st.stalled[lane] = max(st.stalled.get(lane, 0), dur)
                    event = True
        if self.deadline:
            self._sweep_deadlines(st)
        while st.pending and st.pending[0].arrival <= st.tick:
            req = st.pending.popleft()
            if st.rung >= RUNG_SHED:
                st.shed += 1
                self._cancel_queued(st, req, "shed")
            else:
                st.queue.append(req)
        self._ladder_update(st)
        if (self.ladder is not None and st.rung >= RUNG_EVICT
                and alloc is not None
                and alloc.committed > alloc.n_blocks):
            # rung 3: commitments overhang the (shrunken) pool — evict one
            # victim per tick proactively instead of waiting for the free
            # list to run dry mid-decode
            occ = sum(s is not None for s in st.slots)
            if occ >= 2:         # never thrash the only lane in and out
                self._evict(st.slots, self._pick_victim(st.slots),
                            st.queue)
        admitted = chunked = 0
        decoded = False
        waiting = st.exec_wait > 0
        if waiting:
            st.exec_wait -= 1
            st.backoff_ticks += 1
            st.peak_queue = max(st.peak_queue, len(st.queue))
        else:
            admitted, calls, ptoks = self._admit(st.queue, st.slots,
                                                 st.tick)
            st.prefills += admitted
            st.prefill_calls += calls
            st.prefill_tokens += ptoks
            if self.chunk_prefill and not st.exec_wait:
                chunked, ctoks = self._advance_chunks(st.slots, st.queue)
                st.chunk_calls += chunked
                st.prefill_tokens += ctoks
            st.peak_queue = max(st.peak_queue, len(st.queue))
            st.max_concurrent = max(st.max_concurrent,
                                    sum(s is not None for s in st.slots))
            # single-token requests complete at admission (prefill emitted
            # their only token)
            for i in range(self.n_slots):
                if (st.slots[i] is not None and not st.slots[i].pending
                        and st.slots[i].remaining == 0):
                    self._finish(st, i, st.tick)
            # mid-prefill lanes hold a slot but have no decode cursor yet;
            # stalled lanes sit out the tick (their streams just pause)
            active = [i for i in range(self.n_slots)
                      if st.slots[i] is not None
                      and not st.slots[i].pending
                      and st.stalled.get(i, 0) <= 0]
            if st.exec_wait:     # a fault mid-tick armed backoff
                active = []
            if alloc is not None and active:
                # allocate-on-decode-tick: a lane crossing into a new
                # logical block gets a physical block from the free list
                # (its reservation guarantees one in worst mode; expected
                # mode overdrafts and EVICTS on a dry pool) — freshly
                # re-linked blocks are invalidated first so a previous
                # owner's positions can't leak through the mask
                fresh: List[int] = []
                kept: List[int] = []
                for i in active:
                    a = st.slots[i]
                    if a is None or st.slots[i] is not a:
                        continue         # evicted earlier this tick
                    if self._alloc_through(st.slots, i,
                                           a.pos // alloc.block_size,
                                           st.queue, fresh) == 1:
                        kept.append(i)
                if fresh:
                    self.executor.fresh_blocks(fresh)
                active = [i for i in kept if st.slots[i] is not None]
            if active:
                tokens = [st.slots[i].tokens[-1]
                          if st.slots[i] is not None and st.slots[i].tokens
                          else 0 for i in range(self.n_slots)]
                positions = [st.slots[i].pos if st.slots[i] is not None
                             else 0 for i in range(self.n_slots)]
                nxt = None
                try:
                    if alloc is not None:
                        tables = [st.slots[i].table
                                  if st.slots[i] is not None else []
                                  for i in range(self.n_slots)]
                        nxt = self.executor.decode(tokens, positions,
                                                   tables=tables,
                                                   lanes=active)
                    else:
                        nxt = self.executor.decode(tokens, positions,
                                                   lanes=active)
                except TransientExecutorError:
                    self._exec_fault(st)  # nothing mutated: replay later
                if nxt is not None:
                    st.exec_fails = 0
                    decoded = True
                    st.decode_ticks += 1
                    st.useful += len(active)
                    width_fn = getattr(self.executor, "decode_width",
                                       None)
                    width = width_fn(len(active)) if width_fn else None
                    st.lane_tokens += (width if width is not None
                                       else self.n_slots)
                    for i in active:
                        a = st.slots[i]
                        if a.first_token < 0:
                            a.first_token = st.tick
                        a.tokens.append(int(nxt[i]))
                        a.pos += 1
                        a.remaining -= 1
                        if a.remaining == 0:
                            self._finish(st, i, st.tick)
                    retain = self._eff_retain(st)
                    if alloc is not None and retain:
                        mass_fn = getattr(self.executor, "block_masses",
                                          None)
                        masses = mass_fn() if mass_fn is not None else {}
                        for i in active:
                            if st.slots[i] is not None:
                                st.block_drops += self._retain(
                                    st.slots[i], masses.get(i), retain)
        if decoded:
            pass
        elif (waiting or event or admitted or chunked
                or self._evictions > ev0 or len(st.cancellations) > canc0
                or st.alloc_faults > af0 or st.exec_wait > 0
                or st.stalled):
            # admissions / chunks / evictions / cancellations / fault
            # events / backoff waits did real work this tick even though
            # no decode ran — the taxonomy invariant is
            # ticks == decode + admit + idle
            st.admit_only += 1
        else:
            st.idle += 1         # pure waiting on arrivals
        for i in list(st.stalled):
            st.stalled[i] -= 1
            if st.stalled[i] <= 0:
                del st.stalled[i]
        # first tokens emitted by prefill this tick
        for i in range(self.n_slots):
            a = st.slots[i]
            if a is not None and a.tokens and a.first_token < 0:
                a.first_token = st.tick
        self._audit(st)
        st.tick += 1

    def _report(self, st: _RunState) -> ServeReport:
        alloc = self.allocator
        st.completions.sort(key=lambda c: c.rid)
        degradation: Dict = {}
        if self.ladder is not None:
            degradation = {
                "max_rung": st.max_rung,
                "max_rung_name": RUNG_NAMES.get(st.max_rung, "?"),
                "final_rung": st.rung,
                "rung_ticks": {RUNG_NAMES[k]: v
                               for k, v in sorted(st.rung_ticks.items())},
                "events": list(st.ladder_events)}
        observed: Dict = {}
        if self.stats is not None and hasattr(self.stats, "summary"):
            observed = self.stats.summary()
        return ServeReport(
            policy=self.policy, n_slots=self.n_slots,
            completions=list(st.completions), ticks=st.tick,
            decode_ticks=st.decode_ticks, useful_slot_tokens=st.useful,
            idle_ticks=st.idle, peak_queue=st.peak_queue,
            max_concurrent=st.max_concurrent, prefills=st.prefills,
            prefill_calls=st.prefill_calls,
            n_blocks=(alloc.n_blocks if alloc else 0),
            peak_blocks=(alloc.peak_in_use if alloc else 0),
            admit_ticks=st.admit_only, decode_lane_tokens=st.lane_tokens,
            chunk_calls=st.chunk_calls, evictions=self._evictions,
            block_drops=st.block_drops, prefill_tokens=st.prefill_tokens,
            cancellations=sorted(st.cancellations, key=lambda c: c.rid),
            shed=st.shed, exec_faults=st.exec_faults,
            backoff_ticks=st.backoff_ticks, alloc_faults=st.alloc_faults,
            shrunk_blocks=st.shrunk, audits=st.audits,
            audit_failures=st.audit_failures, degradation=degradation,
            observed_lengths=observed)

    # -- snapshot / restore -------------------------------------------------

    def snapshot(self) -> EngineSnapshot:
        """Serialize a suspended run (`run(..., stop_tick=...)`) so a
        FRESH engine — new allocator, new executor, even a different lane
        count — can `resume` it token-identically. In-flight lanes become
        resume records (request + emitted tokens) that re-enter through
        the eviction re-prefill path: the physical pool is
        re-materialized on restore, never serialized."""
        st = self._st
        if st is None:
            raise RuntimeError("no run to snapshot — suspend one first "
                               "with run(trace, stop_tick=...)")
        reqs: Dict[int, Request] = {}
        for r in list(st.pending) + list(st.queue):
            reqs[r.rid] = r
        lanes: List[Optional[Dict]] = []
        for a in st.slots:
            if a is None:
                lanes.append(None)
                continue
            reqs[a.req.rid] = a.req
            emitted = list(a.tokens) if a.tokens else list(a.prior)
            lanes.append({"rid": a.req.rid, "tokens": emitted,
                          "admitted": a.admitted,
                          "first_token": a.first_token})
        resume = {int(rid): {"tokens": list(m["tokens"]),
                             "admitted": m["admitted"],
                             "first_token": m["first_token"]}
                  for rid, m in self._resume.items()}
        ladder = None
        if self.ladder is not None:
            ladder = {"rung": st.rung, "max_rung": st.max_rung,
                      "hot": st.hot, "cool": st.cool,
                      "events": list(st.ladder_events),
                      "rung_ticks": dict(st.rung_ticks)}
        stats_state = None
        if self.stats is not None and hasattr(self.stats, "state_dict"):
            stats_state = self.stats.state_dict()
        return EngineSnapshot(
            tick=st.tick,
            requests=[dataclasses.asdict(reqs[k]) for k in sorted(reqs)],
            pending=[r.rid for r in st.pending],
            queue=[r.rid for r in st.queue],
            lanes=lanes,
            resume=resume,
            completions=[dataclasses.asdict(c) for c in st.completions],
            cancellations=[dataclasses.asdict(c)
                           for c in st.cancellations],
            counters={f: getattr(st, f) for f in _COUNTER_FIELDS},
            evictions=self._evictions,
            ladder=ladder,
            stats=stats_state,
            config={"n_slots": self.n_slots, "policy": self.policy,
                    "chunk_prefill": self.chunk_prefill})

    def resume(self, snap: EngineSnapshot, max_ticks: int = 1_000_000,
               stop_tick: Optional[int] = None) -> ServeReport:
        """Restore a snapshot onto THIS engine (built with a FRESH
        allocator/executor) and run it to completion (or `stop_tick`).
        Snapshot lanes re-enter via re-prefill of prompt + emitted
        tokens, in slot order, ahead of the snapshot queue —
        suffix-consistent executors make the continuation token-identical
        to the uninterrupted run. Requests that no longer fit a smaller
        restore pool are cancelled (reason "capacity"), not deadlocked."""
        alloc = self.allocator
        if alloc is not None and (alloc.in_use or alloc._reserved):
            raise ValueError("resume needs a FRESH allocator: the "
                             "snapshot re-materializes every lane's "
                             "blocks via re-prefill")
        by_rid = {d["rid"]: Request(**{**d, "prompt": tuple(d["prompt"])})
                  for d in snap.requests}
        st = _RunState(
            pending=collections.deque(by_rid[r] for r in snap.pending),
            queue=collections.deque(),
            slots=[None] * self.n_slots,
            completions=[Completion(**{**d, "tokens": tuple(d["tokens"])})
                         for d in snap.completions],
            cancellations=[Cancellation(
                **{**d, "tokens": tuple(d["tokens"])})
                for d in snap.cancellations])
        st.tick = int(snap.tick)
        for f in _COUNTER_FIELDS:
            setattr(st, f, snap.counters.get(f, 0))
        self._resume = {int(rid): {"tokens": list(m["tokens"]),
                                   "admitted": m["admitted"],
                                   "first_token": m["first_token"]}
                        for rid, m in snap.resume.items()}
        self._prefix_state = {}
        self._evictions = int(snap.evictions)
        for rec in snap.lanes:
            if rec is None:
                continue
            self._resume[rec["rid"]] = {
                "tokens": list(rec["tokens"]),
                "admitted": rec["admitted"],
                "first_token": rec["first_token"]}
            st.queue.append(by_rid[rec["rid"]])
        for rid in snap.queue:
            st.queue.append(by_rid[rid])
        if snap.ladder and self.ladder is not None:
            st.rung = snap.ladder.get("rung", 0)
            st.max_rung = snap.ladder.get("max_rung", st.rung)
            st.hot = snap.ladder.get("hot", 0)
            st.cool = snap.ladder.get("cool", 0)
            st.ladder_events = list(snap.ladder.get("events", []))
            st.rung_ticks = {
                int(k): v for k, v in
                (snap.ladder.get("rung_ticks") or {}).items()}
        if (snap.stats and self.stats is not None
                and hasattr(self.stats, "load_state")):
            self.stats.load_state(snap.stats)
        self._st = st
        return self._loop(st, max_ticks, stop_tick)
