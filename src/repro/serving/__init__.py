"""Memory-governed serving: WSMC capacity prediction drives continuous
batching over a slotted KV pool.

`trace`, `engine` and `faults` are jax-free (the scheduler is a
deterministic state machine and the chaos harness injects into it); the
jax-backed executor lives in `repro.serving.executor` and is imported
lazily so planning/metrics code never touches device state.
"""
from repro.serving.engine import (  # noqa: F401
    AUDIT_MODES, AllocationFault, BlockAllocator, Cancellation, Completion,
    DoubleFree, Engine, EngineFault, EngineSnapshot, LadderConfig,
    LedgerCorruption, NegativeRefcount, POLICIES, PoolExhausted,
    RESERVATIONS, RUNG_NAMES, ScriptedExecutor, ServeReport,
    TransientExecutorError,
)
from repro.serving.faults import (  # noqa: F401
    ChaosAllocator, ChaosExecutor, FaultPlan, leak_check,
    survivor_mismatches,
)
from repro.serving.trace import (  # noqa: F401
    LengthStats, OnlineLengthStats, Request, describe_trace, length_stats,
    synthetic_trace, trace_context,
)


def __getattr__(name):
    if name in ("JaxExecutor", "PagedJaxExecutor"):
        from repro.serving import executor
        return getattr(executor, name)
    if name in ("AgreementReport", "token_agreement", "measure_bend"):
        from repro.serving import quality
        return getattr(quality, name)
    raise AttributeError(name)
