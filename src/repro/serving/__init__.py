"""Memory-governed serving: WSMC capacity prediction drives continuous
batching over a slotted KV pool.

`trace` and `engine` are jax-free (the scheduler is a deterministic state
machine); the jax-backed executor lives in `repro.serving.executor` and is
imported lazily so planning/metrics code never touches device state.
"""
from repro.serving.engine import (  # noqa: F401
    BlockAllocator, Completion, Engine, POLICIES, PoolExhausted,
    RESERVATIONS, ScriptedExecutor, ServeReport,
)
from repro.serving.trace import (  # noqa: F401
    LengthStats, Request, describe_trace, length_stats, synthetic_trace,
    trace_context,
)


def __getattr__(name):
    if name in ("JaxExecutor", "PagedJaxExecutor"):
        from repro.serving import executor
        return getattr(executor, name)
    if name in ("AgreementReport", "token_agreement", "measure_bend"):
        from repro.serving import quality
        return getattr(quality, name)
    raise AttributeError(name)
