"""Deterministic synthetic serving traces.

A trace is a list of `Request`s ordered by arrival tick. Everything is
drawn from a seeded `random.Random`, so the same (seed, knobs) always
replays the same workload — the engine tests and the CI smoke job pin
their metrics against that determinism. Prompt/generation lengths are
drawn from small caller-chosen bucket sets (mixed-length traffic with a
bounded number of prefill compile shapes); arrivals are exponential
inter-arrival gaps rounded to whole engine ticks.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: `prompt` token ids arriving at engine tick
    `arrival`, asking for `max_new` greedily decoded tokens."""
    rid: int
    arrival: int
    prompt: Tuple[int, ...]
    max_new: int

    @property
    def context(self) -> int:
        """Ring-cache extent this request needs: prompt + generation."""
        return len(self.prompt) + self.max_new


def synthetic_trace(n_requests: int, *, vocab_size: int, seed: int = 0,
                    prompt_lens: Sequence[int] = (4, 8, 16),
                    gen_lens: Sequence[int] = (2, 4, 8),
                    mean_interarrival: float = 1.0) -> List[Request]:
    """The deterministic mixed-length trace the serve driver replays.

    Token ids stay in [2, vocab_size) (0/1 reserved, matching the other
    drivers' prompt generation). `mean_interarrival` <= 0 makes every
    request arrive at tick 0 (a closed-loop burst)."""
    if n_requests < 1:
        raise ValueError("synthetic_trace needs n_requests >= 1")
    if min(prompt_lens) < 1 or min(gen_lens) < 1:
        raise ValueError("prompt/gen length buckets must be >= 1")
    rng = random.Random(seed)
    t = 0
    out = []
    for rid in range(n_requests):
        p = rng.choice(tuple(prompt_lens))
        g = rng.choice(tuple(gen_lens))
        prompt = tuple(rng.randrange(2, vocab_size) for _ in range(p))
        out.append(Request(rid=rid, arrival=t, prompt=prompt, max_new=g))
        if mean_interarrival > 0:
            t += int(rng.expovariate(1.0 / mean_interarrival))
    return out


def trace_context(trace: Sequence[Request]) -> int:
    """The pool-wide ring extent: the largest prompt+gen in the trace."""
    return max(r.context for r in trace)


def describe_trace(trace: Sequence[Request]) -> str:
    p = sorted({len(r.prompt) for r in trace})
    g = sorted({r.max_new for r in trace})
    span = trace[-1].arrival - trace[0].arrival if trace else 0
    return (f"{len(trace)} requests over {span + 1} ticks, "
            f"prompt_lens={p} gen_lens={g} context={trace_context(trace)}")
