"""Deterministic synthetic serving traces.

A trace is a list of `Request`s ordered by arrival tick. Everything is
drawn from a seeded `random.Random`, so the same (seed, knobs) always
replays the same workload — the engine tests and the CI smoke job pin
their metrics against that determinism. Prompt/generation lengths are
drawn from small caller-chosen bucket sets (mixed-length traffic with a
bounded number of prefill compile shapes); arrivals are exponential
inter-arrival gaps rounded to whole engine ticks.

Two workload dimensions ride on top for the overload story:

  prefix_len   — every request shares one seeded system-prompt prefix
                 (`prefix_id=0`) prepended to its own tokens, the traffic
                 shape where refcounted prefix-block sharing pays.
  slo_classes  — a per-request SLO class (0 = strictest); the engine's
                 eviction policy preempts the loosest class first.

Both draw from a SECOND seeded stream so enabling them never perturbs
the base trace: `synthetic_trace(..., prefix_len=P)[i].prompt[P:]` is
exactly the prompt the same call without a prefix would produce.

`length_stats` summarizes the trace's written-length distribution
(mean/std/max per prompt bucket) — the workload-specific profile the
optimistic admission mode reserves `E[blocks] + k·sigma` from.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: `prompt` token ids arriving at engine tick
    `arrival`, asking for `max_new` greedily decoded tokens. `prefix_id`
    names the shared system-prompt its first `prefix_len` prompt tokens
    are (None = no shared prefix); `slo` is the latency class (0 =
    strictest — evicted last under pool pressure)."""
    rid: int
    arrival: int
    prompt: Tuple[int, ...]
    max_new: int
    prefix_id: Optional[int] = None
    prefix_len: int = 0
    slo: int = 0

    @property
    def context(self) -> int:
        """Ring-cache extent this request needs: prompt + generation."""
        return len(self.prompt) + self.max_new


def synthetic_trace(n_requests: int, *, vocab_size: int, seed: int = 0,
                    prompt_lens: Sequence[int] = (4, 8, 16),
                    gen_lens: Sequence[int] = (2, 4, 8),
                    mean_interarrival: float = 1.0,
                    prefix_len: int = 0,
                    slo_classes: Sequence[int] = (0,)) -> List[Request]:
    """The deterministic mixed-length trace the serve driver replays.

    Token ids stay in [2, vocab_size) (0/1 reserved, matching the other
    drivers' prompt generation). `mean_interarrival` <= 0 makes every
    request arrive at tick 0 (a closed-loop burst). `prompt_lens` sizes
    each request's OWN tokens; `prefix_len > 0` prepends one shared
    seeded prefix to every prompt (so full prompt lengths are
    `prefix_len + bucket`). Prefix tokens and SLO draws come from a
    separate seeded stream, so the base trace is unchanged by them."""
    if n_requests < 1:
        raise ValueError("synthetic_trace needs n_requests >= 1")
    if min(prompt_lens) < 1 or min(gen_lens) < 1:
        raise ValueError("prompt/gen length buckets must be >= 1")
    if prefix_len < 0:
        raise ValueError(f"prefix_len must be >= 0, got {prefix_len}")
    if not slo_classes:
        raise ValueError("slo_classes must be non-empty")
    rng = random.Random(seed)
    aux = random.Random((seed << 1) ^ 0x9E3779B9)   # never perturbs `rng`
    prefix = tuple(aux.randrange(2, vocab_size) for _ in range(prefix_len))
    classes = tuple(slo_classes)
    t = 0
    out = []
    for rid in range(n_requests):
        p = rng.choice(tuple(prompt_lens))
        g = rng.choice(tuple(gen_lens))
        own = tuple(rng.randrange(2, vocab_size) for _ in range(p))
        out.append(Request(rid=rid, arrival=t, prompt=prefix + own,
                           max_new=g,
                           prefix_id=(0 if prefix_len else None),
                           prefix_len=prefix_len,
                           slo=aux.choice(classes)))
        if mean_interarrival > 0:
            t += int(rng.expovariate(1.0 / mean_interarrival))
    return out


def trace_context(trace: Sequence[Request]) -> int:
    """The pool-wide ring extent: the largest prompt+gen in the trace."""
    return max(r.context for r in trace)


@dataclasses.dataclass(frozen=True)
class LengthStats:
    """Written-length distribution of a trace — the workload-specific
    profile optimistic admission reserves from. A request at prompt
    length P writes `P + max_new - 1` positions; `by_prompt[P]` holds
    (mean, std, max) over the trace's requests at that prompt bucket,
    and the top-level fields the whole-trace fallback for unseen
    buckets."""
    by_prompt: Dict[int, Tuple[float, float, int]]
    mean: float
    std: float
    max: int

    def expected_written(self, prompt_len: int, k: float = 0.0) -> float:
        """`E[written | prompt bucket] + k·sigma`, clamped to [1, bucket
        max] — the safety-margined expected footprint in positions."""
        m, s, mx = self.by_prompt.get(int(prompt_len),
                                      (self.mean, self.std, self.max))
        return max(1.0, min(m + max(k, 0.0) * s, float(mx)))


class OnlineLengthStats:
    """Exponentially-weighted online written-length stats — the live
    feedback loop closing the gap between the PROFILED distribution and
    the traffic actually served. Seeded from a static `LengthStats`
    (`base`), it is a drop-in for the engine's `stats=` parameter:
    `expected_written` answers from the EW estimate once a bucket has
    been observed and falls back to the profile until then, and the
    engine calls `observe` on every completion, so optimistic
    admission's `E[blocks] + k·sigma` reservation tracks the workload as
    it drifts. `state_dict`/`load_state` ride the engine snapshot so a
    restored engine keeps its learned distribution."""

    def __init__(self, base: Optional[LengthStats] = None,
                 alpha: float = 0.25):
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.base = base
        self.alpha = float(alpha)
        # prompt bucket -> [ew_mean, ew_var, max_seen, n_observed]
        self._ew: Dict[int, List[float]] = {}

    def observe(self, prompt_len: int, written: int) -> None:
        """Fold one completed request's written length into its bucket.
        The first observation seeds the EW state from the profile's
        bucket (so one outlier can't whipsaw the reservation), then each
        update is a standard EW mean/variance step."""
        p = int(prompt_len)
        w = float(written)
        cell = self._ew.get(p)
        if cell is None:
            if self.base is not None:
                m0, s0, mx0 = self.base.by_prompt.get(
                    p, (self.base.mean, self.base.std, self.base.max))
                cell = [float(m0), float(s0) ** 2, float(mx0), 0.0]
            else:
                cell = [w, 0.0, w, 0.0]
            self._ew[p] = cell
        a = self.alpha
        d = w - cell[0]
        cell[0] += a * d
        cell[1] = (1.0 - a) * (cell[1] + a * d * d)
        cell[2] = max(cell[2], w)
        cell[3] += 1.0

    def expected_written(self, prompt_len: int, k: float = 0.0) -> float:
        """`E[written | bucket] + k·sigma` from the EW estimate (profile
        fallback for never-observed buckets), clamped to [1, max seen]."""
        cell = self._ew.get(int(prompt_len))
        if cell is None or cell[3] < 1:
            if self.base is not None:
                return self.base.expected_written(prompt_len, k)
            return 1.0
        m, var, mx = cell[0], cell[1], cell[2]
        return max(1.0, min(m + max(k, 0.0) * (var ** 0.5), mx))

    def sigma(self, prompt_len: int) -> float:
        """The live per-bucket sigma (0 for unobserved buckets)."""
        cell = self._ew.get(int(prompt_len))
        return (cell[1] ** 0.5) if cell and cell[3] >= 1 else 0.0

    def summary(self) -> Dict:
        """What `ServeReport.observed_lengths` carries: observation count
        plus the observation-weighted mean/sigma and per-bucket state."""
        obs = sum(c[3] for c in self._ew.values())
        if not obs:
            return {"observations": 0}
        mean = sum(c[0] * c[3] for c in self._ew.values()) / obs
        sig = (sum(c[1] * c[3] for c in self._ew.values()) / obs) ** 0.5
        return {"observations": int(obs),
                "mean_written": round(mean, 3),
                "sigma_written": round(sig, 3),
                "by_prompt": {p: {"mean": round(c[0], 3),
                                  "sigma": round(c[1] ** 0.5, 3),
                                  "max": int(c[2]), "n": int(c[3])}
                              for p, c in sorted(self._ew.items())}}

    def state_dict(self) -> Dict:
        return {str(p): list(c) for p, c in self._ew.items()}

    def load_state(self, state: Dict) -> None:
        self._ew = {int(p): [float(x) for x in c]
                    for p, c in state.items()}


def length_stats(trace: Sequence[Request]) -> LengthStats:
    """Per-prompt-bucket (mean, std, max) of written positions."""
    if not trace:
        raise ValueError("length_stats needs a non-empty trace")

    def _stats(vals: List[int]) -> Tuple[float, float, int]:
        m = sum(vals) / len(vals)
        var = sum((v - m) ** 2 for v in vals) / len(vals)
        return (m, var ** 0.5, max(vals))

    groups: Dict[int, List[int]] = {}
    for r in trace:
        groups.setdefault(len(r.prompt), []).append(
            len(r.prompt) + r.max_new - 1)
    overall = _stats([w for vals in groups.values() for w in vals])
    return LengthStats(by_prompt={p: _stats(v) for p, v in groups.items()},
                       mean=overall[0], std=overall[1], max=overall[2])


def describe_trace(trace: Sequence[Request]) -> str:
    p = sorted({len(r.prompt) for r in trace})
    g = sorted({r.max_new for r in trace})
    span = trace[-1].arrival - trace[0].arrival if trace else 0
    pfx = ""
    if any(r.prefix_id is not None for r in trace):
        pfx = f" shared_prefix={max(r.prefix_len for r in trace)}"
    return (f"{len(trace)} requests over {span + 1} ticks, "
            f"prompt_lens={p} gen_lens={g} context={trace_context(trace)}"
            f"{pfx}")
