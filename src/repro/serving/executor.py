"""The jax executors behind the serving engine: a slotted ring-cache pool
(baseline) and a paged KV block pool, each with jitted prefill / batched-
decode steps.

One decode compile serves the whole run (the pool width and context are
fixed); prefill compiles once per distinct prompt length — synthetic
traces draw prompts from small bucket sets, so the compile count stays
bounded and every compile serves traffic (zero throwaway compiles when
planning went through the simulator). Engine-level batched prefill pads
each same-tick, same-bucket admission group to the pool width, so a burst
of admissions costs ONE prefill call instead of one per request.

The paged executor additionally supports lane COMPACTION (decode at the
smallest bucketed width covering the active lanes, one compile per
touched bucket instead of full-width padding every tick) and CHUNKED
prefill (append long prompts to the live pool kv_block-aligned chunks at
a time so one long prompt stops holding the tick hostage).

The chunked step is STATELESS per call — each chunk carries its own
absolute `starts` and block tables, so the engine reuses it unchanged for
the overload machinery: a prefix SHARER prefills only its private suffix
(starts at `shared_blocks * kv_block`, reading the shared prefix KV
through its seeded table — the boundary partial block is copy-on-write by
recompute into an owned block), and an EVICTED request re-prefills
`prompt + already-emitted tokens` from scratch into freshly allocated
blocks. No executor state survives an eviction; everything is the block
tables.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.runtime import serve_step as SS


def _compile_count(fn) -> Optional[int]:
    try:
        return int(fn._cache_size())
    except AttributeError:          # older jax: no cache-size probe
        return None


def _sum_compile_counts(*fns) -> Optional[int]:
    """Sum per-step compile counts, propagating 'unknown' (None) instead of
    arithmetic on sentinels."""
    counts = [_compile_count(fn) for fn in fns]
    if any(c is None for c in counts):
        return None
    return sum(counts)


def _pad_token(cfg: ModelConfig) -> int:
    """Dummy token id for padding rows — must be a REAL vocab entry (tiny
    test configs can have vocab_size <= 2, where a hardcoded id would
    index past the embedding table)."""
    pad = min(2, cfg.vocab_size - 1)
    assert 0 <= pad < cfg.vocab_size, cfg.vocab_size
    return pad


def _pad_batch(width: int, slots: Sequence[int],
               prompts: Sequence[Sequence[int]], pad_token: int):
    """Pack a same-length admission group into pool-width arrays: padding
    rows carry dummy prompts (`pad_token`) and index `width` — out of
    bounds, so the prefill scatter drops them (mode='drop')."""
    p = len(prompts[0])
    toks = np.full((width, p), pad_token, np.int32)
    idx = np.full((width,), width, np.int32)
    for i, (s, pr) in enumerate(zip(slots, prompts)):
        toks[i] = pr
        idx[i] = s
    return jnp.asarray(toks), jnp.asarray(idx)


def _pow2_buckets(n: int) -> tuple:
    """Power-of-two widths up to n, always including n itself."""
    out = []
    w = 1
    while w < n:
        out.append(w)
        w *= 2
    out.append(int(n))
    return tuple(out)


def _cover(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n (buckets sorted ascending; n <= max)."""
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


class JaxExecutor:
    """Executes engine slot operations against a real parameter set.

    The pool cache (batch dim = slot index) lives here and is donated
    through every step: prefill overwrites one slot in place, decode
    advances all slots in one batched heterogeneous-position step (the
    ring cache's slot = pos % L layout needs no per-sequence alignment).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int,
                 context: int, settings: Optional[M.ModelSettings] = None):
        self.params = params
        self.cfg = cfg
        self.settings = settings
        self.n_slots = int(n_slots)
        self.context = int(context)
        self.pool = SS.init_slot_pool(cfg, self.n_slots, self.context)
        self.prefills = 0
        self.decodes = 0

    def _steps(self):
        # fetched per call: memoized on (cfg, settings, ambient sharding
        # context), so a second executor for the same model (--policy both)
        # reuses the compiled steps while a different mesh/rules retraces
        return SS.slot_serve_steps(self.cfg, self.settings)

    def prefill(self, slot: int, prompt: Sequence[int]) -> int:
        prefill_step, _, _ = self._steps()
        tokens = jnp.asarray(list(prompt), jnp.int32)[None, :]
        logits, self.pool = prefill_step(self.params, tokens, slot,
                                         self.pool, context=self.context)
        self.prefills += 1
        return int(jnp.argmax(logits[0], axis=-1))

    def prefill_batch(self, slots: Sequence[int],
                      prompts: Sequence[Sequence[int]],
                      tables=None) -> List[int]:
        """One padded prefill for a same-bucket admission group: tokens are
        padded to the pool width W (dummy rows use the config's pad token)
        and the scatter drops rows whose slot index is W (out of bounds)."""
        _, batch_step, _ = self._steps()
        toks, slot_arr = _pad_batch(self.n_slots, slots, prompts,
                                    _pad_token(self.cfg))
        logits, self.pool = batch_step(self.params, toks, slot_arr,
                                       self.pool, context=self.context)
        self.prefills += len(slots)        # per-request, like the engine
        out = np.asarray(jnp.argmax(logits, axis=-1))
        return [int(out[i]) for i in range(len(slots))]

    def decode_width(self, n_active: int) -> int:
        """The batch width a decode tick with `n_active` lanes computes at
        (the ring pool always runs full width)."""
        return self.n_slots

    def decode(self, tokens: Sequence[int], positions: Sequence[int],
               tables=None, lanes=None) -> List[int]:
        _, _, decode_step = self._steps()
        t = jnp.asarray(list(tokens), jnp.int32)[:, None]
        p = jnp.asarray(list(positions), jnp.int32)
        logits, self.pool = decode_step(self.params, t, p, self.pool,
                                        context=self.context)
        self.decodes += 1
        return np.asarray(jnp.argmax(logits, axis=-1)).astype(int).tolist()

    def compile_counts(self) -> dict:
        """Compiled-variant counts of the serving steps (prefill: one per
        prompt-length bucket; decode: one) — the driver reports them so
        'every compile served traffic' is checkable. None = unknown (older
        jax exposes no cache-size probe)."""
        single, batch, decode_step = self._steps()
        return {"prefill": _sum_compile_counts(batch, single),
                "decode": _compile_count(decode_step)}


class PagedJaxExecutor:
    """Engine lane operations over the paged KV block pool.

    Full-context attention layers store KV in `n_blocks` shared blocks of
    `kv_block` positions (physical id 0 is the scratch block for inactive
    lanes, so the pool is allocated one block larger); each active lane's
    logical layout reaches the pool through its block table. Prefill
    scatters whole blocks, padded to lane width per prompt bucket like the
    ring executor.

    Decode runs either full width (one compile at lane width regardless of
    pool occupancy) or, with `compact=True`, at the smallest bucketed
    width covering the active lanes: active lanes are packed to the front,
    their tables trimmed to the bucketed maximum of blocks actually
    allocated, and the per-lane caches gathered/scattered around the step
    — so a tick with 3 active sequences stops paying for 24 padded lanes,
    at the cost of one compile per touched (lane, table) width bucket.

    `chunk > 0` enables chunked prefill (`prefill_chunks`): prompts are
    appended to the live pool `chunk` positions at a time, interleaved
    with decode ticks by the engine. Exactness relies on every mixer
    resuming from carried state, which holds for attention (the cache IS
    the state) but not for mLSTM's fresh-scan sequence path — hence the
    all-attention gate.
    """

    def __init__(self, params, cfg: ModelConfig, *, n_lanes: int,
                 n_blocks: int, kv_block: int, context: int,
                 settings: Optional[M.ModelSettings] = None,
                 compact: bool = False,
                 lane_buckets: Optional[Sequence[int]] = None,
                 chunk: int = 0, kv_quant: str = "none",
                 kv_retain: int = 0, track_mass: bool = False):
        if kv_block < 1:
            raise ValueError(f"kv_block must be >= 1, got {kv_block}")
        if kv_retain < 0:
            raise ValueError(f"kv_retain must be >= 0, got {kv_retain}")
        self.params = params
        self.cfg = cfg
        self.kv_quant = str(kv_quant)
        self.kv_retain = int(kv_retain)
        if self.kv_retain or track_mass:
            # retention ranks blocks by attention mass — decode steps must
            # account it, so bake track_mass into the jitted settings.
            # `track_mass=True` alone pays the accounting without a
            # standing retention cap, for engines whose degradation
            # ladder may engage `bend_retain` mid-run.
            base = settings or M.ModelSettings()
            settings = dataclasses.replace(
                base, attn=dataclasses.replace(base.attn, track_mass=True))
        self.settings = settings
        self.n_lanes = int(n_lanes)
        self.kv_block = int(kv_block)
        # block-align the ring extent so logical blocks tile it exactly
        self.context = -(-int(context) // kv_block) * kv_block
        self.max_blocks = self.context // kv_block
        self.n_blocks = int(n_blocks)
        self.compact = bool(compact)
        if lane_buckets is None:
            self.lane_buckets = _pow2_buckets(self.n_lanes)
        else:
            bk = sorted({int(b) for b in lane_buckets})
            if not bk or bk[0] < 1:
                raise ValueError(f"bad lane_buckets {lane_buckets}")
            if bk[-1] < self.n_lanes:
                bk.append(self.n_lanes)   # must be able to cover every tick
            self.lane_buckets = tuple(bk)
        self.table_buckets = _pow2_buckets(self.max_blocks)
        self.chunk = int(chunk)
        if self.chunk and self.chunk % self.kv_block:
            raise ValueError(f"chunk={self.chunk} must be a multiple "
                             f"of kv_block={self.kv_block}")
        # recurrent mixers carry their scan state across chunks through
        # the per-lane pool leaves (mlstm_scan initial=, rglru h0, slstm
        # core), so chunked prefill works for any block tree; the engine
        # still refuses prefix_share here — shared prefix blocks hold
        # attention KV only, not the recurrent state at the boundary
        self.has_recurrent = any(not b.is_attn for b in cfg.blocks())
        self.pool = SS.init_paged_pool(cfg, self.n_lanes, self.n_blocks + 1,
                                       kv_block, self.context,
                                       kv_quant=self.kv_quant)
        self.prefills = 0
        self.decodes = 0
        self.chunk_calls = 0
        # lane -> per-logical-block attention mass from the LAST decode
        # tick (only populated when kv_retain or track_mass enables the
        # accounting)
        self._last_mass: Dict[int, np.ndarray] = {}

    def _steps(self):
        return SS.paged_serve_steps(self.cfg, self.settings)

    def _table_array(self, tables: Sequence[Sequence[int]], rows: int,
                     width: Optional[int] = None) -> np.ndarray:
        width = self.max_blocks if width is None else width
        out = np.full((rows, width), -1, np.int32)
        for i, tbl in enumerate(tables):
            if len(tbl) > width:
                raise ValueError(f"lane {i}: table of {len(tbl)} blocks "
                                 f"exceeds table width {width}")
            out[i, :len(tbl)] = tbl
        return out

    def prefill_batch(self, lanes: Sequence[int],
                      prompts: Sequence[Sequence[int]],
                      tables: Sequence[Sequence[int]]) -> List[int]:
        prefill_step = self._steps()[0]
        w = self.n_lanes
        toks, lane_arr = _pad_batch(w, lanes, prompts, _pad_token(self.cfg))
        tbl = self._table_array(list(tables) + [[]] * (w - len(tables)), w)
        logits, self.pool = prefill_step(self.params, toks, lane_arr,
                                         jnp.asarray(tbl), self.pool,
                                         context=self.context)
        self.prefills += len(lanes)        # per-request, like the engine
        out = np.asarray(jnp.argmax(logits, axis=-1))
        return [int(out[i]) for i in range(len(lanes))]

    def fresh_blocks(self, ids: Sequence[int]) -> None:
        """Invalidate re-linked physical blocks (pos = -1) before decode
        reads them through a new owner's table. Padded to a multiple of
        the lane count (scratch block), so the common <= 1 block/lane/tick
        case stays a single compile and chunked prefill's multi-block
        ticks cost at most one more."""
        reset_step = self._steps()[2]
        w = self.n_lanes * max(1, -(-len(ids) // self.n_lanes))
        arr = np.zeros((w,), np.int32)                  # pad -> scratch
        arr[:len(ids)] = list(ids)
        self.pool = reset_step(self.pool, jnp.asarray(arr))

    def reset(self) -> None:
        """Return this executor to as-fresh state WITHOUT rebuilding its
        device buffers: the whole pool's validity metadata is invalidated
        (`serve_step.clear_pool`) and the per-lane chunk/mass bookkeeping
        dropped. `Engine.resume` re-materializes every lane's KV via
        re-prefill, so a reset executor is exactly as good as a new one
        for restoring a snapshot — minus the allocation cost."""
        self.pool = SS.clear_pool(self.pool)
        self._last_mass = {}

    def decode_width(self, n_active: int) -> int:
        """The batch width a decode tick with `n_active` lanes computes at:
        the smallest covering bucket when compacting, else the full pool."""
        if not self.compact:
            return self.n_lanes
        return _cover(max(int(n_active), 1), self.lane_buckets)

    def decode(self, tokens: Sequence[int], positions: Sequence[int],
               tables: Sequence[Sequence[int]], lanes=None) -> List[int]:
        if self.compact and lanes is not None:
            return self._decode_compact(tokens, positions, tables, lanes)
        decode_step = self._steps()[1]
        if lanes is not None:
            # Full-width decode still computes every lane row; rows NOT in
            # `lanes` (empty slots, lanes mid-chunk-prefill) are made INERT
            # (pos -1, empty table) so their write lands in the scratch
            # block / is dropped instead of clobbering live KV through a
            # mid-prefill lane's real block table.
            act = set(int(i) for i in lanes)
            pad = _pad_token(self.cfg)
            tokens = [t if i in act else pad for i, t in enumerate(tokens)]
            positions = [p if i in act else -1
                         for i, p in enumerate(positions)]
            tables = [t if i in act else [] for i, t in enumerate(tables)]
        t = jnp.asarray(list(tokens), jnp.int32)[:, None]
        p = jnp.asarray(list(positions), jnp.int32)
        tbl = jnp.asarray(self._table_array(tables, self.n_lanes))
        logits, self.pool, mass = decode_step(self.params, t, p, tbl,
                                              self.pool,
                                              context=self.context)
        self.decodes += 1
        if mass is not None:
            m = np.asarray(mass)
            act = lanes if lanes is not None else range(len(m))
            self._last_mass = {int(i): m[int(i)] for i in act}
        return np.asarray(jnp.argmax(logits, axis=-1)).astype(int).tolist()

    def _decode_compact(self, tokens, positions, tables, lanes) -> List[int]:
        """Pack the active lanes into the smallest covering bucket and run
        the compacted step: padding rows carry lane id n_lanes (their
        per-lane write-back is dropped) and an all -1 table (they read and
        write only the scratch block)."""
        compact_step = self._steps()[3]
        w = self.decode_width(len(lanes))
        mb = _cover(max((len(tables[i]) for i in lanes), default=1),
                    self.table_buckets)
        t = np.zeros((w, 1), np.int32)
        p = np.zeros((w,), np.int32)
        lane_arr = np.full((w,), self.n_lanes, np.int32)
        tbl = np.full((w, mb), -1, np.int32)
        for j, i in enumerate(lanes):
            t[j, 0] = tokens[i]
            p[j] = positions[i]
            lane_arr[j] = i
            if len(tables[i]) > mb:
                raise ValueError(f"lane {i}: table of {len(tables[i])} "
                                 f"blocks exceeds bucketed width {mb}")
            tbl[j, :len(tables[i])] = tables[i]
        logits, self.pool, mass = compact_step(self.params, jnp.asarray(t),
                                               jnp.asarray(p),
                                               jnp.asarray(tbl),
                                               jnp.asarray(lane_arr),
                                               self.pool,
                                               context=self.context)
        self.decodes += 1
        if mass is not None:
            m = np.asarray(mass)
            self._last_mass = {int(i): m[j] for j, i in enumerate(lanes)}
        out = np.asarray(jnp.argmax(logits, axis=-1))
        res = [0] * self.n_lanes
        for j, i in enumerate(lanes):
            res[i] = int(out[j])
        return res

    def prefill_chunks(self, lanes: Sequence[int],
                       chunks: Sequence[Sequence[int]],
                       starts: Sequence[int],
                       tables: Optional[Sequence[Sequence[int]]] = None,
                       final: Optional[Sequence[bool]] = None) -> List[int]:
        """One batched chunk-prefill call: lane `lanes[j]` appends prompt
        tokens `chunks[j]` at absolute positions starting at `starts[j]`
        through its block table. Short final chunks pad with position -1
        (masked everywhere); returned next-token ids are meaningful only
        where `final[j]`."""
        if not self.chunk:
            raise RuntimeError("executor built with chunk=0")
        chunk_step = self._steps()[4]
        w = _cover(len(lanes), self.lane_buckets)
        C = self.chunk
        tbls = [list(t) for t in (tables if tables is not None else
                                  [[]] * len(lanes))]
        mb = _cover(max((len(t) for t in tbls), default=1),
                    self.table_buckets)
        toks = np.full((w, C), _pad_token(self.cfg), np.int32)
        pos = np.full((w, C), -1, np.int32)
        lane_arr = np.full((w,), self.n_lanes, np.int32)
        tbl = self._table_array(tbls + [[]] * (w - len(tbls)), w, width=mb)
        for j, lane in enumerate(lanes):
            c = list(chunks[j])
            if not 0 < len(c) <= C:
                raise ValueError(f"lane {lane}: chunk of {len(c)} tokens "
                                 f"vs chunk size {C}")
            toks[j, :len(c)] = c
            pos[j, :len(c)] = starts[j] + np.arange(len(c))
            lane_arr[j] = lane
        logits, self.pool = chunk_step(self.params, jnp.asarray(toks),
                                       jnp.asarray(pos), jnp.asarray(tbl),
                                       jnp.asarray(lane_arr), self.pool,
                                       context=self.context)
        self.chunk_calls += 1
        if final is not None:
            self.prefills += sum(bool(f) for f in final)
        out = np.asarray(jnp.argmax(logits, axis=-1))
        return [int(out[j]) for j in range(len(lanes))]

    def block_masses(self) -> Dict[int, np.ndarray]:
        """Per-lane attention mass over the lane's logical blocks from the
        last decode tick ({} when mass tracking is off) — the retention
        policy's ranking signal."""
        return self._last_mass

    def compile_counts(self) -> dict:
        prefill_step, decode_step, reset_step, compact_step, chunk_step = \
            self._steps()
        return {"prefill": _compile_count(prefill_step),
                "decode": _compile_count(decode_step),
                "decode_compact": _compile_count(compact_step),
                "chunk": _compile_count(chunk_step),
                "reset": _compile_count(reset_step)}
