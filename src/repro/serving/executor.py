"""The jax executors behind the serving engine: a slotted ring-cache pool
(baseline) and a paged KV block pool, each with jitted prefill / batched-
decode steps.

One decode compile serves the whole run (the pool width and context are
fixed); prefill compiles once per distinct prompt length — synthetic
traces draw prompts from small bucket sets, so the compile count stays
bounded and every compile serves traffic (zero throwaway compiles when
planning went through the simulator). Engine-level batched prefill pads
each same-tick, same-bucket admission group to the pool width, so a burst
of admissions costs ONE prefill call instead of one per request.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.runtime import serve_step as SS


def _compile_count(fn) -> int:
    try:
        return int(fn._cache_size())
    except AttributeError:          # older jax: no cache-size probe
        return -1


def _pad_batch(width: int, slots: Sequence[int],
               prompts: Sequence[Sequence[int]]):
    """Pack a same-length admission group into pool-width arrays: padding
    rows carry dummy prompts (token id 2) and index `width` — out of
    bounds, so the prefill scatter drops them (mode='drop')."""
    p = len(prompts[0])
    toks = np.full((width, p), 2, np.int32)
    idx = np.full((width,), width, np.int32)
    for i, (s, pr) in enumerate(zip(slots, prompts)):
        toks[i] = pr
        idx[i] = s
    return jnp.asarray(toks), jnp.asarray(idx)


class JaxExecutor:
    """Executes engine slot operations against a real parameter set.

    The pool cache (batch dim = slot index) lives here and is donated
    through every step: prefill overwrites one slot in place, decode
    advances all slots in one batched heterogeneous-position step (the
    ring cache's slot = pos % L layout needs no per-sequence alignment).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int,
                 context: int, settings: Optional[M.ModelSettings] = None):
        self.params = params
        self.cfg = cfg
        self.settings = settings
        self.n_slots = int(n_slots)
        self.context = int(context)
        self.pool = SS.init_slot_pool(cfg, self.n_slots, self.context)
        self.prefills = 0
        self.decodes = 0

    def _steps(self):
        # fetched per call: memoized on (cfg, settings, ambient sharding
        # context), so a second executor for the same model (--policy both)
        # reuses the compiled steps while a different mesh/rules retraces
        return SS.slot_serve_steps(self.cfg, self.settings)

    def prefill(self, slot: int, prompt: Sequence[int]) -> int:
        prefill_step, _, _ = self._steps()
        tokens = jnp.asarray(list(prompt), jnp.int32)[None, :]
        logits, self.pool = prefill_step(self.params, tokens, slot,
                                         self.pool, context=self.context)
        self.prefills += 1
        return int(jnp.argmax(logits[0], axis=-1))

    def prefill_batch(self, slots: Sequence[int],
                      prompts: Sequence[Sequence[int]],
                      tables=None) -> List[int]:
        """One padded prefill for a same-bucket admission group: tokens are
        padded to the pool width W (dummy rows use token id 2) and the
        scatter drops rows whose slot index is W (out of bounds)."""
        _, batch_step, _ = self._steps()
        toks, slot_arr = _pad_batch(self.n_slots, slots, prompts)
        logits, self.pool = batch_step(self.params, toks, slot_arr,
                                       self.pool, context=self.context)
        self.prefills += 1
        out = np.asarray(jnp.argmax(logits, axis=-1))
        return [int(out[i]) for i in range(len(slots))]

    def decode(self, tokens: Sequence[int], positions: Sequence[int],
               tables=None) -> List[int]:
        _, _, decode_step = self._steps()
        t = jnp.asarray(list(tokens), jnp.int32)[:, None]
        p = jnp.asarray(list(positions), jnp.int32)
        logits, self.pool = decode_step(self.params, t, p, self.pool,
                                        context=self.context)
        self.decodes += 1
        return np.asarray(jnp.argmax(logits, axis=-1)).astype(int).tolist()

    def compile_counts(self) -> dict:
        """Compiled-variant counts of the serving steps (prefill: one per
        prompt-length bucket; decode: one) — the driver reports them so
        'every compile served traffic' is checkable."""
        single, batch, decode_step = self._steps()
        return {"prefill": _compile_count(batch) + _compile_count(single),
                "decode": _compile_count(decode_step)}


class PagedJaxExecutor:
    """Engine lane operations over the paged KV block pool.

    Full-context attention layers store KV in `n_blocks` shared blocks of
    `kv_block` positions (physical id 0 is the scratch block for inactive
    lanes, so the pool is allocated one block larger); each active lane's
    logical layout reaches the pool through its block table. Decode is ONE
    batched gather-based step at lane width regardless of pool occupancy;
    prefill scatters whole blocks, padded to lane width per prompt bucket
    like the ring executor.
    """

    def __init__(self, params, cfg: ModelConfig, *, n_lanes: int,
                 n_blocks: int, kv_block: int, context: int,
                 settings: Optional[M.ModelSettings] = None):
        if kv_block < 1:
            raise ValueError(f"kv_block must be >= 1, got {kv_block}")
        self.params = params
        self.cfg = cfg
        self.settings = settings
        self.n_lanes = int(n_lanes)
        self.kv_block = int(kv_block)
        # block-align the ring extent so logical blocks tile it exactly
        self.context = -(-int(context) // kv_block) * kv_block
        self.max_blocks = self.context // kv_block
        self.n_blocks = int(n_blocks)
        self.pool = SS.init_paged_pool(cfg, self.n_lanes, self.n_blocks + 1,
                                       kv_block, self.context)
        self.prefills = 0
        self.decodes = 0

    def _steps(self):
        return SS.paged_serve_steps(self.cfg, self.settings)

    def _table_array(self, tables: Sequence[Sequence[int]], rows: int
                     ) -> np.ndarray:
        out = np.full((rows, self.max_blocks), -1, np.int32)
        for i, tbl in enumerate(tables):
            if len(tbl) > self.max_blocks:
                raise ValueError(f"lane {i}: table of {len(tbl)} blocks "
                                 f"exceeds max_blocks={self.max_blocks}")
            out[i, :len(tbl)] = tbl
        return out

    def prefill_batch(self, lanes: Sequence[int],
                      prompts: Sequence[Sequence[int]],
                      tables: Sequence[Sequence[int]]) -> List[int]:
        prefill_step, _, _ = self._steps()
        w = self.n_lanes
        toks, lane_arr = _pad_batch(w, lanes, prompts)
        tbl = self._table_array(list(tables) + [[]] * (w - len(tables)), w)
        logits, self.pool = prefill_step(self.params, toks, lane_arr,
                                         jnp.asarray(tbl), self.pool,
                                         context=self.context)
        self.prefills += 1
        out = np.asarray(jnp.argmax(logits, axis=-1))
        return [int(out[i]) for i in range(len(lanes))]

    def fresh_blocks(self, ids: Sequence[int]) -> None:
        """Invalidate re-linked physical blocks (pos = -1) before decode
        reads them through a new owner's table. Fixed width (lane count,
        padded with the scratch block) keeps this a single compile."""
        _, _, reset_step = self._steps()
        if len(ids) > self.n_lanes:     # engine adds <= 1 block/lane/tick
            raise ValueError(f"{len(ids)} fresh blocks for "
                             f"{self.n_lanes} lanes")
        arr = np.zeros((self.n_lanes,), np.int32)       # pad -> scratch
        arr[:len(ids)] = list(ids)
        self.pool = reset_step(self.pool, jnp.asarray(arr))

    def decode(self, tokens: Sequence[int], positions: Sequence[int],
               tables: Sequence[Sequence[int]]) -> List[int]:
        _, decode_step, _ = self._steps()
        t = jnp.asarray(list(tokens), jnp.int32)[:, None]
        p = jnp.asarray(list(positions), jnp.int32)
        tbl = jnp.asarray(self._table_array(tables, self.n_lanes))
        logits, self.pool = decode_step(self.params, t, p, tbl, self.pool,
                                        context=self.context)
        self.decodes += 1
        return np.asarray(jnp.argmax(logits, axis=-1)).astype(int).tolist()

    def compile_counts(self) -> dict:
        prefill_step, decode_step, reset_step = self._steps()
        return {"prefill": _compile_count(prefill_step),
                "decode": _compile_count(decode_step),
                "reset": _compile_count(reset_step)}
