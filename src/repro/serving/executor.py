"""The jax executor behind the serving engine: a slotted ring-cache pool
plus jitted prefill-into-slot / batched-decode steps.

One decode compile serves the whole run (the pool width and context are
fixed); prefill compiles once per distinct prompt length — synthetic
traces draw prompts from small bucket sets, so the compile count stays
bounded and every compile serves traffic (zero throwaway compiles when
planning went through the simulator).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.runtime import serve_step as SS


class JaxExecutor:
    """Executes engine slot operations against a real parameter set.

    The pool cache (batch dim = slot index) lives here and is donated
    through every step: prefill overwrites one slot in place, decode
    advances all slots in one batched heterogeneous-position step (the
    ring cache's slot = pos % L layout needs no per-sequence alignment).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int,
                 context: int, settings: Optional[M.ModelSettings] = None):
        self.params = params
        self.cfg = cfg
        self.settings = settings
        self.n_slots = int(n_slots)
        self.context = int(context)
        self.pool = SS.init_slot_pool(cfg, self.n_slots, self.context)
        self.prefills = 0
        self.decodes = 0

    def _steps(self):
        # fetched per call: memoized on (cfg, settings, ambient sharding
        # context), so a second executor for the same model (--policy both)
        # reuses the compiled steps while a different mesh/rules retraces
        return SS.slot_serve_steps(self.cfg, self.settings)

    def prefill(self, slot: int, prompt: Sequence[int]) -> int:
        prefill_step, _ = self._steps()
        tokens = jnp.asarray(list(prompt), jnp.int32)[None, :]
        logits, self.pool = prefill_step(self.params, tokens, slot,
                                         self.pool, context=self.context)
        self.prefills += 1
        return int(jnp.argmax(logits[0], axis=-1))

    def decode(self, tokens: Sequence[int], positions: Sequence[int]
               ) -> List[int]:
        _, decode_step = self._steps()
        t = jnp.asarray(list(tokens), jnp.int32)[:, None]
        p = jnp.asarray(list(positions), jnp.int32)
        logits, self.pool = decode_step(self.params, t, p, self.pool,
                                        context=self.context)
        self.decodes += 1
        return np.asarray(jnp.argmax(logits, axis=-1)).astype(int).tolist()

    def compile_counts(self) -> dict:
        """Compiled-variant counts of the serving steps (prefill: one per
        prompt-length bucket; decode: one) — the driver reports them so
        'every compile served traffic' is checkable."""
        def n(fn):
            try:
                return int(fn._cache_size())
            except AttributeError:      # older jax: no cache-size probe
                return -1
        prefill_step, decode_step = self._steps()
        return {"prefill": n(prefill_step), "decode": n(decode_step)}
