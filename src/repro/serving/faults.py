"""Deterministic chaos injection for the paged serving engine.

WSMC exists because memory predictions are fallible; this module makes
the engine PROVE it survives its own model being wrong. A seeded
`FaultPlan` names every fault up front — transient executor-call
failures, transient allocation refusals, mid-run HBM budget shrinks (the
misprediction / co-located-tenant case, translated to live block-pool
retirement), request cancellations, and stuck-lane stalls — and two thin
wrappers (`ChaosExecutor`, `ChaosAllocator`) inject the transient ones
from their own seeded streams, always BEFORE the wrapped call mutates
anything, so the engine's rollback/retry paths replay the exact same
call.

Everything is derived from `FaultPlan.seed`: the same plan against the
same trace produces the same fault interleaving, the same survivor set,
and the same token streams — which is what lets the chaos test suite pin
survivors token-identical to a fault-free replay.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.engine import (AllocationFault, BlockAllocator,
                                  ServeReport, TransientExecutorError)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One seeded chaos schedule. `exec_rate`/`alloc_rate` are per-call
    transient-fault probabilities drawn by the wrappers; `shrinks`,
    `cancels` and `stalls` are tick-indexed events the ENGINE applies
    (shrink = (tick, fraction of the current pool to retire), cancel =
    (tick, rid), stall = (tick, lane, duration ticks))."""
    seed: int
    exec_rate: float = 0.0
    alloc_rate: float = 0.0
    shrinks: Tuple[Tuple[int, float], ...] = ()
    cancels: Tuple[Tuple[int, int], ...] = ()
    stalls: Tuple[Tuple[int, int, int], ...] = ()

    @classmethod
    def generate(cls, seed: int, *, ticks: int = 512, n_requests: int = 0,
                 n_lanes: int = 0, exec_rate: float = 0.02,
                 alloc_rate: float = 0.02, n_shrinks: int = 1,
                 shrink_frac: float = 0.25, n_cancels: int = 0,
                 n_stalls: int = 0, stall_len: int = 4) -> "FaultPlan":
        """Draw a full plan from one seed. Shrinks land mid-run (the
        middle half of the tick horizon) so there is live state to
        squeeze; cancels pick rids < `n_requests`, stalls pick lanes <
        `n_lanes` — both need their bound passed to be generated."""
        if ticks < 4:
            raise ValueError(f"generate needs ticks >= 4, got {ticks}")
        if not (0.0 <= exec_rate < 1.0 and 0.0 <= alloc_rate < 1.0):
            raise ValueError("fault rates must be in [0, 1)")
        if not (0.0 <= shrink_frac < 1.0):
            raise ValueError(f"shrink_frac must be in [0, 1), got "
                             f"{shrink_frac}")
        rng = random.Random(seed)
        lo, hi = ticks // 4, 3 * ticks // 4
        shrinks = tuple(sorted((rng.randrange(lo, hi), shrink_frac)
                               for _ in range(n_shrinks)))
        cancels = ()
        if n_cancels and n_requests:
            rids = rng.sample(range(n_requests),
                              min(n_cancels, n_requests))
            cancels = tuple(sorted((rng.randrange(1, ticks), rid)
                                   for rid in rids))
        stalls = ()
        if n_stalls and n_lanes:
            stalls = tuple(sorted((rng.randrange(1, ticks),
                                   rng.randrange(n_lanes),
                                   max(1, stall_len))
                                  for _ in range(n_stalls)))
        return cls(seed=seed, exec_rate=exec_rate, alloc_rate=alloc_rate,
                   shrinks=shrinks, cancels=cancels, stalls=stalls)

    def describe(self) -> str:
        return (f"FaultPlan(seed={self.seed} exec_rate={self.exec_rate} "
                f"alloc_rate={self.alloc_rate} shrinks={len(self.shrinks)}"
                f" cancels={len(self.cancels)} stalls={len(self.stalls)})")


class ChaosExecutor:
    """Wraps any executor and raises `TransientExecutorError` from its
    own seeded stream BEFORE forwarding `prefill_batch` /
    `prefill_chunks` / `decode` — the wrapped executor never sees the
    faulted call, so the engine's retry replays it exactly. Everything
    else (fresh_blocks, decode_width, block_masses, has_recurrent, …)
    delegates untouched."""

    def __init__(self, inner, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self.faults_injected = 0
        self._rng = random.Random((plan.seed << 1) ^ 0x5DEECE66D)

    def _maybe_fault(self, what: str) -> None:
        if self._rng.random() < self.plan.exec_rate:
            self.faults_injected += 1
            raise TransientExecutorError(
                f"chaos: injected transient {what} failure "
                f"#{self.faults_injected}")

    def prefill_batch(self, slots, prompts, tables=None):
        self._maybe_fault("prefill_batch")
        return self.inner.prefill_batch(slots, prompts, tables=tables)

    def prefill_chunks(self, lanes, chunks, starts, tables=None,
                       final=None):
        self._maybe_fault("prefill_chunks")
        return self.inner.prefill_chunks(lanes, chunks, starts,
                                         tables=tables, final=final)

    def decode(self, tokens, positions, tables=None, lanes=None):
        self._maybe_fault("decode")
        if tables is not None:
            return self.inner.decode(tokens, positions, tables=tables,
                                     lanes=lanes)
        return self.inner.decode(tokens, positions, lanes=lanes)

    def __getattr__(self, name):
        return getattr(self.inner, name)


class ChaosAllocator(BlockAllocator):
    """A `BlockAllocator` whose `alloc` transiently refuses from its own
    seeded stream (raising `AllocationFault` before any ledger mutation).
    The engine treats a refusal as a one-tick deferral / admission
    rollback — NOT a capacity signal — so the ledger invariants hold
    through every injection."""

    def __init__(self, n_blocks: int, block_size: int,
                 reservation: str = "worst", *,
                 plan: Optional[FaultPlan] = None):
        super().__init__(n_blocks, block_size, reservation)
        self.plan = plan
        self.faults_injected = 0
        seed = plan.seed if plan is not None else 0
        self._rng = random.Random((seed << 2) ^ 0xB5297A4D)

    def alloc(self, rid: int) -> int:
        if (self.plan is not None
                and self._rng.random() < self.plan.alloc_rate):
            self.faults_injected += 1
            raise AllocationFault(
                f"chaos: allocator refused request {rid} "
                f"(injection #{self.faults_injected})")
        return super().alloc(rid)


def leak_check(alloc: BlockAllocator) -> List[str]:
    """Post-run leak assertions for a drained engine: every non-retired
    block back on the free list, no reservations or owned ledgers left,
    no referenced prefixes, plus the full ledger audit. Returns problem
    strings (empty = clean)."""
    problems = list(alloc.audit())
    if alloc._reserved or alloc._owned:
        problems.append(f"leaked reservations: "
                        f"{sorted(alloc._reserved)} / owned "
                        f"{sorted(alloc._owned)}")
    referenced = [k for k, p in alloc._prefix.items() if p["refs"] > 0]
    if referenced:
        problems.append(f"leaked prefix references: {referenced}")
    live = alloc.free_blocks + sum(len(p["blocks"])
                                   for p in alloc._prefix.values())
    if live != alloc.n_blocks:
        problems.append(f"drained pool not whole: free({alloc.free_blocks})"
                        f" + cached prefix != pool({alloc.n_blocks})")
    return problems


def survivor_mismatches(faulty: ServeReport,
                        clean: ServeReport) -> List[str]:
    """Compare a chaos run against its fault-free replay: every request
    the chaos run COMPLETED must carry the exact token stream the clean
    run produced (faults may delay or cancel work, never corrupt it).
    Returns mismatch strings (empty = token-identical survivors)."""
    clean_by = {c.rid: c.tokens for c in clean.completions}
    out = []
    for c in faulty.completions:
        want = clean_by.get(c.rid)
        if want is None:
            out.append(f"rid {c.rid} completed under chaos but not in "
                       "the clean run")
        elif c.tokens != want:
            out.append(f"rid {c.rid} tokens diverged under chaos: "
                       f"{c.tokens[:8]}... != {want[:8]}...")
    return out


def merge_reports(parts: Sequence[ServeReport]) -> Dict:
    """Small helper for the benchmark: goodput-relevant aggregates over
    a set of reports (e.g. the fault-free vs degraded cells)."""
    return {
        "completed": sum(len(p.completions) for p in parts),
        "cancelled": sum(len(p.cancellations) for p in parts),
        "tokens": sum(p.generated_tokens for p in parts),
        "ticks": sum(p.ticks for p in parts),
    }
