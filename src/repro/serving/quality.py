"""Token-agreement harness for capacity bending.

Quantized KV blocks and block-granular retention buy admitted sequences
with bytes that used to hold exact state, so "how many lanes fit" is only
half the ledger — this module supplies the other half: for every
completion an engine emitted, replay the request through the exact
per-request reference path (`greedy_generate`, fp cache, no dropping) and
count position-wise token matches. The resulting agreement fraction is
what `BENCH_serving.json` reports next to the capacity multiplier, and
what validates the planner's `predicted_agreement` priors.

Agreement is measured on greedy argmax token ids, the strictest cheap
proxy: a bent cache either reproduces the exact token stream or it
doesn't, and the first divergence position is recorded per request so
drift (late divergence, long prompts) is distinguishable from damage
(immediate divergence).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.runtime.serve_step import greedy_generate


@dataclasses.dataclass(frozen=True)
class AgreementReport:
    """Position-wise greedy-token agreement of an engine run vs exact."""
    agreement: float                     # matched / compared, in [0, 1]
    matched: int
    compared: int
    per_request: Tuple[float, ...]       # per-rid fraction, trace order
    first_divergence: Tuple[int, ...]    # per-rid index, -1 = identical

    def describe(self) -> str:
        exact = sum(1 for d in self.first_divergence if d < 0)
        return (f"agreement={self.agreement:.4f} "
                f"({self.matched}/{self.compared} tokens, "
                f"{exact}/{len(self.per_request)} requests exact)")


def token_agreement(params, cfg, trace: Sequence, report, *,
                    context: int, settings=None,
                    ref_cache: Optional[Dict] = None) -> AgreementReport:
    """Score a finished engine run against the exact reference decoder.

    `trace` is the request list the engine ran (each with `.rid`,
    `.prompt`, `.max_new`); `report` is its ServeReport. Each completion
    is compared token-by-token against `greedy_generate` on the same
    prompt — the fp, full-cache, single-sequence path — so any mismatch
    is attributable to the bend (quantization error or dropped blocks),
    not to scheduling. Requests are deduplicated by prompt/length so
    prefix-heavy traces don't pay the reference decode twice; pass a
    shared `ref_cache` dict to reuse references across calls (e.g. a
    benchmark scoring many bend settings against the same trace).
    """
    by_rid = {r.rid: r for r in trace}
    if ref_cache is None:
        ref_cache = {}
    fracs, firsts = [], []
    matched = compared = 0
    for c in sorted(report.completions, key=lambda c: c.rid):
        req = by_rid[c.rid]
        key = (tuple(req.prompt), req.max_new)
        if key not in ref_cache:
            out = greedy_generate(params, cfg,
                                  jnp.asarray(req.prompt, jnp.int32)[None],
                                  n_steps=req.max_new, context=context,
                                  settings=settings)
            ref_cache[key] = np.asarray(out)[0]
        ref = ref_cache[key]
        got = np.asarray(c.tokens, dtype=ref.dtype)
        n = min(len(got), len(ref))
        hits = got[:n] == ref[:n]
        matched += int(hits.sum())
        compared += n
        fracs.append(float(hits.mean()) if n else 1.0)
        div = int(np.argmin(hits)) if not hits.all() else -1
        firsts.append(div)
    return AgreementReport(
        agreement=(matched / compared) if compared else 1.0,
        matched=matched, compared=compared,
        per_request=tuple(fracs), first_divergence=tuple(firsts))


def measure_bend(params, cfg, trace: Sequence, *, n_lanes: int,
                 n_blocks: int, kv_block: int, context: int,
                 kv_quant: str = "none", kv_retain: int = 0,
                 settings=None, compact: bool = False, chunk: int = 0,
                 reservation: str = "worst", prefix_share: bool = False,
                 engine_kwargs: Optional[dict] = None):
    """Run a bent paged engine over `trace` and score it in one call.

    Convenience wrapper for benchmarks and smokes: builds the
    PagedJaxExecutor with the requested bend, an allocator sized to the
    pool, and an Engine with retention enforcement, then returns
    `(ServeReport, AgreementReport)`. The throughput numbers and the
    quality numbers come from the SAME run, so a benchmark cell can't
    accidentally report capacity from one configuration and fidelity
    from another.
    """
    from repro.serving.engine import BlockAllocator, Engine
    from repro.serving.executor import PagedJaxExecutor
    executor = PagedJaxExecutor(
        params, cfg, n_lanes=n_lanes, n_blocks=n_blocks, kv_block=kv_block,
        context=context, settings=settings, compact=compact, chunk=chunk,
        kv_quant=kv_quant, kv_retain=kv_retain)
    allocator = BlockAllocator(n_blocks, kv_block, reservation=reservation)
    kw = dict(engine_kwargs or {})
    kw.setdefault("kv_retain", kv_retain)
    kw.setdefault("prefix_share", prefix_share)
    kw.setdefault("chunk_prefill", chunk)
    report = Engine(executor, n_lanes, allocator=allocator, **kw).run(trace)
    agree = token_agreement(params, cfg, trace, report, context=context,
                            settings=settings)
    return report, agree
