"""Pallas TPU flash-attention backward: dQ, dK, dV without materializing
the attention matrix.

Standard two-kernel schedule (TPU grids iterate the innermost dim
sequentially, so accumulators live in VMEM scratch):

  dQ kernel:    grid (b, H, nq, nk)  — dq accumulated over kv blocks
  dK/dV kernel: grid (b, H, nk, nq)  — dk, dv accumulated over q blocks

Both recompute p = exp(s − L) from the forward's saved row log-sum-exp L
(m + log l), and use D = rowsum(dO ⊙ O):

  dv += pᵀ dO
  dp  = dO Vᵀ
  ds  = p ⊙ (dp − D)
  dq += ds K · scale      dk += dsᵀ Q · scale
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    _SCRATCH = lambda shape: pl.MemorySpace.ANY(shape, jnp.float32)

NEG_INF = -1e30


def _mask_block(qp, kp, window, chunk):
    mask = (kp[None, :] <= qp[:, None]) & (kp[None, :] >= 0)
    if window is not None:
        mask &= kp[None, :] > qp[:, None] - window
    if chunk is not None:
        mask &= (kp[None, :] // chunk) == (qp[:, None] // chunk)
    return mask


def _dq_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
               dvec_ref, dq_out_ref, dq_acc, *,
               scale, window, chunk, q_block, kv_block, nk):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_lo, q_hi = qi * q_block, qi * q_block + q_block - 1
    k_lo = ki * kv_block
    live = k_lo <= q_hi
    reach = window if window is not None else chunk
    if reach is not None:
        live &= k_lo + kv_block - 1 >= q_lo - reach

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, :, 0]
        dvec = dvec_ref[0, :, 0]
        qp = qpos_ref[0, :]
        kp = kpos_ref[0, :]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _mask_block(qp, kp, window, chunk)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dvec[:, None])
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(ki == nk - 1)
    def _fin():
        dq_out_ref[0, :, 0, :] = dq_acc[...].astype(dq_out_ref.dtype)


def _dkv_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                dvec_ref, dk_out_ref, dv_out_ref, dk_acc, dv_acc, *,
                scale, window, chunk, q_block, kv_block, nq):
    qi = pl.program_id(3)
    ki = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_lo = qi * q_block
    q_hi = q_lo + q_block - 1
    k_lo = ki * kv_block
    live = k_lo <= q_hi
    reach = window if window is not None else chunk
    if reach is not None:
        live &= k_lo + kv_block - 1 >= q_lo - reach

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, :, 0]
        dvec = dvec_ref[0, :, 0]
        qp = qpos_ref[0, :]
        kp = kpos_ref[0, :]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _mask_block(qp, kp, window, chunk)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)      # [qb, kb]
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                   # [kb, hd]
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dvec[:, None])
        # q was pre-scaled at load, so dsᵀ·q already carries the 1/√d factor
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                   # [kb, hd]

    @pl.when(qi == nq - 1)
    def _fin():
        dk_out_ref[0, :, 0, :] = dk_acc[...].astype(dk_out_ref.dtype)
        dv_out_ref[0, :, 0, :] = dv_acc[...].astype(dv_out_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, do, qpos, kpos, *,
                        window: Optional[int] = None,
                        chunk: Optional[int] = None,
                        q_block: int = 512, kv_block: int = 512,
                        interpret: bool = False):
    """q/do/out [b,s,H,hd]; k/v [b,s,H,hd] (pre-repeated per-head KV);
    lse [b,s,H]. Returns (dq, dk, dv) with dk/dv per H head."""
    b, s, H, hd = q.shape
    q_block = min(q_block, s)
    kv_block = min(kv_block, s)
    assert s % q_block == 0 and s % kv_block == 0
    nq, nk = s // q_block, s // kv_block
    scale = 1.0 / np.sqrt(hd)
    dvec = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                   axis=-1)                                       # [b,s,H]

    common_specs = dict(
        qpos=pl.BlockSpec((1, q_block), lambda bi, hi, i, j: (bi, i)),
        kpos=pl.BlockSpec((1, kv_block), lambda bi, hi, i, j: (bi, j)),
        q=pl.BlockSpec((1, q_block, 1, hd),
                       lambda bi, hi, i, j: (bi, i, hi, 0)),
        k=pl.BlockSpec((1, kv_block, 1, hd),
                       lambda bi, hi, i, j: (bi, j, hi, 0)),
        v=pl.BlockSpec((1, kv_block, 1, hd),
                       lambda bi, hi, i, j: (bi, j, hi, 0)),
        do=pl.BlockSpec((1, q_block, 1, hd),
                        lambda bi, hi, i, j: (bi, i, hi, 0)),
        lse=pl.BlockSpec((1, q_block, 1), lambda bi, hi, i, j: (bi, i, hi)),
        dvec=pl.BlockSpec((1, q_block, 1), lambda bi, hi, i, j: (bi, i, hi)),
    )

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, window=window,
                          chunk=chunk, q_block=q_block, kv_block=kv_block,
                          nk=nk),
        grid=(b, H, nq, nk),
        in_specs=[common_specs["qpos"], common_specs["kpos"],
                  common_specs["q"], common_specs["k"], common_specs["v"],
                  common_specs["do"], common_specs["lse"],
                  common_specs["dvec"]],
        out_specs=pl.BlockSpec((1, q_block, 1, hd),
                               lambda bi, hi, i, j: (bi, i, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, H, hd), q.dtype),
        scratch_shapes=[_SCRATCH((q_block, hd))],
        interpret=interpret,
    )(qpos, kpos, q, k, v, do, lse, dvec)

    # dK/dV: swap the roles — kv blocks outer, q blocks inner (sequential)
    kv_specs = dict(
        qpos=pl.BlockSpec((1, q_block), lambda bi, hi, j, i: (bi, i)),
        kpos=pl.BlockSpec((1, kv_block), lambda bi, hi, j, i: (bi, j)),
        q=pl.BlockSpec((1, q_block, 1, hd),
                       lambda bi, hi, j, i: (bi, i, hi, 0)),
        k=pl.BlockSpec((1, kv_block, 1, hd),
                       lambda bi, hi, j, i: (bi, j, hi, 0)),
        v=pl.BlockSpec((1, kv_block, 1, hd),
                       lambda bi, hi, j, i: (bi, j, hi, 0)),
        do=pl.BlockSpec((1, q_block, 1, hd),
                        lambda bi, hi, j, i: (bi, i, hi, 0)),
        lse=pl.BlockSpec((1, q_block, 1), lambda bi, hi, j, i: (bi, i, hi)),
        dvec=pl.BlockSpec((1, q_block, 1), lambda bi, hi, j, i: (bi, i, hi)),
    )
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, window=window,
                          chunk=chunk, q_block=q_block, kv_block=kv_block,
                          nq=nq),
        grid=(b, H, nk, nq),
        in_specs=[kv_specs["qpos"], kv_specs["kpos"], kv_specs["q"],
                  kv_specs["k"], kv_specs["v"], kv_specs["do"],
                  kv_specs["lse"], kv_specs["dvec"]],
        out_specs=[
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda bi, hi, j, i: (bi, j, hi, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda bi, hi, j, i: (bi, j, hi, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, s, H, hd), k.dtype),
                   jax.ShapeDtypeStruct((b, s, H, hd), v.dtype)],
        scratch_shapes=[_SCRATCH((kv_block, hd)), _SCRATCH((kv_block, hd))],
        interpret=interpret,
    )(qpos, kpos, q, k, v, do, lse, dvec)
    return dq, dk, dv
