"""Pallas TPU chunked mLSTM scan: the xLSTM hot loop.

TPU adaptation (DESIGN.md §6): the GPU reference implementations stream the
recurrence with warp-level primitives; on TPU we use the *chunkwise-parallel*
form — within a chunk everything is dense matmul work for the MXU (D-matrix
intra-chunk attention-like term), across chunks a compact (dk × dv) state
tile is carried in VMEM scratch over the sequentially-iterated chunk grid
dimension. Stabilized in log-space exactly like the per-step reference
(kernels/ref.py:mlstm_ref): the chunkwise max telescopes to the same m_t.

Grid: (batch*heads, n_chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    _SCRATCH = lambda shape: pl.MemorySpace.ANY(shape, jnp.float32)

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, i_ref, f_ref, c0_ref, n0_ref, m0_ref,
            o_ref, c_out_ref, n_out_ref, m_out_ref,
            c_ref, n_ref, m_ref, *,
            scale: float, nc: int, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = c0_ref[0]
        n_ref[...] = n0_ref[0][:, None]
        m_ref[0, 0] = m0_ref[0, 0]

    q = q_ref[0].astype(jnp.float32) * scale      # [c, dk]
    k = k_ref[0].astype(jnp.float32)              # [c, dk]
    v = v_ref[0].astype(jnp.float32)              # [c, dv]
    ig = i_ref[0, :].astype(jnp.float32)          # [c]
    fg = f_ref[0, :].astype(jnp.float32)          # [c]

    logf = jax.nn.log_sigmoid(fg)
    g = jnp.cumsum(logf)                          # inclusive cumulative decay
    m_prev = m_ref[0, 0]
    C_prev = c_ref[...]                           # [dk, dv]
    n_prev = n_ref[...]                           # [dk, 1]

    # Stabilizer per step t: m_t = max(m_prev + g_t, max_{s<=t}(g_t - g_s + i_s))
    dmat = g[:, None] - g[None, :] + ig[None, :]  # [c(t), c(s)]
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    dmat = jnp.where(tri, dmat, NEG_INF)
    m_intra = dmat.max(axis=1)
    m_t = jnp.maximum(m_prev + g, m_intra)

    # Intra-chunk (MXU): weights exp(D - m_t), scores q k^T.
    w = jnp.where(tri, jnp.exp(dmat - m_t[:, None]), 0.0)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [c, c]
    sw = s * w
    out_intra = jax.lax.dot_general(sw, v, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    qn_intra = sw.sum(axis=1)

    # Inter-chunk from carried state.
    inter_coeff = jnp.exp(m_prev + g - m_t)       # [c]
    qC = jax.lax.dot_general(q, C_prev, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [c, dv]
    qn_inter = jax.lax.dot_general(q, n_prev, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)[:, 0]
    num = inter_coeff[:, None] * qC + out_intra
    qn = inter_coeff * qn_inter + qn_intra
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
    o_ref[0] = (num / den[:, None]).astype(o_ref.dtype)

    # State update to end of chunk.
    g_end = g[chunk - 1]
    m_new = jnp.maximum(m_prev + g_end, jnp.max(g_end - g + ig))
    a = jnp.exp(g_end - g + ig - m_new)           # [c]
    decay = jnp.exp(m_prev + g_end - m_new)
    c_ref[...] = decay * C_prev + jax.lax.dot_general(
        k * a[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = decay * n_prev + jax.lax.dot_general(
        k * a[:, None], jnp.ones((chunk, 1), jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[0, 0] = m_new

    @pl.when(ci == nc - 1)
    def _finalize():
        c_out_ref[0] = c_ref[...]
        n_out_ref[0] = n_ref[..., 0]
        m_out_ref[0, 0] = m_ref[0, 0]


def mlstm_scan_fwd(q, k, v, i_gate, f_gate, *, chunk: int = 128,
                   interpret: bool = False, initial=None):
    """Chunked mLSTM over folded heads.

    q, k [bh, s, dk]; v [bh, s, dv]; i_gate/f_gate [bh, s].
    `initial` optionally seeds the carried state: (C0 [bh, dk, dv],
    n0 [bh, dk], m0 [bh, 1]) — a mid-prompt chunk continues a sequence
    whose earlier chunks already ran (serving chunked prefill).
    Returns (out [bh, s, dv], (C [bh, dk, dv], n [bh, dk], m [bh, 1])).
    """
    bh, s, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    scale = 1.0 / np.sqrt(dk)
    if initial is None:
        C0 = jnp.zeros((bh, dk, dv), jnp.float32)
        n0 = jnp.zeros((bh, dk), jnp.float32)
        m0 = jnp.full((bh, 1), NEG_INF, jnp.float32)
    else:
        C0, n0, m0 = (t.astype(jnp.float32) for t in initial)
    kernel = functools.partial(_kernel, scale=scale, nc=nc, chunk=chunk)
    out, C, n, m = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, dv), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bi, ci: (bi, ci)),
            pl.BlockSpec((1, chunk), lambda bi, ci: (bi, ci)),
            pl.BlockSpec((1, dk, dv), lambda bi, ci: (bi, 0, 0)),
            pl.BlockSpec((1, dk), lambda bi, ci: (bi, 0)),
            pl.BlockSpec((1, 1), lambda bi, ci: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, dk, dv), lambda bi, ci: (bi, 0, 0)),
            pl.BlockSpec((1, dk), lambda bi, ci: (bi, 0)),
            pl.BlockSpec((1, 1), lambda bi, ci: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dv), v.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, dk), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1), jnp.float32),
        ],
        scratch_shapes=[
            _SCRATCH((dk, dv)),
            _SCRATCH((dk, 1)),
            _SCRATCH((1, 1)),
        ],
        interpret=interpret,
    )(q, k, v, i_gate, f_gate, C0, n0, m0)
    return out, (C, n, m)
