"""Pallas TPU decode attention: one query token per sequence against a
blocked ring-buffer KV cache, running log-sum-exp merge across KV blocks.

The same (m, l, acc) merge algebra is reused by the sequence-parallel decode
path (parallel/sp.py) to combine per-shard partial attentions — this kernel
is the single-device version of that schedule.

Grid: (batch, kv_blocks) — kv blocks iterate sequentially (innermost), the
softmax state lives in VMEM scratch. All heads are processed per grid cell
(q is tiny: [K, G, hd]).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    pltpu = None
    _SCRATCH = lambda shape: pl.MemorySpace.ANY(shape, jnp.float32)

NEG_INF = -1e30


def _kernel(pos_ref, cpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *,
            scale: float, window: Optional[int], chunk: Optional[int],
            nl: int):
    li = pl.program_id(1)

    @pl.when(li == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale         # [K, G, hd]
    k = k_ref[0].astype(jnp.float32)                 # [Lb, K, hd]
    v = v_ref[0].astype(jnp.float32)
    pos = pos_ref[0, 0]                              # scalar
    cpos = cpos_ref[0, :]                            # [Lb]
    s = jnp.einsum("kgh,lkh->kgl", q, k)             # [K, G, Lb]
    mask = (cpos <= pos) & (cpos >= 0)
    if window is not None:
        mask &= cpos > pos - window
    if chunk is not None:
        mask &= (cpos // chunk) == (pos // chunk)
    s = jnp.where(mask[None, None, :], s, NEG_INF)
    m_prev = m_ref[...]                              # [K, G]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(mask[None, None, :], jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "kgl,lkh->kgh", p, v)
    m_ref[...] = m_new

    @pl.when(li == nl - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]).astype(o_ref.dtype)


def decode_attention_fwd(q, k_cache, v_cache, cache_pos, positions, *,
                         window: Optional[int] = None,
                         chunk: Optional[int] = None,
                         kv_block: int = 512, interpret: bool = False):
    """q [b,K,G,hd]; caches [b,L,K,hd]; cache_pos [b,L]; positions [b]."""
    b, K, G, hd = q.shape
    L = k_cache.shape[1]
    kv_block = min(kv_block, L)
    assert L % kv_block == 0, (L, kv_block)
    nl = L // kv_block
    scale = 1.0 / np.sqrt(hd)
    kernel = functools.partial(_kernel, scale=scale, window=window,
                               chunk=chunk, nl=nl)
    return pl.pallas_call(
        kernel,
        grid=(b, nl),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, li: (bi, 0)),
            pl.BlockSpec((1, kv_block), lambda bi, li: (bi, li)),
            pl.BlockSpec((1, K, G, hd), lambda bi, li: (bi, 0, 0, 0)),
            pl.BlockSpec((1, kv_block, K, hd), lambda bi, li: (bi, li, 0, 0)),
            pl.BlockSpec((1, kv_block, K, hd), lambda bi, li: (bi, li, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, K, G, hd), lambda bi, li: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, K, G, hd), q.dtype),
        scratch_shapes=[
            _SCRATCH((K, G)),
            _SCRATCH((K, G)),
            _SCRATCH((K, G, hd)),
        ],
        interpret=interpret,
    )(positions.reshape(b, 1), cache_pos, q, k_cache, v_cache)


# ---------------------------------------------------------------------------
# Paged decode: read the KV pool through per-sequence block tables
# ---------------------------------------------------------------------------
#
# The pool stores KV in fixed-size position blocks shared across sequences
# ([n_blocks, block, K, hd]); each sequence maps logical block j to a
# physical block via its table row. The tables ride in as SCALAR PREFETCH
# (pltpu.PrefetchScalarGridSpec) so the index_map itself can chase the
# indirection — grid cell (b, j) DMAs exactly the physical block sequence b
# needs, which is what makes decode traffic proportional to the blocks a
# sequence actually wrote instead of the pool-wide max context. Unassigned
# table entries (-1) clamp to physical block 0 (the serving engine's
# scratch block) and are masked out in-kernel.

def _dequant_block(raw, scale_row, quant: str):
    """In-kernel dequant of one pool block: raw [bs, K, hd] int8 or
    [bs, K, hd//2] uint8 (packed nibbles, offset +8), scale_row [bs, K]
    f32 per-token per-head absmax scales -> f32 [bs, K, hd]. This is the
    fused path: the DMA moved quantized bytes; no fp pool ever exists."""
    if quant == "none":
        return raw.astype(jnp.float32)
    if quant == "int8":
        return raw.astype(jnp.float32) * scale_row[..., None]
    lo = (raw & 0xF).astype(jnp.int32) - 8           # elements 0, 2, 4, ...
    hi = (raw >> 4).astype(jnp.int32) - 8            # elements 1, 3, 5, ...
    bs, K, hd2 = raw.shape
    full = jnp.stack([lo, hi], axis=-1).reshape(bs, K, hd2 * 2)
    return full.astype(jnp.float32) * scale_row[..., None]


def _paged_kernel(tbl_ref, pos_ref, cpos_ref, q_ref, k_ref, v_ref, *refs,
                  scale: float, window: Optional[int], chunk: Optional[int],
                  nl: int, quant: str = "none", mass: bool = False):
    # refs layout (flags append, never reorder):
    #   [ks_ref, vs_ref]  when quant != "none"   (per-row scale blocks)
    #   o_ref
    #   [bm_ref, bl_ref]  when mass              (per-block max / sumexp)
    #   m_ref, l_ref, acc_ref                     (VMEM scratch)
    i = 0
    ks_ref = vs_ref = bm_ref = bl_ref = None
    if quant != "none":
        ks_ref, vs_ref = refs[0], refs[1]
        i = 2
    o_ref = refs[i]
    i += 1
    if mass:
        bm_ref, bl_ref = refs[i], refs[i + 1]
        i += 2
    m_ref, l_ref, acc_ref = refs[i:i + 3]
    bi = pl.program_id(0)
    li = pl.program_id(1)

    @pl.when(li == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if mass:
        # every grid cell owns its (bi, li) mass slot; unassigned blocks
        # report an empty block (max = -inf, sumexp = 0)
        bm_ref[...] = jnp.full_like(bm_ref, NEG_INF)
        bl_ref[...] = jnp.zeros_like(bl_ref)

    # an unassigned logical block (table entry -1) contributes nothing to
    # the softmax — skip its whole merge (its DMA clamps to scratch block
    # 0, but the compute is predicated off)
    @pl.when(tbl_ref[bi, li] >= 0)
    def _merge():
        q = q_ref[0].astype(jnp.float32) * scale     # [K, G, hd]
        k = _dequant_block(k_ref[0], None if ks_ref is None else ks_ref[0],
                           quant)                    # [bs, K, hd]
        v = _dequant_block(v_ref[0], None if vs_ref is None else vs_ref[0],
                           quant)
        pos = pos_ref[0, 0]                          # scalar
        cpos = cpos_ref[0, :]                        # [bs]
        s = jnp.einsum("kgh,lkh->kgl", q, k)         # [K, G, bs]
        mask = (cpos <= pos) & (cpos >= 0)
        if window is not None:
            mask &= cpos > pos - window
        if chunk is not None:
            mask &= (cpos // chunk) == (pos // chunk)
        s = jnp.where(mask[None, None, :], s, NEG_INF)
        m_prev = m_ref[...]                          # [K, G]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(mask[None, None, :], jnp.exp(s - m_new[..., None]),
                      0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
            "kgl,lkh->kgh", p, v)
        m_ref[...] = m_new
        if mass:
            # block-LOCAL softmax stats; combined across blocks outside
            # the kernel (log-sum-exp merge, same algebra as (m, l))
            bmax = s.max(axis=-1)                    # [K, G]
            bm_ref[0, 0] = bmax
            bl_ref[0, 0] = jnp.where(
                mask[None, None, :], jnp.exp(s - bmax[..., None]),
                0.0).sum(axis=-1)

    @pl.when(li == nl - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]).astype(o_ref.dtype)


def paged_quant_of(k_pool) -> str:
    """Pool storage codec, read off the pool's own dtype (self-describing,
    mirroring models.attention.paged_quant_kind)."""
    if k_pool.dtype == jnp.int8:
        return "int8"
    if k_pool.dtype == jnp.uint8:
        return "int4"
    return "none"


def paged_decode_attention_fwd(q, k_pool, v_pool, pool_pos, block_tables,
                               positions, *,
                               window: Optional[int] = None,
                               chunk: Optional[int] = None,
                               k_scales=None, v_scales=None,
                               return_mass: bool = False,
                               interpret: bool = False):
    """q [b,K,G,hd]; pools [n_blocks,block,K,hd] bf16 — or int8 / uint8
    (packed int4 nibbles) with per-row f32 scales [n_blocks,block,K] in
    `k_scales`/`v_scales`; pool_pos [n_blocks,block]; block_tables
    [b,max_blocks] int32 (-1 = unassigned); positions [b].

    The grid's KV extent is the TABLE width, not the pool-wide max-context
    block count: callers that trim tables to the blocks actually allocated
    (serving lane compaction does) shrink the grid — and the unassigned
    tail that remains is skipped by the in-kernel predicate — so decode
    work tracks what sequences wrote, not what they could write.

    Quantized pools are read DIRECTLY: the block-table DMA moves int8/int4
    bytes (plus the tiny scale stripe, chased by the same index map) and
    dequant happens in-kernel after the copy — no fp-dequantized pool is
    ever materialized.

    `return_mass=True` additionally returns per-logical-block attention
    mass [b, max_blocks] (softmax weight captured by each block, averaged
    over heads) — the serving engine's block-retention signal. Per-block
    (max, sumexp) stats come out of the kernel and are merged outside with
    the standard log-sum-exp algebra."""
    if pltpu is None:  # pragma: no cover
        raise NotImplementedError("paged decode needs pallas TPU grid specs")
    b, K, G, hd = q.shape
    m_blocks = block_tables.shape[1]
    bs = pool_pos.shape[1]
    quant = paged_quant_of(k_pool)
    if quant != "none" and (k_scales is None or v_scales is None):
        raise ValueError(f"{quant} pool needs k_scales/v_scales")
    hd_s = k_pool.shape[-1]                  # stored width (hd // 2 for int4)
    scale = 1.0 / np.sqrt(hd)
    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               chunk=chunk, nl=m_blocks, quant=quant,
                               mass=return_mass)

    def physical(bi, li, tbl):
        return jnp.maximum(tbl[bi, li], 0)

    in_specs = [
        pl.BlockSpec((1, 1), lambda bi, li, tbl: (bi, 0)),
        pl.BlockSpec((1, bs), lambda bi, li, tbl: (physical(bi, li, tbl), 0)),
        pl.BlockSpec((1, K, G, hd), lambda bi, li, tbl: (bi, 0, 0, 0)),
        pl.BlockSpec((1, bs, K, hd_s),
                     lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0, 0)),
        pl.BlockSpec((1, bs, K, hd_s),
                     lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0, 0)),
    ]
    args = [block_tables, positions.reshape(b, 1), pool_pos, q,
            k_pool, v_pool]
    if quant != "none":
        # scale stripes chase the same block table as their payload
        in_specs += [
            pl.BlockSpec((1, bs, K),
                         lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0)),
            pl.BlockSpec((1, bs, K),
                         lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0)),
        ]
        args += [k_scales, v_scales]
    out_specs = [pl.BlockSpec((1, K, G, hd),
                              lambda bi, li, tbl: (bi, 0, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((b, K, G, hd), q.dtype)]
    if return_mass:
        out_specs += [pl.BlockSpec((1, 1, K, G),
                                   lambda bi, li, tbl: (bi, li, 0, 0))] * 2
        out_shape += [jax.ShapeDtypeStruct((b, m_blocks, K, G),
                                           jnp.float32)] * 2
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, m_blocks),
        in_specs=in_specs,
        out_specs=out_specs if return_mass else out_specs[0],
        scratch_shapes=[
            _SCRATCH((K, G)),
            _SCRATCH((K, G)),
            _SCRATCH((K, G, hd)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape if return_mass else out_shape[0],
        interpret=interpret,
    )(*args)
    if not return_mass:
        return out
    o, bm, bl = out
    # merge block-local (max, sumexp) into each block's global softmax
    # share: w_j = l_j * exp(m_j - M); mass_j = w_j / sum w
    M = bm.max(axis=1, keepdims=True)                # [b, 1, K, G]
    w = bl * jnp.exp(bm - M)                         # [b, nl, K, G]
    mass = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-30)
    return o, mass.mean(axis=(2, 3))                 # [b, nl]
