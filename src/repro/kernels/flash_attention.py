"""Pallas TPU flash-attention (forward): blocked online-softmax with causal /
sliding-window / chunked-local masks and GQA head mapping.

TPU adaptation (DESIGN.md §6): the grid's innermost dim iterates KV blocks
*sequentially* on TPU, so the running (m, l, acc) state lives in VMEM scratch
across grid steps — no HBM round-trips for the softmax state. Block shapes
are MXU-aligned (multiples of 128 where dims allow). Fully-masked KV blocks
are skipped via pl.when on the block-level causal/window bounds.

Contiguous positions are assumed (qpos/kpos ascending); the mask refs still
make padding (-1) exact.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # pltpu provides typed VMEM scratch; interpret mode works on CPU
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    _SCRATCH = lambda shape: pl.MemorySpace.ANY(shape, jnp.float32)

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
            m_ref, l_ref, acc_ref, *,
            scale: float, window: Optional[int], chunk: Optional[int],
            q_block: int, kv_block: int, nk: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level skip bounds (contiguous positions): block is live unless
    # entirely above the diagonal or entirely outside the window/chunk.
    q_lo = qi * q_block
    q_hi = q_lo + q_block - 1
    k_lo = ki * kv_block
    live = k_lo <= q_hi
    reach = window if window is not None else (chunk if chunk is not None else None)
    if reach is not None:
        k_hi = k_lo + kv_block - 1
        live &= k_hi >= q_lo - reach

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale    # [qb, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [kb, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        qp = qpos_ref[0, :]                                   # [qb]
        kp = kpos_ref[0, :]                                   # [kb]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = (kp[None, :] <= qp[:, None]) & (kp[None, :] >= 0)
        if window is not None:
            mask &= kp[None, :] > qp[:, None] - window
        if chunk is not None:
            mask &= (kp[None, :] // chunk) == (qp[:, None] // chunk)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, :, 0, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)
        # row log-sum-exp (saved for the backward kernels)
        lse_ref[0, :, 0] = m_ref[...] + jnp.log(
            jnp.maximum(l_ref[...], 1e-30))


def flash_attention_fwd(q, k, v, qpos, kpos, *,
                        window: Optional[int] = None,
                        chunk: Optional[int] = None,
                        q_block: int = 512, kv_block: int = 512,
                        interpret: bool = False, return_lse: bool = False):
    """q [b,s,H,hd]; k/v [b,s,K,hd]; qpos/kpos [b,s] -> out [b,s,H,hd]
    (+ lse [b,s,H] when return_lse — consumed by flash_attention_bwd)."""
    b, s, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    q_block = min(q_block, s)
    kv_block = min(kv_block, s)
    assert s % q_block == 0 and s % kv_block == 0, (s, q_block, kv_block)
    nq, nk = s // q_block, s // kv_block
    grid = (b, H, nq, nk)
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _kernel, scale=scale, window=window, chunk=chunk,
        q_block=q_block, kv_block=kv_block, nk=nk)

    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block), lambda bi, hi, qi, ki: (bi, qi)),
            pl.BlockSpec((1, kv_block), lambda bi, hi, qi, ki: (bi, ki)),
            pl.BlockSpec((1, q_block, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // G, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_block, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, q_block, 1),
                         lambda bi, hi, qi, ki: (bi, qi, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, H, hd), q.dtype),
            jax.ShapeDtypeStruct((b, s, H), jnp.float32),
        ],
        scratch_shapes=[
            _SCRATCH((q_block,)),       # m
            _SCRATCH((q_block,)),       # l
            _SCRATCH((q_block, hd)),    # acc
        ],
        interpret=interpret,
    )(qpos, kpos, q, k, v)
    return (out, lse) if return_lse else out
