"""jit'd wrappers around the Pallas kernels with backend dispatch.

Backends:
  pallas    — compiled pallas_call (TPU target)
  interpret — pallas_call(interpret=True): kernel body evaluated on CPU;
              used by the allclose test sweeps
  blocked   — memory-equivalent pure-jnp tiling (lax.scan) — what the CPU
              dry-run lowers, keeping the compile-visible memory footprint
              faithful to the kernel's
  ref       — kernels.ref oracles (small shapes only)

Default: pallas on TPU, blocked elsewhere. Override per call or with env
REPRO_KERNEL_BACKEND.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import functools

from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention_bwd import flash_attention_bwd
from repro.kernels.decode_attention import (decode_attention_fwd,
                                            paged_decode_attention_fwd)
from repro.kernels.mlstm_scan import mlstm_scan_fwd
from repro.kernels.prefill_attention import paged_prefill_attention_fwd

NEG_INF = -1e30


def default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "blocked"


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, qpos, kpos, *, window: Optional[int] = None,
                    chunk: Optional[int] = None, backend: Optional[str] = None,
                    q_block: int = 512, kv_block: int = 512):
    """q [b,s,K,G,hd]; k/v [b,s,K,hd] -> [b,s,K,G,hd]."""
    backend = backend or default_backend()
    b, s, K, G, hd = q.shape
    if backend == "ref":
        return kref.flash_attention_ref(q, k, v, qpos, kpos,
                                        window=window, chunk=chunk)
    if backend in ("pallas", "interpret"):
        qf = q.reshape(b, s, K * G, hd)
        out = flash_attention_fwd(
            qf, k, v, qpos, kpos, window=window, chunk=chunk,
            q_block=min(q_block, s), kv_block=min(kv_block, s),
            interpret=(backend == "interpret"))
        return out.reshape(b, s, K, G, hd)
    # blocked jnp fallback lives in models.attention (shared tiling logic)
    from repro.models import attention as mattn
    from repro.configs.base import BlockSpec
    blk = BlockSpec(window=window, chunk=chunk)
    set_ = mattn.AttnSettings(backend="blocked", q_block=q_block,
                              kv_block=kv_block)
    return mattn._seq_attention(q, k, v, qpos, kpos, blk, set_)


@functools.lru_cache(maxsize=64)
def _flash_vjp(window, chunk, q_block, kv_block, interpret, G):
    """custom_vjp flash attention over per-H-head tensors (KV pre-repeated);
    dk/dv are reduced back over the G q-heads sharing each KV head."""

    @jax.custom_vjp
    def fn(qh, kh, vh, qpos, kpos):
        return flash_attention_fwd(qh, kh, vh, qpos, kpos, window=window,
                                   chunk=chunk, q_block=q_block,
                                   kv_block=kv_block, interpret=interpret)

    def fwd(qh, kh, vh, qpos, kpos):
        out, lse = flash_attention_fwd(qh, kh, vh, qpos, kpos, window=window,
                                       chunk=chunk, q_block=q_block,
                                       kv_block=kv_block,
                                       interpret=interpret, return_lse=True)
        return out, (qh, kh, vh, out, lse, qpos, kpos)

    def bwd(res, do):
        qh, kh, vh, out, lse, qpos, kpos = res
        dq, dk, dv = flash_attention_bwd(
            qh, kh, vh, out, lse, do, qpos, kpos, window=window, chunk=chunk,
            q_block=q_block, kv_block=kv_block, interpret=interpret)
        return dq, dk, dv, None, None

    fn.defvjp(fwd, bwd)
    return fn


def flash_attention_trainable(q, k, v, qpos, kpos, *,
                              window: Optional[int] = None,
                              chunk: Optional[int] = None,
                              q_block: int = 512, kv_block: int = 512,
                              interpret: bool = False):
    """Differentiable pallas flash attention (fwd + dQ/dK/dV kernels).

    q [b,s,K,G,hd]; k/v [b,s,K,hd] -> [b,s,K,G,hd]. KV is repeated to H
    heads for the kernels; dk/dv sum back over each KV head's G q-heads.
    """
    b, s, K, G, hd = q.shape
    qh = q.reshape(b, s, K * G, hd)
    kh = jnp.repeat(k, G, axis=2)
    vh = jnp.repeat(v, G, axis=2)
    fn = _flash_vjp(window, chunk, min(q_block, s), min(kv_block, s),
                    interpret, G)
    out = fn(qh, kh, vh, qpos, kpos)
    return out.reshape(b, s, K, G, hd)


def decode_attention(q, k_cache, v_cache, cache_pos, positions, *,
                     window: Optional[int] = None, chunk: Optional[int] = None,
                     backend: Optional[str] = None, kv_block: int = 512):
    """q [b,K,G,hd]; caches [b,L,K,hd] -> [b,K,G,hd]."""
    backend = backend or default_backend()
    if backend in ("pallas", "interpret"):
        L = k_cache.shape[1]
        kv_block = min(kv_block, L)
        if L % kv_block:
            kv_block = L  # single block for ragged small caches
        return decode_attention_fwd(
            q, k_cache, v_cache, cache_pos, positions,
            window=window, chunk=chunk, kv_block=kv_block,
            interpret=(backend == "interpret"))
    return kref.decode_attention_ref(q, k_cache, v_cache, cache_pos,
                                     positions, window=window, chunk=chunk)


def paged_decode_attention(q, k_pool, v_pool, pool_pos, block_tables,
                           positions, *, window: Optional[int] = None,
                           chunk: Optional[int] = None,
                           backend: Optional[str] = None,
                           k_scales=None, v_scales=None,
                           return_mass: bool = False):
    """Decode through a paged KV pool: q [b,K,G,hd]; pools
    [n_blocks,block,K,hd] (bf16, int8, or uint8-packed int4 with per-row
    f32 `k_scales`/`v_scales` [n_blocks,block,K]); pool_pos
    [n_blocks,block]; block_tables [b,max_blocks] (-1 = unassigned) ->
    [b,K,G,hd], or (out, mass [b,max_blocks]) with `return_mass`.
    Quantized pools are DMA'd and dequantized inside the kernel — no fp
    pool copy. Compiled Pallas on TPU; interpret-mode kernel everywhere
    else (the CPU test tiers drive the same block-table indirection the
    TPU kernel runs)."""
    backend = backend or default_backend()
    if backend not in ("pallas", "interpret"):
        backend = "interpret"       # no jnp twin: the kernel IS the gather
    return paged_decode_attention_fwd(
        q, k_pool, v_pool, pool_pos, block_tables, positions,
        window=window, chunk=chunk, k_scales=k_scales, v_scales=v_scales,
        return_mass=return_mass, interpret=(backend == "interpret"))


def paged_prefill_attention(q, k_new, v_new, k_pool, v_pool, pool_pos,
                            block_tables, positions, *,
                            window: Optional[int] = None,
                            chunk: Optional[int] = None,
                            backend: Optional[str] = None,
                            k_scales=None, v_scales=None):
    """Fused chunked prefill through a paged KV pool: write the chunk's
    K/V into the pool via the block tables (quantize-on-write in-kernel
    for int8/int4 pools — no fp intermediate in HBM) and flash-attend the
    chunk's queries over history + chunk in O(chunk x block) tiles.

    q [b,C,K,G,hd]; k_new/v_new [b,C,K,hd]; pools [n_blocks,block,K,hd]
    (bf16, int8, or uint8-packed int4 with f32 `k_scales`/`v_scales`);
    pool_pos [n_blocks,block]; block_tables [b,max_blocks]; positions
    [b,C] (-1 = padding). Returns (o, pool_pos', k_pool', v_pool'[, ks',
    vs']). Compiled Pallas on TPU; interpret-mode elsewhere — like paged
    decode there is no jnp twin: the kernel IS the scatter + gather."""
    backend = backend or default_backend()
    if backend not in ("pallas", "interpret"):
        backend = "interpret"
    return paged_prefill_attention_fwd(
        q, k_new, v_new, k_pool, v_pool, pool_pos, block_tables, positions,
        window=window, chunk_mask=chunk, k_scales=k_scales,
        v_scales=v_scales, interpret=(backend == "interpret"))


# ---------------------------------------------------------------------------
# mLSTM chunked scan
# ---------------------------------------------------------------------------

def _mlstm_chunked_jnp(q, k, v, i_gate, f_gate, chunk: int, initial=None):
    """Blocked jnp mirror of the Pallas kernel: lax.scan over chunks.
    `initial` = (C0 [bh,dk,dv], n0 [bh,dk], m0 [bh]) continues a sequence
    mid-prompt (serving chunked prefill); None starts from scratch."""
    bh, s, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    f32 = jnp.float32
    scale = 1.0 / np.sqrt(dk)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))

    qs = jnp.moveaxis(q.reshape(bh, nc, chunk, dk), 1, 0).astype(f32) * scale
    ks = jnp.moveaxis(k.reshape(bh, nc, chunk, dk), 1, 0).astype(f32)
    vs = jnp.moveaxis(v.reshape(bh, nc, chunk, dv), 1, 0).astype(f32)
    igs = jnp.moveaxis(i_gate.reshape(bh, nc, chunk), 1, 0).astype(f32)
    fgs = jnp.moveaxis(f_gate.reshape(bh, nc, chunk), 1, 0).astype(f32)

    def body(carry, xs):
        C, n, m = carry                       # [bh,dk,dv],[bh,dk],[bh]
        qc, kc, vc, ic, fc = xs
        logf = jax.nn.log_sigmoid(fc)
        g = jnp.cumsum(logf, axis=-1)         # [bh, c]
        dmat = g[:, :, None] - g[:, None, :] + ic[:, None, :]
        dmat = jnp.where(tri[None], dmat, NEG_INF)
        m_t = jnp.maximum(m[:, None] + g, dmat.max(axis=-1))
        w = jnp.where(tri[None], jnp.exp(dmat - m_t[..., None]), 0.0)
        sc = jnp.einsum("btk,bsk->bts", qc, kc) * w
        out_intra = jnp.einsum("bts,bsv->btv", sc, vc)
        qn_intra = sc.sum(axis=-1)
        inter = jnp.exp(m[:, None] + g - m_t)
        qC = jnp.einsum("btk,bkv->btv", qc, C)
        qn_inter = jnp.einsum("btk,bk->bt", qc, n)
        num = inter[..., None] * qC + out_intra
        qn = inter * qn_inter + qn_intra
        den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
        out = num / den[..., None]
        g_end = g[:, -1]
        m_new = jnp.maximum(m + g_end, (g_end[:, None] - g + ic).max(axis=-1))
        a = jnp.exp(g_end[:, None] - g + ic - m_new[:, None])
        decay = jnp.exp(m + g_end - m_new)
        C = decay[:, None, None] * C + jnp.einsum("bsk,bsv->bkv",
                                                  kc * a[..., None], vc)
        n = decay[:, None] * n + (kc * a[..., None]).sum(axis=1)
        return (C, n, m_new), out

    if initial is None:
        C0 = jnp.zeros((bh, dk, dv), f32)
        n0 = jnp.zeros((bh, dk), f32)
        m0 = jnp.full((bh,), NEG_INF, f32)
    else:
        C0, n0, m0 = (t.astype(f32) for t in initial)
    (C, n, m), outs = jax.lax.scan(body, (C0, n0, m0),
                                   (qs, ks, vs, igs, fgs))
    out = jnp.moveaxis(outs, 0, 1).reshape(bh, s, dv).astype(v.dtype)
    return out, (C, n, m[:, None])


def mlstm_scan(q, k, v, i_gate, f_gate, *, chunk: int = 128,
               backend: Optional[str] = None, initial=None):
    """q, k [b,s,h,dk]; v [b,s,h,dv]; gates [b,s,h].

    `initial` optionally continues a sequence mid-prompt from carried
    state (C [b,h,dk,dv], n [b,h,dk], m [b,h,1]) — serving chunked
    prefill; None is a fresh sequence.
    Returns (out [b,s,h,dv], state (C [b,h,dk,dv], n [b,h,dk], m [b,h,1])).
    """
    backend = backend or default_backend()
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    fold = lambda t: jnp.moveaxis(t, 2, 1).reshape((b * h, s) + t.shape[3:])
    if backend == "ref":
        init = (None if initial is None
                else (initial[0], initial[1], initial[2][..., 0]))
        out, (C, n, m) = kref.mlstm_ref(q, k, v, i_gate, f_gate,
                                        initial_state=init)
        return out, (C, n, m[..., None])
    qf, kf, vf = fold(q), fold(k), fold(v)
    igf, fgf = fold(i_gate), fold(f_gate)
    init_f = (None if initial is None
              else (initial[0].reshape(b * h, dk, dv),
                    initial[1].reshape(b * h, dk),
                    initial[2].reshape(b * h, 1)))
    if backend in ("pallas", "interpret"):
        out, (C, n, m) = mlstm_scan_fwd(qf, kf, vf, igf, fgf, chunk=chunk,
                                        interpret=(backend == "interpret"),
                                        initial=init_f)
    else:
        init_j = None if init_f is None else (init_f[0], init_f[1],
                                              init_f[2][:, 0])
        out, (C, n, m) = _mlstm_chunked_jnp(qf, kf, vf, igf, fgf, chunk,
                                            initial=init_j)
    out = jnp.moveaxis(out.reshape(b, h, s, dv), 1, 2)
    return out, (C.reshape(b, h, dk, dv), n.reshape(b, h, dk),
                 m.reshape(b, h, 1))


def mlstm_decode_step(q, k, v, i_gate, f_gate, state):
    """Single-token mLSTM update. q,k [b,h,dk]; v [b,h,dv]; gates [b,h];
    state (C, n, m[b,h,1]) -> (out [b,h,dv], new_state)."""
    C, n, m = state
    m = m[..., 0]
    f32 = jnp.float32
    dk = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_gate.astype(f32))
    m_new = jnp.maximum(logf + m, i_gate.astype(f32))
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(i_gate.astype(f32) - m_new)
    C = fp[..., None, None] * C + ip[..., None, None] * (
        k.astype(f32)[..., :, None] * v.astype(f32)[..., None, :])
    n = fp[..., None] * n + ip[..., None] * k.astype(f32)
    qs = q.astype(f32) / np.sqrt(dk)
    num = jnp.einsum("bhk,bhkv->bhv", qs, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n)),
                      jnp.exp(-m_new))
    out = (num / den[..., None]).astype(v.dtype)
    return out, (C, n, m_new[..., None])
