"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: small-shape, no tiling, f32 math.
Kernel tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention (forward) oracle — GQA, causal + window + chunk masks
# ---------------------------------------------------------------------------

def _mask(qpos, kpos, window: Optional[int], chunk: Optional[int]):
    q = qpos[..., :, None].astype(jnp.int32)
    k = kpos[..., None, :].astype(jnp.int32)
    m = (k <= q) & (k >= 0)
    if window is not None:
        m &= k > q - window
    if chunk is not None:
        m &= (k // chunk) == (q // chunk)
    return m


def flash_attention_ref(q, k, v, qpos, kpos,
                        window: Optional[int] = None,
                        chunk: Optional[int] = None):
    """q [b,s,K,G,hd]; k/v [b,s,K,hd]; qpos/kpos [b,s] -> [b,s,K,G,hd]."""
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    msk = _mask(qpos, kpos, window, chunk)
    s = jnp.where(msk[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, cache_pos, positions,
                         window: Optional[int] = None,
                         chunk: Optional[int] = None):
    """q [b,K,G,hd]; caches [b,L,K,hd]; cache_pos [b,L]; positions [b]."""
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bkgh,blkh->bkgl", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    msk = _mask(positions[:, None], cache_pos, window, chunk)[:, 0]  # [b, L]
    s = jnp.where(msk[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgl,blkh->bkgh", p, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# mLSTM oracle — per-step stabilized recurrence (xLSTM Eq. 19-27 form)
# ---------------------------------------------------------------------------

def mlstm_ref(q, k, v, i_gate, f_gate, initial_state=None):
    """Sequential stabilized mLSTM.

    q, k [b, s, h, dk]; v [b, s, h, dv]; i_gate/f_gate [b, s, h] (pre-act).
    Returns (out [b, s, h, dv], state (C [b,h,dk,dv], n [b,h,dk], m [b,h])).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    if initial_state is None:
        C0 = jnp.zeros((b, h, dk, dv), f32)
        n0 = jnp.zeros((b, h, dk), f32)
        m0 = jnp.full((b, h), -jnp.inf, f32)
    else:
        C0, n0, m0 = initial_state
    scale = 1.0 / np.sqrt(dk)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs          # [b,h,dk],[b,h,dk],[b,h,dv],[b,h]
        logf = jax.nn.log_sigmoid(ft.astype(f32))
        m_new = jnp.maximum(logf + m, it.astype(f32))
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(it.astype(f32) - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * (
            kt.astype(f32)[..., :, None] * vt.astype(f32)[..., None, :])
        n = fp[..., None] * n + ip[..., None] * kt.astype(f32)
        qs = qt.astype(f32) * scale
        num = jnp.einsum("bhk,bhkv->bhv", qs, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n)),
                          jnp.exp(-m_new))
        out = num / den[..., None]
        return (C, n, m_new), out

    xs = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(i_gate, 1, 0),
          jnp.moveaxis(f_gate, 1, 0))
    (C, n, m), outs = jax.lax.scan(step, (C0, n0, m0), xs)
    return jnp.moveaxis(outs, 0, 1).astype(v.dtype), (C, n, m)


# ---------------------------------------------------------------------------
# RG-LRU oracle — sequential gated diagonal recurrence
# ---------------------------------------------------------------------------

def rglru_ref(x, r_gate, i_gate, a_param, initial_h=None, c: float = 8.0):
    """x [b, s, w]; r_gate/i_gate [b, s, w] (pre-sigmoid); a_param [w]."""
    f32 = jnp.float32
    b, s, w = x.shape
    h0 = jnp.zeros((b, w), f32) if initial_h is None else initial_h
    log_a_base = -c * jax.nn.softplus(a_param.astype(f32))   # [w] < 0

    def step(h, xs):
        xt, rt, it = xs
        r = jax.nn.sigmoid(rt.astype(f32))
        log_a = log_a_base * r
        a = jnp.exp(log_a)
        gated = jax.nn.sigmoid(it.astype(f32)) * xt.astype(f32)
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        h = a * h + beta * gated
        return h, h

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(r_gate, 1, 0),
          jnp.moveaxis(i_gate, 1, 0))
    h_last, hs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), h_last
