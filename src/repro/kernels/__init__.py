# Pallas TPU kernels: flash_attention, decode_attention, mlstm_scan —
# each with a jit-wrapped dispatcher (ops.py) and a pure-jnp oracle (ref.py).
