"""Pallas TPU fused paged flash-prefill: write a prompt chunk into the
paged KV pool THROUGH the block table and attend over history + chunk in
O(chunk x block) tiles — online softmax, no [chunk, context] score matrix.

This is the prefill sibling of decode_attention._paged_kernel. The jnp
chunk-append path (models.attention._chunk_append) first scatters the chunk
into the pool and then gathers every allocated block back into a contiguous
fp "virtual ring" before running dense SDPA — an O(chunk x context) f32
score matrix plus, for quantized pools, a dequantized fp copy of the whole
context. Neither transient exists here: grid cell (b, j) DMAs exactly one
physical block, merges the chunk rows that land in it (quantize-on-write:
int8/int4 encoding happens in-kernel, so quantized pools never see an fp
intermediate in HBM), and folds the block into the running (m, l, acc)
softmax state. `core.predictor.prefill_transient_bytes` prices exactly this
difference, which is how tiled-prefill plans buy more lanes at tight
budgets.

Grid: (batch, max_blocks) — logical blocks iterate sequentially (innermost)
so the VMEM softmax state carries across blocks and a block's write-merge
always precedes its own attend. Block tables ride in as scalar prefetch and
the index maps chase the indirection, identical to paged decode; the pool
leaves alias their outputs so unvisited physical blocks keep their contents.

Within-chunk causality needs no ordering tricks: every chunk row landing in
block j is merged before block j is attended, rows in later blocks have
strictly larger positions, and the (cpos <= qpos) mask orders everything.
Write hazards can't occur — a physical block is written by at most one lane
(block tables partition the pool; shared prefix blocks are read-only by the
engine's CoW rule), and unmapped table entries clamp to the scratch block
where the merge is predicated off (identity write-back).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.decode_attention import _dequant_block, paged_quant_of

try:
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    pltpu = None
    _SCRATCH = lambda shape: pl.MemorySpace.ANY(shape, jnp.float32)

NEG_INF = -1e30
_QMAX = {"int8": 127.0, "int4": 7.0}


def _quantize_rows(x, quant: str):
    """In-kernel mirror of models.attention.quantize_kv: x [C, K, hd] f32 ->
    (codes f32 [C, K, hd] int-valued, scale [C, K] f32). Per-row absmax so a
    row's encoding never depends on its neighbours — merging a chunk row
    into a half-full block can't requantize what's already there."""
    qmax = _QMAX[quant]
    scale = jnp.max(jnp.abs(x), axis=-1) / qmax
    q = jnp.round(x / jnp.maximum(scale, 1e-30)[..., None])
    return jnp.clip(q, -qmax, qmax), scale


def _pack_int4(codes):
    """codes [C, K, hd] f32 in [-8, 7] -> packed f32 [C, K, hd//2] holding
    uint8 byte values (lo | hi << 4, offset +8) — same layout quantize_kv
    stores. Kept in f32 so the one-hot merge matmul stays exact."""
    c, k, hd = codes.shape
    nib = codes + 8.0
    pair = nib.reshape(c, k, hd // 2, 2)
    return pair[..., 0] + pair[..., 1] * 16.0


def _prefill_kernel(tbl_ref, qpos_ref, q_ref, kn_ref, vn_ref,
                    pp_ref, kp_ref, vp_ref, *refs,
                    scale: float, window: Optional[int],
                    chunk_mask: Optional[int], nl: int, bs: int, quant: str):
    # refs layout (mirrors decode_attention: flags append, never reorder):
    #   [ks_ref, vs_ref]          when quant != "none" (scale stripes in)
    #   o_ref, pp_out, kp_out, vp_out
    #   [ks_out, vs_out]          when quant != "none"
    #   m_ref, l_ref, acc_ref     (VMEM scratch)
    i = 0
    ks_ref = vs_ref = ks_out = vs_out = None
    if quant != "none":
        ks_ref, vs_ref = refs[0], refs[1]
        i = 2
    o_ref, pp_out, kp_out, vp_out = refs[i:i + 4]
    i += 4
    if quant != "none":
        ks_out, vs_out = refs[i], refs[i + 1]
        i += 2
    m_ref, l_ref, acc_ref = refs[i:i + 3]
    bi = pl.program_id(0)
    li = pl.program_id(1)
    f32 = jnp.float32

    @pl.when(li == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mapped = tbl_ref[bi, li] >= 0
    qpos = qpos_ref[0]                               # [C] int32
    C = qpos.shape[0]
    valid = qpos >= 0

    # ---- phase A: merge the chunk rows that land in this logical block.
    # sel [bs, C] one-hot: slot s takes chunk row c iff c's position maps
    # to (block li, slot s). Positions are distinct, so each slot matches
    # at most one row and the one-hot matmul is an exact gather.
    overlap = valid & (qpos // bs == li) & mapped    # [C]
    slot_of = jnp.where(overlap, qpos % bs, -1)
    slots = jax.lax.broadcasted_iota(jnp.int32, (bs, C), 0)
    sel = (slot_of[None, :] == slots)                # [bs, C]
    written = sel.any(axis=1)                        # [bs]
    self_ = sel.astype(f32)

    kn = kn_ref[0].astype(f32)                       # [C, K, hd]
    vn = vn_ref[0].astype(f32)
    old_pos = pp_ref[0]                              # [bs]
    new_pos = jnp.einsum("sc,c->s", self_, qpos.astype(f32)).astype(jnp.int32)
    merged_pos = jnp.where(written, new_pos, old_pos)
    pp_out[0] = merged_pos

    if quant == "none":
        # fp pool: cast through the pool dtype so the chunk's own keys are
        # attended exactly as a later reader would see them
        mk = jnp.einsum("sc,ckh->skh", self_, kn).astype(kp_ref.dtype)
        mv = jnp.einsum("sc,ckh->skh", self_, vn).astype(vp_ref.dtype)
        merged_kraw = jnp.where(written[:, None, None], mk, kp_ref[0])
        merged_vraw = jnp.where(written[:, None, None], mv, vp_ref[0])
        kp_out[0] = merged_kraw
        vp_out[0] = merged_vraw
        kblk = merged_kraw.astype(f32)
        vblk = merged_vraw.astype(f32)
    else:
        # quantize-on-write: encode the chunk rows in-register, merge the
        # integer codes + scale stripes into the block, and attend against
        # the DEQUANTIZED merge — bit-for-bit what the pool now stores, and
        # no fp copy of the pool ever reaches HBM.
        kq, ksc = _quantize_rows(kn, quant)          # [C,K,hd], [C,K]
        vq, vsc = _quantize_rows(vn, quant)
        if quant == "int4":
            kq, vq = _pack_int4(kq), _pack_int4(vq)  # [C,K,hd//2] uint8 vals
        mk = jnp.einsum("sc,ckh->skh", self_, kq)
        mv = jnp.einsum("sc,ckh->skh", self_, vq)
        merged_kraw = jnp.where(
            written[:, None, None],
            mk.astype(jnp.int32).astype(kp_ref.dtype), kp_ref[0])
        merged_vraw = jnp.where(
            written[:, None, None],
            mv.astype(jnp.int32).astype(vp_ref.dtype), vp_ref[0])
        merged_ks = jnp.where(written[:, None],
                              jnp.einsum("sc,ck->sk", self_, ksc), ks_ref[0])
        merged_vs = jnp.where(written[:, None],
                              jnp.einsum("sc,ck->sk", self_, vsc), vs_ref[0])
        kp_out[0] = merged_kraw
        vp_out[0] = merged_vraw
        ks_out[0] = merged_ks
        vs_out[0] = merged_vs
        kblk = _dequant_block(merged_kraw, merged_ks, quant)
        vblk = _dequant_block(merged_vraw, merged_vs, quant)

    # ---- phase B: fold this (post-write) block into the online softmax
    @pl.when(mapped)
    def _merge():
        qv = q_ref[0].astype(f32) * scale            # [C, K, G, hd]
        s = jnp.einsum("ckgh,skh->ckgs", qv, kblk)   # [C, K, G, bs]
        cpos = merged_pos
        mask = (cpos[None, :] <= qpos[:, None]) & (cpos[None, :] >= 0) \
            & valid[:, None]                         # [C, bs]
        if window is not None:
            mask &= cpos[None, :] > qpos[:, None] - window
        if chunk_mask is not None:
            mask &= (cpos[None, :] // chunk_mask) == \
                (qpos[:, None] // chunk_mask)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_prev = m_ref[...]                          # [C, K, G]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(mask[:, None, None, :],
                      jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
            "ckgs,skh->ckgh", p, vblk)
        m_ref[...] = m_new

    @pl.when(li == nl - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def paged_prefill_attention_fwd(q, k_new, v_new, k_pool, v_pool, pool_pos,
                                block_tables, positions, *,
                                window: Optional[int] = None,
                                chunk_mask: Optional[int] = None,
                                k_scales=None, v_scales=None,
                                interpret: bool = False):
    """Fused chunk write + causal flash attend through a paged KV pool.

    q [b,C,K,G,hd]; k_new/v_new [b,C,K,hd] fp; pools [n_blocks,block,K,hd]
    bf16 — or int8 / uint8 (packed int4) with per-row f32 scales
    [n_blocks,block,K] in `k_scales`/`v_scales`; pool_pos [n_blocks,block];
    block_tables [b,max_blocks] int32 (-1 = unassigned); positions [b,C]
    int32 (-1 = padding rows of a short final chunk).

    Returns (o [b,C,K,G,hd], pool_pos', k_pool', v_pool'[, k_scales',
    v_scales']) — the pool leaves are updated IN PLACE via
    input_output_aliases; physical blocks no table entry points at keep
    their contents. As with paged decode, the grid's KV extent is the
    table width, so trimmed tables shrink prefill work too."""
    if pltpu is None:  # pragma: no cover
        raise NotImplementedError("paged prefill needs pallas TPU grid specs")
    b, C, K, G, hd = q.shape
    m_blocks = block_tables.shape[1]
    bs = pool_pos.shape[1]
    quant = paged_quant_of(k_pool)
    if quant != "none" and (k_scales is None or v_scales is None):
        raise ValueError(f"{quant} pool needs k_scales/v_scales")
    hd_s = k_pool.shape[-1]                  # stored width (hd // 2 for int4)
    scale = 1.0 / np.sqrt(hd)
    kernel = functools.partial(_prefill_kernel, scale=scale, window=window,
                               chunk_mask=chunk_mask, nl=m_blocks, bs=bs,
                               quant=quant)

    def physical(bi, li, tbl):
        return jnp.maximum(tbl[bi, li], 0)

    in_specs = [
        pl.BlockSpec((1, C), lambda bi, li, tbl: (bi, 0)),
        pl.BlockSpec((1, C, K, G, hd), lambda bi, li, tbl: (bi, 0, 0, 0, 0)),
        pl.BlockSpec((1, C, K, hd), lambda bi, li, tbl: (bi, 0, 0, 0)),
        pl.BlockSpec((1, C, K, hd), lambda bi, li, tbl: (bi, 0, 0, 0)),
        pl.BlockSpec((1, bs), lambda bi, li, tbl: (physical(bi, li, tbl), 0)),
        pl.BlockSpec((1, bs, K, hd_s),
                     lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0, 0)),
        pl.BlockSpec((1, bs, K, hd_s),
                     lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0, 0)),
    ]
    args = [block_tables, positions, q, k_new, v_new, pool_pos,
            k_pool, v_pool]
    if quant != "none":
        in_specs += [
            pl.BlockSpec((1, bs, K),
                         lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0)),
            pl.BlockSpec((1, bs, K),
                         lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0)),
        ]
        args += [k_scales, v_scales]
    out_specs = [
        pl.BlockSpec((1, C, K, G, hd), lambda bi, li, tbl: (bi, 0, 0, 0, 0)),
        pl.BlockSpec((1, bs), lambda bi, li, tbl: (physical(bi, li, tbl), 0)),
        pl.BlockSpec((1, bs, K, hd_s),
                     lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0, 0)),
        pl.BlockSpec((1, bs, K, hd_s),
                     lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, C, K, G, hd), q.dtype),
        jax.ShapeDtypeStruct(pool_pos.shape, pool_pos.dtype),
        jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
        jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
    ]
    # alias indices COUNT the scalar-prefetch operand: block_tables is
    # input 0, so pool_pos / k_pool / v_pool sit at 5 / 6 / 7
    aliases = {5: 1, 6: 2, 7: 3}
    if quant != "none":
        out_specs += [
            pl.BlockSpec((1, bs, K),
                         lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0)),
            pl.BlockSpec((1, bs, K),
                         lambda bi, li, tbl: (physical(bi, li, tbl), 0, 0)),
        ]
        out_shape += [jax.ShapeDtypeStruct(k_scales.shape, k_scales.dtype),
                      jax.ShapeDtypeStruct(v_scales.shape, v_scales.dtype)]
        aliases.update({8: 4, 9: 5})
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, m_blocks),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            _SCRATCH((C, K, G)),
            _SCRATCH((C, K, G)),
            _SCRATCH((C, K, G, hd)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*args)
