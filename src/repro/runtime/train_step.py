"""Train-step factory: remat policy × microbatch accumulation × optimizer.

These three knobs are exactly the WSMC planner's configuration surface
(core/planner.py): they trade transient memory ("shuffle data") against
step time, the way spark.executor.memory traded caching against spills.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import cross_entropy
from repro.optim import optimizers as opt
from repro.optim.compress import compress_roundtrip
from repro.optim.schedule import warmup_cosine

REMAT_POLICIES = ("none", "dots", "full")


def remat_wrapper(policy: str) -> Callable:
    if policy == "none":
        return lambda f: f
    if policy == "dots":
        return lambda f: jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if policy == "full":
        return lambda f: jax.checkpoint(
            f, policy=jax.checkpoint_policies.nothing_saveable)
    raise ValueError(policy)


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    remat: str = "none"
    microbatches: int = 1
    optimizer: opt.OptimizerConfig = opt.OptimizerConfig()
    settings: M.ModelSettings = M.ModelSettings()
    max_grad_norm: float = 1.0
    lb_coef: float = 0.01
    z_coef: float = 1e-3
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False    # int8 round-trip on accumulated grads


def make_loss_fn(cfg: ModelConfig, tcfg: TrainStepConfig):
    wrapper = remat_wrapper(tcfg.remat)

    def loss_fn(params, batch):
        logits, _, aux = M.apply(
            params, cfg, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            settings=tcfg.settings, unit_wrapper=wrapper)
        if cfg.n_prefix_embeds:
            logits = logits[:, cfg.n_prefix_embeds:]
        loss = cross_entropy(logits, batch["targets"])
        total = (loss + tcfg.lb_coef * aux["lb_loss"]
                 + tcfg.z_coef * aux["z_loss"])
        return total, {"loss": loss, "lb_loss": aux["lb_loss"],
                       "z_loss": aux["z_loss"]}

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainStepConfig):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics). Pure; jit/pjit-ready."""
    loss_fn = make_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    n_micro = tcfg.microbatches

    def train_step(params, opt_state, batch, step):
        if n_micro == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                assert b % n_micro == 0, (b, n_micro)
                return x.reshape((n_micro, b // n_micro) + x.shape[1:])
            micro = jax.tree.map(reshape, batch)
            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            met0 = {"loss": jnp.zeros((), jnp.float32),
                    "lb_loss": jnp.zeros((), jnp.float32),
                    "z_loss": jnp.zeros((), jnp.float32)}

            def body(carry, mb):
                gacc, macc = carry
                (_, met), g = grad_fn(params, mb)
                gacc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), gacc, g)
                macc = {k: macc[k] + met[k] for k in macc}
                return (gacc, macc), None

            (gacc, macc), _ = jax.lax.scan(body, (acc0, met0), micro)
            grads = jax.tree.map(lambda g: (g / n_micro), gacc)
            metrics = {k: v / n_micro for k, v in macc.items()}

        if tcfg.compress_grads:
            key = jax.random.fold_in(jax.random.PRNGKey(17), step)
            grads = compress_roundtrip(grads, key)

        grads, gnorm = opt.clip_by_global_norm(grads, tcfg.max_grad_norm)
        lr = warmup_cosine(step, tcfg.optimizer.lr, tcfg.warmup_steps,
                           tcfg.total_steps)
        params, opt_state = opt.apply_updates(tcfg.optimizer, params, grads,
                                              opt_state, lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step
