"""Train-step configuration and loss assembly.

The remat / microbatches / optimizer knobs are exactly the WSMC planner's
configuration surface (core/planner.py): they trade transient memory
("shuffle data") against step time, the way spark.executor.memory traded
caching against spills. HOW the microbatches execute (single shot, scan
accumulation, or the 1F1B pipe-axis pipeline) is the schedule's business:
`runtime.schedule.make_train_step` is the factory; the `make_train_step`
here is a back-compat facade that resolves the schedule from tcfg alone.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import cross_entropy
from repro.optim import optimizers as opt

REMAT_POLICIES = ("none", "dots", "full")


def remat_wrapper(policy: str) -> Callable:
    if policy == "none":
        return lambda f: f
    if policy == "dots":
        return lambda f: jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if policy == "full":
        return lambda f: jax.checkpoint(
            f, policy=jax.checkpoint_policies.nothing_saveable)
    raise ValueError(policy)


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    remat: str = "none"
    microbatches: int = 1
    optimizer: opt.OptimizerConfig = opt.OptimizerConfig()
    settings: M.ModelSettings = M.ModelSettings()
    max_grad_norm: float = 1.0
    lb_coef: float = 0.01
    z_coef: float = 1e-3
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False    # int8 round-trip on accumulated grads


def make_loss_fn(cfg: ModelConfig, tcfg: TrainStepConfig):
    wrapper = remat_wrapper(tcfg.remat)

    def loss_fn(params, batch):
        logits, _, aux = M.apply(
            params, cfg, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            settings=tcfg.settings, unit_wrapper=wrapper)
        if cfg.n_prefix_embeds:
            logits = logits[:, cfg.n_prefix_embeds:]
        loss = cross_entropy(logits, batch["targets"])
        total = (loss + tcfg.lb_coef * aux["lb_loss"]
                 + tcfg.z_coef * aux["z_loss"])
        return total, {"loss": loss, "lb_loss": aux["lb_loss"],
                       "z_loss": aux["z_loss"]}

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainStepConfig, *,
                    mesh=None, schedule: str = "auto"):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics). Pure; jit/pjit-ready.

    Facade over `runtime.schedule.make_train_step` (lazy import so the two
    modules stay a one-way dependency): without a mesh this resolves to the
    legacy single/scan schedules; a mesh with a pipe axis > 1 dispatches to
    the 1F1B pipeline schedule.
    """
    from repro.runtime import schedule as SCH
    return SCH.make_train_step(cfg, tcfg, mesh=mesh, schedule=schedule)
