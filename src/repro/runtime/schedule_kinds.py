"""Schedule vocabulary — jax-free on purpose.

`search.execplan` promotes plans to schedules at planning time (possibly
with zero compiles and no jax import at all); `runtime.schedule` executes
them. Both speak this module's language, so the planning layer never has
to import the jax-heavy runtime stack.
"""
from __future__ import annotations

from typing import List, Mapping, Optional

from repro.configs.base import MLP_MOE, TRAIN, ModelConfig

SCHEDULE_SINGLE = "single"
SCHEDULE_SCAN = "scan"
SCHEDULE_PIPELINE = "pipeline_1f1b"
SCHEDULES = (SCHEDULE_SINGLE, SCHEDULE_SCAN, SCHEDULE_PIPELINE)


def schedule_kind(kind: str, microbatches: int, pipe: int = 1) -> str:
    """The execution schedule implied by a (shape kind, plan, mesh) triple.
    Serving steps are always single-shot; training dispatches on the pipe
    axis first, then on microbatch depth."""
    if kind != TRAIN:
        return SCHEDULE_SINGLE
    if pipe > 1:
        return SCHEDULE_PIPELINE
    if microbatches > 1:
        return SCHEDULE_SCAN
    return SCHEDULE_SINGLE


def pipeline_problems(cfg: Optional[ModelConfig], microbatches: int,
                      mesh_shape: Mapping[str, int],
                      global_batch: Optional[int] = None) -> List[str]:
    """Why (cfg, microbatches, mesh) cannot run the 1F1B schedule on a
    pipe>1 mesh; empty = executable. THE single source of truth mirrored
    by runtime.schedule.validate_pipeline (raises), launch.compile's
    fallback_schedule, the search space's PIPE_EXECUTABLE constraint
    (filters candidates) and the predictor's pipeline_would_execute (the
    memory model follows the compile fallback). A MoE TAIL is fine — tail
    blocks run outside the stages with their aux losses collected; only
    MoE inside the repeated unit is blocked. With `global_batch` the
    batch/dp divisibility the pipeline x_spec sharding needs is checked
    too (callers without a workload shape skip it)."""
    pipe = int(mesh_shape.get("pipe", 1))
    problems = []
    if int(mesh_shape.get("model", 1)) > 1:
        problems.append("model axis > 1 (no TP inside pipeline stages yet)")
    if cfg is None or not cfg.unit:
        problems.append("config has no repeated unit to split into stages")
    else:
        if cfg.repeats % max(pipe, 1):
            problems.append(f"unit repeats {cfg.repeats} not divisible by "
                            f"pipe={pipe}")
        if any(blk.mlp == MLP_MOE for blk in cfg.unit):
            problems.append("MoE units unsupported (aux losses cannot "
                            "cross stage boundaries yet)")
    if cfg is not None and cfg.n_prefix_embeds:
        problems.append("prefix-embed archs unsupported under the pipeline "
                        "schedule")
    if microbatches < pipe:
        problems.append(f"microbatches={microbatches} < pipe={pipe}: "
                        "the pipeline never fills")
    if global_batch is not None:
        micro = max(microbatches, 1)
        dp = (int(mesh_shape.get("pod", 1))
              * int(mesh_shape.get("data", 1)))
        if global_batch % micro:
            problems.append(f"global batch {global_batch} not divisible "
                            f"by microbatches={micro}")
        elif (global_batch // micro) % max(dp, 1):
            problems.append(
                f"per-microbatch batch {global_batch // micro} not "
                f"divisible by the data axes (dp={dp}): the pipeline "
                "x_spec shards the microbatch batch dim")
    return problems


def pipeline_executable(cfg: Optional[ModelConfig], microbatches: int,
                        mesh_shape: Mapping[str, int],
                        global_batch: Optional[int] = None) -> bool:
    """True iff a pipe>1 mesh would actually run the 1F1B schedule."""
    if int(mesh_shape.get("pipe", 1)) <= 1:
        return False
    return not pipeline_problems(cfg, microbatches, mesh_shape,
                                 global_batch)
