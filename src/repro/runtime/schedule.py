"""Schedule-aware train-step factory: HOW a step executes its microbatches.

The WSMC planner decides the memory plan (remat x microbatches x optimizer)
and, since mesh search, the mesh itself — including a "pipe" axis. This
module turns that decision into the runnable step:

  single        — one forward/backward over the whole batch.
  scan          — microbatch accumulation via lax.scan (the plan's
                  transient-shrinking knob on a flat mesh).
  pipeline_1f1b — the pipe-axis runtime: the stacked unit layers are split
                  into mesh.shape["pipe"] contiguous stages
                  (parallel.pipeline.split_stages) and driven through
                  parallel.pipeline.pipeline_apply; loss and gradients flow
                  through the pipelined forward. Each stage keeps at most
                  its in-flight boundary carries resident (stage bodies are
                  rematerialized in the backward), which is the in-flight
                  transient model core.predictor assumes for pipe > 1.

`make_train_step(cfg, tcfg, mesh=..., schedule="auto")` dispatches; the
legacy `runtime.train_step.make_train_step(cfg, tcfg)` facade delegates
here with schedule resolution from tcfg alone (no pipe).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import TRAIN, ModelConfig
from repro.models import model as M
from repro.models.layers import cross_entropy
from repro.optim import optimizers as opt
from repro.optim.compress import compress_roundtrip
from repro.optim.schedule import warmup_cosine
from repro.parallel import axes as pax
from repro.parallel import pipeline as PPL
from repro.runtime.schedule_kinds import (  # noqa: F401 — re-exported vocabulary
    SCHEDULE_PIPELINE, SCHEDULE_SCAN, SCHEDULE_SINGLE, SCHEDULES,
    pipeline_problems, schedule_kind)
from repro.runtime.train_step import (TrainStepConfig, make_loss_fn,
                                      remat_wrapper)


def pipe_size_of(mesh) -> int:
    """Pipeline-stage count of a jax Mesh or {axis: size} dict (1 = none)."""
    if mesh is None:
        return 1
    shape = mesh if isinstance(mesh, dict) else dict(mesh.shape)
    return int(shape.get("pipe", 1))


def validate_pipeline(cfg: ModelConfig, tcfg: TrainStepConfig, mesh) -> int:
    """Check (cfg, tcfg, mesh) is executable by the 1F1B schedule; returns
    the stage count. One predicate (schedule_kinds.pipeline_problems) is
    shared with the search space's PIPE_EXECUTABLE constraint and the
    predictor, so a planned candidate is exactly a runnable one."""
    pipe = pipe_size_of(mesh)
    shape = mesh if isinstance(mesh, dict) else dict(mesh.shape)
    problems = []
    if pipe <= 1:
        problems.append("mesh has no pipe axis of size > 1")
    problems += pipeline_problems(cfg, tcfg.microbatches, shape)
    if problems:
        raise ValueError("pipeline_1f1b schedule not executable: "
                         + "; ".join(problems))
    return pipe


def fallback_schedule(cfg: ModelConfig, tcfg: TrainStepConfig, mesh,
                      global_batch: Optional[int] = None) -> str:
    """Best-effort schedule for measurement probes (launch.compile): the
    pipeline kind when (cfg, tcfg, mesh) is executable, else scan/single on
    the same mesh — the profiling ladder measures the BASELINE_PLAN
    (microbatches=1) on whatever mesh it is handed, including pipe ones,
    and exhaustive/staged search enumerates microbatch counts the pipeline
    batch sharding cannot take. Drivers executing a planned schedule stay
    strict (validate_pipeline raises)."""
    kind = schedule_kind(TRAIN, tcfg.microbatches, pipe_size_of(mesh))
    if kind == SCHEDULE_PIPELINE:
        shape = mesh if isinstance(mesh, dict) else dict(mesh.shape)
        if pipeline_problems(cfg, tcfg.microbatches, shape, global_batch):
            return (SCHEDULE_SCAN if tcfg.microbatches > 1
                    else SCHEDULE_SINGLE)
    return kind


def _batch_spec(mesh) -> P:
    """Spec of the microbatched activations [n_micro, mb, ...]: batch dim
    sharded over the data axes, microbatch dim local."""
    bd = tuple(a for a in ("pod", "data")
               if a in mesh.axis_names and mesh.shape[a] > 1)
    return P(None, bd) if bd else P()


def make_pipeline_loss_fn(cfg: ModelConfig, tcfg: TrainStepConfig, mesh,
                          axis: str = "pipe"):
    """loss_fn(params, batch) whose forward runs the unit stack as a
    pipeline over mesh axis `axis`. Embedding and tail/norm/head stay
    outside the shard_map (they are not depth-split); the stage body is
    rematerialized so the scan carries (boundary activations) are the only
    stashed state — the 1F1B in-flight memory shape."""
    n_stages = validate_pipeline(cfg, tcfg, mesh)
    n_micro = tcfg.microbatches
    settings = tcfg.settings
    wrapper = remat_wrapper(tcfg.remat)
    x_spec = _batch_spec(mesh)

    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= int(mesh.shape[a])

    def loss_fn(params, batch):
        tokens, targets = batch["tokens"], batch["targets"]
        b, s = tokens.shape
        if b % n_micro:
            raise ValueError(f"global batch {b} not divisible by "
                             f"microbatches={n_micro}")
        mb = b // n_micro
        if mb % dp:
            raise ValueError(
                f"per-microbatch batch {mb} not divisible by the data axes "
                f"(dp={dp}): the pipeline shards the microbatch batch dim; "
                "lower microbatches or the data axis")
        x = M.layers.embed_lookup(params["embed"], cfg, tokens,
                                  onehot=settings.embed_onehot)
        x_micro = x.reshape((n_micro, mb, s, x.shape[-1]))
        x_micro = jax.lax.with_sharding_constraint(
            x_micro, NamedSharding(mesh, x_spec))
        stage_units = PPL.split_stages(tuple(params["units"]), n_stages)

        def stage_fn(units_one, xmb):
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                   (xmb.shape[0], s))
            # per-device code: logical-axis annotations would name manual
            # mesh axes inside shard_map — suspend them for this trace
            with pax.suspend_annotations():
                y, _ = M.unit_stack_forward(list(units_one), cfg, xmb, pos,
                                            settings=settings, context=s,
                                            unit_wrapper=wrapper)
            return y

        # Rematerialize the whole stage per tick: backward re-runs one
        # stage body at a time, so only the boundary carries stay resident
        # (GPipe full-stash would keep every microbatch's activations).
        stage_fn = jax.checkpoint(
            stage_fn, policy=jax.checkpoint_policies.nothing_saveable)
        y = PPL.pipeline_apply(stage_fn, stage_units, x_micro, mesh=mesh,
                               axis=axis, x_spec=x_spec)
        x = y.reshape((b, s, y.shape[-1])).astype(x.dtype)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        logits, aux = M.tail_head_forward(params, cfg, x, pos,
                                          settings=settings, context=s)
        loss = cross_entropy(logits, targets)
        total = (loss + tcfg.lb_coef * aux["lb_loss"]
                 + tcfg.z_coef * aux["z_loss"])
        return total, {"loss": loss, "lb_loss": aux["lb_loss"],
                       "z_loss": aux["z_loss"]}

    return loss_fn


# ---------------------------------------------------------------------------
# Gradient schedules
# ---------------------------------------------------------------------------

def _single_shot(grad_fn):
    def compute(params, batch):
        (_, metrics), grads = grad_fn(params, batch)
        return grads, metrics
    return compute


def _scan_accum(grad_fn, n_micro: int):
    def compute(params, batch):
        def reshape(x):
            b = x.shape[0]
            assert b % n_micro == 0, (b, n_micro)
            return x.reshape((n_micro, b // n_micro) + x.shape[1:])
        micro = jax.tree.map(reshape, batch)
        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)
        met0 = {"loss": jnp.zeros((), jnp.float32),
                "lb_loss": jnp.zeros((), jnp.float32),
                "z_loss": jnp.zeros((), jnp.float32)}

        def body(carry, mb):
            gacc, macc = carry
            (_, met), g = grad_fn(params, mb)
            gacc = jax.tree.map(
                lambda a, b_: a + b_.astype(jnp.float32), gacc, g)
            macc = {k: macc[k] + met[k] for k in macc}
            return (gacc, macc), None

        (gacc, macc), _ = jax.lax.scan(body, (acc0, met0), micro)
        grads = jax.tree.map(lambda g: (g / n_micro), gacc)
        metrics = {k: v / n_micro for k, v in macc.items()}
        return grads, metrics
    return compute


# ---------------------------------------------------------------------------
# The factory
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, tcfg: TrainStepConfig, *,
                    mesh=None, schedule: str = "auto"):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics). Pure; jit/pjit-ready.

    schedule: "auto" resolves from (tcfg.microbatches, mesh pipe axis);
    or one of SCHEDULES explicitly. The pipeline schedule needs `mesh`.
    The chosen kind is exposed as `train_step.schedule`.
    """
    if schedule == "auto":
        schedule = schedule_kind(TRAIN, tcfg.microbatches, pipe_size_of(mesh))
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; known: {SCHEDULES}")

    if schedule == SCHEDULE_PIPELINE:
        if mesh is None or isinstance(mesh, dict):
            raise ValueError("pipeline_1f1b schedule needs a real jax Mesh")
        loss_fn = make_pipeline_loss_fn(cfg, tcfg, mesh)
        compute_grads = _single_shot(jax.value_and_grad(loss_fn,
                                                        has_aux=True))
    else:
        grad_fn = jax.value_and_grad(make_loss_fn(cfg, tcfg), has_aux=True)
        if schedule == SCHEDULE_SCAN:
            if tcfg.microbatches <= 1:
                raise ValueError("scan schedule needs microbatches > 1")
            compute_grads = _scan_accum(grad_fn, tcfg.microbatches)
        else:
            compute_grads = _single_shot(grad_fn)

    def train_step(params, opt_state, batch, step):
        grads, metrics = compute_grads(params, batch)

        if tcfg.compress_grads:
            key = jax.random.fold_in(jax.random.PRNGKey(17), step)
            grads = compress_roundtrip(grads, key)

        grads, gnorm = opt.clip_by_global_norm(grads, tcfg.max_grad_norm)
        lr = warmup_cosine(step, tcfg.optimizer.lr, tcfg.warmup_steps,
                           tcfg.total_steps)
        params, opt_state = opt.apply_updates(tcfg.optimizer, params, grads,
                                              opt_state, lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, metrics

    train_step.schedule = schedule
    return train_step
