"""Serving steps: prefill (builds the ring KV / recurrent caches, returns
last-token logits), decode (one token per sequence against the cache), and
the slot-pool operations the serving engine's continuous batching uses
(claim a slot by overwriting it with a fresh prefill; batched decode over
heterogeneous per-slot positions rides the ring cache's slot = pos % L
layout unchanged).

Paged-pool notes (see serving.engine for the admission/eviction policy):

- Block tables are the ONLY routing state. A physical block written once
  (by whole-prompt or chunked prefill) can be mapped by many tables at
  once — refcounted prefix sharing needs no extra step: sharers simply
  seed the leading entries of their table with the shared prefix's
  physical ids and prefill only their suffix (absolute positions, so the
  KV written is identical to an unshared prefill of the full prompt).
  Copy-on-write at the prefix boundary is BY RECOMPUTE: the sharer never
  mutates a shared block; its boundary tokens are re-prefilled into a
  private block it allocated itself.
- Decode ticks run at full lane width; rows whose lane is empty or still
  mid-chunk-prefill are INERT (position -1, empty table) and their writes
  land in scratch block 0 / are dropped (see attention._paged_write), so
  a decode tick can never clobber KV a concurrent chunked prefill wrote.
- Eviction frees physical blocks but writes nothing: reset_pool_blocks
  invalidates re-linked blocks (pos -1) before a NEW owner's table routes
  a read through them, and an evicted request re-prefills prompt+emitted
  from scratch on readmission — no KV survives eviction."""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention
from repro.models import model as M


def make_prefill_step(cfg: ModelConfig,
                      settings: Optional[M.ModelSettings] = None):
    settings = settings or M.ModelSettings()
    psettings = dataclasses.replace(settings, build_cache=True)

    def prefill_step(params, tokens, context: int, prefix_embeds=None):
        logits, cache, _ = M.apply(params, cfg, tokens,
                                   prefix_embeds=prefix_embeds,
                                   settings=psettings, context=context,
                                   logits_last_only=True)
        return logits[:, -1], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig,
                     settings: Optional[M.ModelSettings] = None):
    settings = settings or M.ModelSettings()

    def decode_step(params, tokens, positions, cache, context: int):
        logits, new_cache, _ = M.apply(params, cfg, tokens,
                                       positions=positions, cache=cache,
                                       decode=True, settings=settings,
                                       context=context)
        return logits[:, -1], new_cache

    return decode_step


# ---------------------------------------------------------------------------
# Slot pool: a batch of independent ring caches the engine claims/frees
# ---------------------------------------------------------------------------

def init_slot_pool(cfg: ModelConfig, n_slots: int, context: int):
    """The engine's KV/recurrent slot pool: one cache tree whose batch dim
    is the slot index. Freshly initialized slots hold pos=-1 everywhere
    (every ring entry masked)."""
    return M.init_cache(cfg, n_slots, context)


def write_cache_slots(cfg: ModelConfig, pool, one, slots):
    """Batched write_cache_slot: scatter a width-W prefill cache (batch=W)
    into pool slots `slots` [W] in one step. Padding rows carry slot index
    >= pool width and are DROPPED by the scatter (mode='drop'), which is
    what lets the engine-level batched prefill pad every admission group to
    the pool width and keep ONE compile per prompt bucket."""
    def upd(axis):
        def f(P, o):
            idx = (slice(None),) * axis + (slots,)
            return P.at[idx].set(o.astype(P.dtype), mode="drop")
        return f

    return {
        "units": [jax.tree.map(upd(1), pool["units"][i], one["units"][i])
                  for i in range(len(cfg.unit))],
        "tail": [jax.tree.map(upd(0), pool["tail"][i], one["tail"][i])
                 for i in range(len(cfg.tail))],
    }


def write_cache_slot(cfg: ModelConfig, pool, one, slot):
    """Overwrite slot `slot` of a pool cache with a single-sequence cache
    (batch=1). Unit caches are stacked over repeats (batch is axis 1); tail
    caches lead with batch (axis 0). Prefill rings always span the full
    cache_len (attention._cache_from_prefill pads short prompts), so this
    is a whole-slot overwrite: whatever a freed slot accumulated while
    riding along in batched decode is wiped on claim."""
    def upd(axis):
        return lambda P, o: jax.lax.dynamic_update_slice_in_dim(
            P, o.astype(P.dtype), slot, axis=axis)

    return {
        "units": [jax.tree.map(upd(1), pool["units"][i], one["units"][i])
                  for i in range(len(cfg.unit))],
        "tail": [jax.tree.map(upd(0), pool["tail"][i], one["tail"][i])
                 for i in range(len(cfg.tail))],
    }


def make_slot_prefill_step(cfg: ModelConfig,
                           settings: Optional[M.ModelSettings] = None):
    """Prefill ONE sequence (tokens [1, p]) directly into slot `slot` of a
    donated pool cache. Returns (last-token logits [1, V], new pool). One
    compile per distinct prompt length (bucketed traces keep that small);
    the decode step stays a single compile at pool width."""
    settings = settings or M.ModelSettings()
    psettings = dataclasses.replace(settings, build_cache=True)

    def prefill_into_slot(params, tokens, slot, pool, context: int):
        logits, one, _ = M.apply(params, cfg, tokens, settings=psettings,
                                 context=context, logits_last_only=True)
        return logits[:, -1], write_cache_slot(cfg, pool, one, slot)

    return prefill_into_slot


def make_batch_prefill_step(cfg: ModelConfig,
                            settings: Optional[M.ModelSettings] = None):
    """Engine-level batched prefill: prefill tokens [W, p] (W = pool width,
    padding rows filled with dummy prompts) and scatter each row into pool
    slot `slots[w]` (index >= W drops the row). One compile per prompt
    bucket p, shared by every admission tick that hits the bucket."""
    settings = settings or M.ModelSettings()
    psettings = dataclasses.replace(settings, build_cache=True)

    def prefill_into_slots(params, tokens, slots, pool, context: int):
        logits, one, _ = M.apply(params, cfg, tokens, settings=psettings,
                                 context=context, logits_last_only=True)
        return logits[:, -1], write_cache_slots(cfg, pool, one, slots)

    return prefill_into_slots


# ---------------------------------------------------------------------------
# Paged KV pool: fixed-size position blocks + per-sequence block tables
# ---------------------------------------------------------------------------
#
# Full-context attention layers store KV in a POOL of `block`-position
# blocks ({"kb": [n_blocks, block, K, hd], "vb": ..., "pos": [n_blocks,
# block]}; models.attention.is_paged_cache) indexed through per-sequence
# block tables, so a short request holds ceil(written / block) blocks
# instead of a whole max-context ring. Everything else — recurrent states,
# short windowed/chunked rings — stays a per-lane slot exactly like the
# ring pool. Physical block 0 is the scratch block (inactive decode lanes
# and padded prefill rows read/write it harmlessly); the serving engine's
# BlockAllocator therefore hands out ids 1..n_blocks-1.


def is_paged_block(blk, context: int) -> bool:
    """Which layers page: attention whose ring spans the full context (the
    dominant KV cost). Short windowed/chunked rings stay per-lane."""
    return blk.is_attn and blk.cache_len(context) == context


def init_paged_pool(cfg: ModelConfig, n_lanes: int, n_blocks: int,
                    block: int, context: int, abstract: bool = False,
                    kv_quant: str = "none"):
    """The paged serving pool: paged layers get block-pool leaves (shared
    across lanes), everything else a per-lane cache like init_slot_pool.
    `context` must be a multiple of `block` (the executor rounds up).

    kv_quant != "none" stores kb/vb quantized (int8, or int4 packed two
    nibbles per uint8 byte halving the last dim) with per-(position, kv
    head) f32 absmax scales in sibling "ks"/"vs" leaves. The pool is
    self-describing: read/write paths pick the codec off the leaf dtypes
    (attention.paged_quant_kind), so a quantized pool can never be
    misread as fp."""
    if context % block:
        raise ValueError(f"paged pool context {context} must be a multiple "
                         f"of the kv block size {block}")
    if kv_quant not in ("none", "int8", "int4"):
        raise ValueError(f"unknown kv_quant {kv_quant!r}")
    hd = cfg.resolved_head_dim
    K = cfg.n_kv_heads
    if kv_quant == "int4" and hd % 2:
        raise ValueError(f"int4 KV packs nibble pairs; head_dim {hd} "
                         "must be even")

    def paged_leaf():
        if kv_quant == "none":
            return {
                "kb": jax.ShapeDtypeStruct((n_blocks, block, K, hd),
                                           jnp.bfloat16),
                "vb": jax.ShapeDtypeStruct((n_blocks, block, K, hd),
                                           jnp.bfloat16),
                "pos": jax.ShapeDtypeStruct((n_blocks, block), jnp.int32),
            }
        qdt = jnp.int8 if kv_quant == "int8" else jnp.uint8
        qhd = hd if kv_quant == "int8" else hd // 2
        return {
            "kb": jax.ShapeDtypeStruct((n_blocks, block, K, qhd), qdt),
            "vb": jax.ShapeDtypeStruct((n_blocks, block, K, qhd), qdt),
            "ks": jax.ShapeDtypeStruct((n_blocks, block, K), jnp.float32),
            "vs": jax.ShapeDtypeStruct((n_blocks, block, K), jnp.float32),
            "pos": jax.ShapeDtypeStruct((n_blocks, block), jnp.int32),
        }

    def one_cache(blk):
        if is_paged_block(blk, context):
            return paged_leaf()
        return M.block_cache_init(cfg, blk, n_lanes, context, abstract=True)

    def _materialize(s):
        if s.dtype == jnp.int32:   # position buffers start invalid
            return jnp.full(s.shape, -1, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    def stacked(blk):
        one = one_cache(blk)
        stack = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.repeats,) + s.shape, s.dtype),
            one)
        return stack if abstract else jax.tree.map(_materialize, stack)

    pool = {"units": [stacked(blk) for blk in cfg.unit], "tail": []}
    for blk in cfg.tail:
        one = one_cache(blk)
        pool["tail"].append(one if abstract
                            else jax.tree.map(_materialize, one))
    return pool


def write_paged_prefill(cfg: ModelConfig, pool, one, lanes, tables,
                        block: int):
    """Scatter a width-W prefill cache into the paged pool: paged layers
    split each row's full-context ring (identity layout: prefill positions
    start at 0, so slot i <-> position i) into `context // block` logical
    blocks and scatter them to the physical ids in `tables` [W, mB]
    (entries -1 — unallocated logical blocks, i.e. ring padding beyond the
    prompt, and whole padding rows — land in scratch block 0); per-lane
    layers scatter to `lanes` [W] with pool-width padding dropped."""
    def lane_upd(axis):
        def f(P, o):
            idx = (slice(None),) * axis + (lanes,)
            return P.at[idx].set(o.astype(P.dtype), mode="drop")
        return f

    def paged_upd(P, o, batch_axis):
        # o k/v: [..., W, L, K, hd] with L = mB * block; pos: [..., W, L].
        # Quantized pools quantize the prefill ring here (the exact same
        # per-row codec _paged_write applies on decode appends).
        kind = attention.paged_quant_kind(P)
        W, mB = tables.shape
        flat = jnp.where(tables >= 0, tables, 0).reshape(-1)      # [W*mB]
        idx = (slice(None),) * batch_axis + (flat,)

        def blocked(o_l):
            shp = o_l.shape[:batch_axis] + (W * mB, block) \
                + o_l.shape[batch_axis + 2:]
            return o_l.reshape(shp)

        new = dict(P)
        for kk, pk, sk in (("k", "kb", "ks"), ("v", "vb", "vs")):
            q, s = attention.quantize_kv(o[kk], kind)
            new[pk] = P[pk].at[idx].set(blocked(q).astype(P[pk].dtype))
            if s is not None:
                new[sk] = P[sk].at[idx].set(blocked(s))
        new["pos"] = P["pos"].at[idx].set(
            blocked(o["pos"]).astype(P["pos"].dtype))
        return new

    units = []
    for i, blk in enumerate(cfg.unit):
        P, o = pool["units"][i], one["units"][i]
        if isinstance(P, dict) and "kb" in P:
            units.append(paged_upd(P, o, batch_axis=1))
        else:
            units.append(jax.tree.map(lane_upd(1), P, o))
    tail = []
    for i, blk in enumerate(cfg.tail):
        P, o = pool["tail"][i], one["tail"][i]
        if isinstance(P, dict) and "kb" in P:
            tail.append(paged_upd(P, o, batch_axis=0))
        else:
            tail.append(jax.tree.map(lane_upd(0), P, o))
    return {"units": units, "tail": tail}


def make_paged_prefill_step(cfg: ModelConfig,
                            settings: Optional[M.ModelSettings] = None):
    """Batched prefill into the paged pool: tokens [W, p], lanes [W],
    tables [W, context // block]. One compile per prompt bucket."""
    settings = settings or M.ModelSettings()
    psettings = dataclasses.replace(settings, build_cache=True)

    def prefill_paged(params, tokens, lanes, tables, pool, context: int):
        logits, one, _ = M.apply(params, cfg, tokens, settings=psettings,
                                 context=context, logits_last_only=True)
        block = pool_block_size(pool, default=1)
        return logits[:, -1], write_paged_prefill(cfg, pool, one, lanes,
                                                  tables, block)

    return prefill_paged


def make_paged_decode_step(cfg: ModelConfig,
                           settings: Optional[M.ModelSettings] = None):
    """One batched decode tick through the block tables: a single compile
    at lane width regardless of pool occupancy."""
    settings = settings or M.ModelSettings()

    def decode_paged(params, tokens, positions, tables, pool, context: int):
        logits, new_pool, aux = M.apply(params, cfg, tokens,
                                        positions=positions, cache=pool,
                                        decode=True, settings=settings,
                                        context=context, block_tables=tables)
        # mass [b, max_blocks] (layer-summed per-block attention mass) when
        # settings.attn.track_mass, else None — the retention policy's feed
        return logits[:, -1], new_pool, aux.get("attn_mass")

    return decode_paged


def _is_paged_leaf(P) -> bool:
    return isinstance(P, dict) and "kb" in P


def gather_pool_lanes(pool, lane_ids):
    """Sub-pool view of a paged pool at lanes `lane_ids` [w]: per-lane
    leaves (recurrent states, short windowed rings) are gathered down to
    width w, paged block-pool leaves pass through whole (block tables route
    them, so they need no lane axis). Padding ids >= n_lanes clamp to a
    real lane on the read — harmless, because scatter_pool_lanes drops
    their write-back."""
    def take(axis):
        def f(P):
            idx = ((slice(None),) * axis
                   + (jnp.clip(lane_ids, 0, P.shape[axis] - 1),))
            return P[idx]
        return f

    return {
        "units": [P if _is_paged_leaf(P) else jax.tree.map(take(1), P)
                  for P in pool["units"]],
        "tail": [P if _is_paged_leaf(P) else jax.tree.map(take(0), P)
                 for P in pool["tail"]],
    }


def scatter_pool_lanes(pool, sub, lane_ids):
    """Write a width-w sub-pool (gather_pool_lanes layout) back into the
    full pool: per-lane rows land at `lane_ids` (ids >= n_lanes are padding
    and DROPPED), updated paged leaves replace the pool's wholesale."""
    def put(axis):
        def f(P, o):
            idx = (slice(None),) * axis + (lane_ids,)
            return P.at[idx].set(o.astype(P.dtype), mode="drop")
        return f

    return {
        "units": [o if _is_paged_leaf(P) else jax.tree.map(put(1), P, o)
                  for P, o in zip(pool["units"], sub["units"])],
        "tail": [o if _is_paged_leaf(P) else jax.tree.map(put(0), P, o)
                 for P, o in zip(pool["tail"], sub["tail"])],
    }


def make_compact_decode_step(cfg: ModelConfig,
                             settings: Optional[M.ModelSettings] = None):
    """Paged decode at a COMPACTED width w <= n_lanes: gather the w active
    lanes' per-lane caches, run one batched decode at width w through their
    (trimmed) block tables, scatter the updates back. jax.jit specializes
    per (w, table-width) bucket, so each touched bucket costs one compile
    and a tick with 3 active lanes stops paying for the padded remainder
    of the pool."""
    settings = settings or M.ModelSettings()

    def decode_compact(params, tokens, positions, tables, lane_ids, pool,
                       context: int):
        sub = gather_pool_lanes(pool, lane_ids)
        logits, new_sub, aux = M.apply(params, cfg, tokens,
                                       positions=positions, cache=sub,
                                       decode=True, settings=settings,
                                       context=context, block_tables=tables)
        return (logits[:, -1], scatter_pool_lanes(pool, new_sub, lane_ids),
                aux.get("attn_mass"))

    return decode_compact


def make_chunk_prefill_step(cfg: ModelConfig,
                            settings: Optional[M.ModelSettings] = None):
    """Chunked prefill: run tokens [w, C] at absolute positions [w, C]
    (-1 = padding) against the LIVE pool — attention layers append the
    chunk to what earlier chunks wrote (paged layers through `tables`,
    per-lane rings in place) and attend over history + chunk, which is
    exactly that slice of a whole-prompt prefill. Returns each row's
    last-valid-position logits (meaningful for rows whose chunk completes
    the prompt) and the updated pool. One compile per (width bucket,
    table width); C is fixed by the engine's chunk size."""
    settings = settings or M.ModelSettings()
    psettings = dataclasses.replace(settings, build_cache=True)

    def prefill_chunk(params, tokens, positions, tables, lane_ids, pool,
                      context: int):
        sub = gather_pool_lanes(pool, lane_ids)
        logits, new_sub, _ = M.apply(params, cfg, tokens,
                                     positions=positions, cache=sub,
                                     decode=False, settings=psettings,
                                     context=context, block_tables=tables)
        lens = jnp.sum(positions >= 0, axis=1)
        idx = jnp.maximum(lens - 1, 0)
        last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
        return last, scatter_pool_lanes(pool, new_sub, lane_ids)

    return prefill_chunk


def pool_block_size(pool, default: int = 0) -> int:
    """The kv block size a paged pool was built with (from any paged leaf).
    `default` covers pools with nothing to page (all-recurrent or
    short-window archs, where paged mode degenerates to per-lane slots)."""
    for P in list(pool["units"]) + list(pool["tail"]):
        if isinstance(P, dict) and "kb" in P:
            return int(P["pos"].shape[-1])
    return default


def reset_pool_blocks(pool, ids):
    """Invalidate physical blocks `ids` [W] (pos = -1) before a freed block
    is re-linked into a new sequence's table mid-decode — without it the
    block's stale positions from its previous owner would pass the decode
    mask. Padding entries may point at scratch block 0 (reset is harmless
    there)."""
    def upd(P, lead):
        idx = (slice(None),) * lead + (ids,)
        return {**P, "pos": P["pos"].at[idx].set(-1)}

    return {
        "units": [upd(P, 1) if isinstance(P, dict) and "kb" in P else P
                  for P in pool["units"]],
        "tail": [upd(P, 0) if isinstance(P, dict) and "kb" in P else P
                 for P in pool["tail"]],
    }


def clear_pool(pool):
    """Re-initialize a live pool wholesale: every int32 validity buffer
    (paged block `pos`, ring positions) back to -1, every payload leaf to
    zeros — `init_paged_pool`'s freshly-materialized state without
    rebuilding the tree. This is the executor-REUSE hook for engine
    snapshot/restore: `Engine.resume` re-materializes all KV via
    re-prefill anyway, so a preempted replica hands its existing device
    buffers to the restored engine instead of paying a fresh allocation."""
    def f(x):
        if hasattr(x, "dtype") and x.dtype == jnp.int32:
            return jnp.full(x.shape, -1, x.dtype)
        return jnp.zeros(x.shape, x.dtype)

    return jax.tree.map(f, pool)


def _sharding_ctx_key():
    """The ambient sharding context shard()/gather_fsdp bake into a trace
    (parallel.axes thread-locals). jax.jit's own cache does not key on it,
    so the memoized steps below must — otherwise a run under different
    axis_rules/mesh would reuse a trace with the wrong constraints."""
    from repro.parallel import axes as pax
    mesh = pax.current_mesh()
    return (mesh, tuple(sorted(pax.current_rules().items())))


@functools.lru_cache(maxsize=64)
def _jitted_serve_steps(cfg, settings, mode: str, ctx_key):
    if mode == "plain":
        prefill = jax.jit(make_prefill_step(cfg, settings),
                          static_argnames=("context",))
        decode = jax.jit(make_decode_step(cfg, settings),
                         static_argnames=("context",), donate_argnums=(3,))
        return prefill, decode
    if mode == "slot":
        prefill = jax.jit(make_slot_prefill_step(cfg, settings),
                          static_argnames=("context",), donate_argnums=(3,))
        batch = jax.jit(make_batch_prefill_step(cfg, settings),
                        static_argnames=("context",), donate_argnums=(3,))
        decode = jax.jit(make_decode_step(cfg, settings),
                         static_argnames=("context",), donate_argnums=(3,))
        return prefill, batch, decode
    if mode == "paged":
        prefill = jax.jit(make_paged_prefill_step(cfg, settings),
                          static_argnames=("context",), donate_argnums=(4,))
        decode = jax.jit(make_paged_decode_step(cfg, settings),
                         static_argnames=("context",), donate_argnums=(4,))
        reset = jax.jit(reset_pool_blocks, donate_argnums=(0,))
        compact = jax.jit(make_compact_decode_step(cfg, settings),
                          static_argnames=("context",), donate_argnums=(5,))
        chunk = jax.jit(make_chunk_prefill_step(cfg, settings),
                        static_argnames=("context",), donate_argnums=(5,))
        return prefill, decode, reset, compact, chunk
    raise ValueError(mode)


def serve_steps(cfg: ModelConfig,
                settings: Optional[M.ModelSettings] = None):
    """Jitted (prefill, decode) pair, memoized per (cfg, settings, ambient
    sharding context): repeated greedy_generate calls (tests, examples)
    reuse the compiled steps instead of re-tracing per call. `context` is
    static and the decode cache is donated in place."""
    return _jitted_serve_steps(cfg, settings, "plain", _sharding_ctx_key())


def slot_serve_steps(cfg: ModelConfig,
                     settings: Optional[M.ModelSettings] = None):
    """Jitted (prefill-into-slot, batched-prefill-into-slots, decode)
    triple for the engine's slot pool, memoized like serve_steps so
    successive executors (e.g. the serve driver's --policy both runs)
    share compiled steps instead of paying the whole compile set again.
    Pool arguments are donated."""
    return _jitted_serve_steps(cfg, settings, "slot", _sharding_ctx_key())


def paged_serve_steps(cfg: ModelConfig,
                      settings: Optional[M.ModelSettings] = None):
    """Jitted (batched-prefill, decode, reset-blocks, compact-decode,
    chunk-prefill) tuple for the paged block pool, memoized like
    slot_serve_steps. The full-width decode is one compile at lane width;
    the compact decode specializes per touched (lane, table) width bucket;
    prefill compiles once per prompt bucket (padded to lane width) and
    chunk-prefill once per touched width bucket at the fixed chunk
    length."""
    return _jitted_serve_steps(cfg, settings, "paged", _sharding_ctx_key())


def greedy_generate(params, cfg: ModelConfig, prompt_tokens, n_steps: int,
                    context: int, settings: Optional[M.ModelSettings] = None):
    """Greedy decoding with jitted, cache-donating steps (serve_steps):
    the engine's per-request reference path."""
    b, p = prompt_tokens.shape
    prefill, decode = serve_steps(cfg, settings)
    last_logits, cache = prefill(params, prompt_tokens, context=context)
    out = []
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    for t in range(n_steps):
        out.append(tok)
        pos = jnp.full((b,), p + t, jnp.int32)
        logits, cache = decode(params, tok[:, None], pos, cache,
                               context=context)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(out, axis=1)
