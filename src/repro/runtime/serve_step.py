"""Serving steps: prefill (builds the ring KV / recurrent caches, returns
last-token logits), decode (one token per sequence against the cache), and
the slot-pool operations the serving engine's continuous batching uses
(claim a slot by overwriting it with a fresh prefill; batched decode over
heterogeneous per-slot positions rides the ring cache's slot = pos % L
layout unchanged)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


def make_prefill_step(cfg: ModelConfig,
                      settings: Optional[M.ModelSettings] = None):
    settings = settings or M.ModelSettings()
    psettings = dataclasses.replace(settings, build_cache=True)

    def prefill_step(params, tokens, context: int, prefix_embeds=None):
        logits, cache, _ = M.apply(params, cfg, tokens,
                                   prefix_embeds=prefix_embeds,
                                   settings=psettings, context=context,
                                   logits_last_only=True)
        return logits[:, -1], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig,
                     settings: Optional[M.ModelSettings] = None):
    settings = settings or M.ModelSettings()

    def decode_step(params, tokens, positions, cache, context: int):
        logits, new_cache, _ = M.apply(params, cfg, tokens,
                                       positions=positions, cache=cache,
                                       decode=True, settings=settings,
                                       context=context)
        return logits[:, -1], new_cache

    return decode_step


# ---------------------------------------------------------------------------
# Slot pool: a batch of independent ring caches the engine claims/frees
# ---------------------------------------------------------------------------

def init_slot_pool(cfg: ModelConfig, n_slots: int, context: int):
    """The engine's KV/recurrent slot pool: one cache tree whose batch dim
    is the slot index. Freshly initialized slots hold pos=-1 everywhere
    (every ring entry masked)."""
    return M.init_cache(cfg, n_slots, context)


def write_cache_slot(cfg: ModelConfig, pool, one, slot):
    """Overwrite slot `slot` of a pool cache with a single-sequence cache
    (batch=1). Unit caches are stacked over repeats (batch is axis 1); tail
    caches lead with batch (axis 0). Prefill rings always span the full
    cache_len (attention._cache_from_prefill pads short prompts), so this
    is a whole-slot overwrite: whatever a freed slot accumulated while
    riding along in batched decode is wiped on claim."""
    def upd(axis):
        return lambda P, o: jax.lax.dynamic_update_slice_in_dim(
            P, o.astype(P.dtype), slot, axis=axis)

    return {
        "units": [jax.tree.map(upd(1), pool["units"][i], one["units"][i])
                  for i in range(len(cfg.unit))],
        "tail": [jax.tree.map(upd(0), pool["tail"][i], one["tail"][i])
                 for i in range(len(cfg.tail))],
    }


def make_slot_prefill_step(cfg: ModelConfig,
                           settings: Optional[M.ModelSettings] = None):
    """Prefill ONE sequence (tokens [1, p]) directly into slot `slot` of a
    donated pool cache. Returns (last-token logits [1, V], new pool). One
    compile per distinct prompt length (bucketed traces keep that small);
    the decode step stays a single compile at pool width."""
    settings = settings or M.ModelSettings()
    psettings = dataclasses.replace(settings, build_cache=True)

    def prefill_into_slot(params, tokens, slot, pool, context: int):
        logits, one, _ = M.apply(params, cfg, tokens, settings=psettings,
                                 context=context, logits_last_only=True)
        return logits[:, -1], write_cache_slot(cfg, pool, one, slot)

    return prefill_into_slot


def _sharding_ctx_key():
    """The ambient sharding context shard()/gather_fsdp bake into a trace
    (parallel.axes thread-locals). jax.jit's own cache does not key on it,
    so the memoized steps below must — otherwise a run under different
    axis_rules/mesh would reuse a trace with the wrong constraints."""
    from repro.parallel import axes as pax
    mesh = pax.current_mesh()
    return (mesh, tuple(sorted(pax.current_rules().items())))


@functools.lru_cache(maxsize=64)
def _jitted_serve_steps(cfg, settings, slot: bool, ctx_key):
    prefill_fn = (make_slot_prefill_step if slot
                  else make_prefill_step)(cfg, settings)
    prefill = jax.jit(prefill_fn, static_argnames=("context",),
                      donate_argnums=(3,) if slot else ())
    decode = jax.jit(make_decode_step(cfg, settings),
                     static_argnames=("context",), donate_argnums=(3,))
    return prefill, decode


def serve_steps(cfg: ModelConfig,
                settings: Optional[M.ModelSettings] = None):
    """Jitted (prefill, decode) pair, memoized per (cfg, settings, ambient
    sharding context): repeated greedy_generate calls (tests, examples)
    reuse the compiled steps instead of re-tracing per call. `context` is
    static and the decode cache is donated in place."""
    return _jitted_serve_steps(cfg, settings, False, _sharding_ctx_key())


def slot_serve_steps(cfg: ModelConfig,
                     settings: Optional[M.ModelSettings] = None):
    """Jitted (prefill-into-slot, decode) pair for the engine's slot pool,
    memoized like serve_steps so successive executors (e.g. the serve
    driver's --policy both runs) share compiled steps instead of paying
    the whole compile set again. Pool arguments are donated."""
    return _jitted_serve_steps(cfg, settings, True, _sharding_ctx_key())


def greedy_generate(params, cfg: ModelConfig, prompt_tokens, n_steps: int,
                    context: int, settings: Optional[M.ModelSettings] = None):
    """Greedy decoding with jitted, cache-donating steps (serve_steps):
    the engine's per-request reference path."""
    b, p = prompt_tokens.shape
    prefill, decode = serve_steps(cfg, settings)
    last_logits, cache = prefill(params, prompt_tokens, context=context)
    out = []
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    for t in range(n_steps):
        out.append(tok)
        pos = jnp.full((b,), p + t, jnp.int32)
        logits, cache = decode(params, tok[:, None], pos, cache,
                               context=context)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(out, axis=1)
