"""Serving steps: prefill (builds the ring KV / recurrent caches, returns
last-token logits) and decode (one token per sequence against the cache)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


def make_prefill_step(cfg: ModelConfig,
                      settings: Optional[M.ModelSettings] = None):
    settings = settings or M.ModelSettings()
    psettings = dataclasses.replace(settings, build_cache=True)

    def prefill_step(params, tokens, context: int, prefix_embeds=None):
        logits, cache, _ = M.apply(params, cfg, tokens,
                                   prefix_embeds=prefix_embeds,
                                   settings=psettings, context=context,
                                   logits_last_only=True)
        return logits[:, -1], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig,
                     settings: Optional[M.ModelSettings] = None):
    settings = settings or M.ModelSettings()

    def decode_step(params, tokens, positions, cache, context: int):
        logits, new_cache, _ = M.apply(params, cfg, tokens,
                                       positions=positions, cache=cache,
                                       decode=True, settings=settings,
                                       context=context)
        return logits[:, -1], new_cache

    return decode_step


def greedy_generate(params, cfg: ModelConfig, prompt_tokens, n_steps: int,
                    context: int, settings: Optional[M.ModelSettings] = None):
    """Python-loop greedy decoding (tests/examples; drivers jit the steps)."""
    b, p = prompt_tokens.shape
    prefill = make_prefill_step(cfg, settings)
    decode = make_decode_step(cfg, settings)
    last_logits, cache = prefill(params, prompt_tokens, context)
    out = []
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    for t in range(n_steps):
        out.append(tok)
        pos = jnp.full((b,), p + t, jnp.int32)
        logits, cache = decode(params, tok[:, None], pos, cache, context)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(out, axis=1)
