"""Fault tolerance: step watchdog / straggler detection, preemption-safe
checkpointing, restart and elastic re-mesh orchestration.

Single-process simulation discipline: every mechanism is driven through the
same interfaces a multi-host deployment would use (per-host step timings fed
to the watchdog, SIGTERM -> checkpoint, restore onto a different mesh), so
the logic is testable here and deployable there.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint import ckpt as CK


@dataclasses.dataclass
class StragglerReport:
    step: int
    median_s: float
    slow_hosts: Dict[int, float]    # host_id -> step seconds


class Watchdog:
    """Flags hosts whose step time exceeds `threshold` x median over a
    sliding window — the exclusion candidates for elastic restart."""

    def __init__(self, threshold: float = 2.0, window: int = 16):
        self.threshold = threshold
        self.window = window
        self._times: Dict[int, List[float]] = {}
        self.reports: List[StragglerReport] = []

    def record(self, step: int, host_times: Dict[int, float]
               ) -> Optional[StragglerReport]:
        for h, t in host_times.items():
            self._times.setdefault(h, []).append(t)
            self._times[h] = self._times[h][-self.window:]
        med = float(np.median([np.median(v) for v in self._times.values()]))
        slow = {h: float(np.median(v)) for h, v in self._times.items()
                if np.median(v) > self.threshold * med}
        if slow:
            rep = StragglerReport(step, med, slow)
            self.reports.append(rep)
            return rep
        return None


class CheckpointManager:
    """Periodic + on-demand checkpointing with async writes and auto-resume."""

    def __init__(self, ckpt_dir: str, interval: int = 100,
                 async_: bool = True):
        self.dir = ckpt_dir
        self.interval = interval
        self.async_ = async_
        self._pending = None

    def maybe_save(self, step: int, tree, extra=None, force: bool = False):
        if not force and (self.interval <= 0 or step % self.interval):
            return
        self.wait()
        self._pending = CK.save(self.dir, step, tree, extra=extra,
                                async_=self.async_)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def latest_step(self):
        return CK.latest_step(self.dir)

    def restore(self, target_tree, mesh=None, spec_tree=None, step=None):
        return CK.restore(self.dir, target_tree, step=step, mesh=mesh,
                          spec_tree=spec_tree)


class PreemptionGuard:
    """SIGTERM/SIGINT -> set a flag the train loop polls; the loop saves a
    final checkpoint and exits cleanly (TPU preemption semantics)."""

    def __init__(self, install: bool = False):
        self.preempted = False
        if install:
            signal.signal(signal.SIGTERM, self._handler)

    def _handler(self, signum, frame):   # pragma: no cover (signal path)
        self.preempted = True

    def trigger(self):                   # tests call this directly
        self.preempted = True


def run_train_loop(*, train_step: Callable, params, opt_state, pipeline,
                   n_steps: int, ckpt_mgr: Optional[CheckpointManager] = None,
                   watchdog: Optional[Watchdog] = None,
                   guard: Optional[PreemptionGuard] = None,
                   start_step: int = 0,
                   host_time_fn: Optional[Callable[[int], Dict[int, float]]]
                   = None,
                   on_metrics: Optional[Callable] = None,
                   fail_at: Optional[int] = None):
    """Generic fault-tolerant loop. `fail_at` injects a crash (tests).

    Returns (params, opt_state, last_step_completed, metrics_history).
    """
    import jax.numpy as jnp
    history = []
    step = start_step
    while step < n_steps:
        if guard is not None and guard.preempted:
            if ckpt_mgr:
                ckpt_mgr.maybe_save(step, {"params": params,
                                           "opt": opt_state},
                                    extra={"step": step}, force=True)
                ckpt_mgr.wait()
            return params, opt_state, step, history
        if fail_at is not None and step == fail_at:
            raise RuntimeError(f"injected failure at step {step}")
        batch = pipeline.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.monotonic()
        params, opt_state, metrics = train_step(params, opt_state, batch,
                                                jnp.asarray(step))
        dt = time.monotonic() - t0
        if watchdog is not None:
            times = (host_time_fn(step) if host_time_fn
                     else {0: dt})
            watchdog.record(step, times)
        history.append({k: float(v) for k, v in metrics.items()})
        if on_metrics:
            on_metrics(step, history[-1])
        step += 1
        if ckpt_mgr:
            ckpt_mgr.maybe_save(step, {"params": params, "opt": opt_state},
                                extra={"step": step})
    if ckpt_mgr:
        ckpt_mgr.maybe_save(n_steps, {"params": params, "opt": opt_state},
                            extra={"step": n_steps}, force=True)
        ckpt_mgr.wait()
    return params, opt_state, step, history
