"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427] — RG-LRU + local attention.

38 blocks, d_model=4096, local-attn 16 heads but MQA kv=1, d_ff=12288
(GeGLU), vocab 256000, lru_width=4096, window 2048. Pattern: (rec, rec,
attn) ×12 + (rec, rec) tail = 38.
"""
from repro.configs.base import BlockSpec, ModelConfig, ATTN, RGLRU, MLP_DENSE

_REC = BlockSpec(mixer=RGLRU, mlp=MLP_DENSE)
_ATT = BlockSpec(mixer=ATTN, mlp=MLP_DENSE, window=2048)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    unit=(_REC, _REC, _ATT),
    tail=(_REC, _REC),
    activation="geglu",
    lru_width=4096,
    conv_width=4,
    tie_embeddings=True,
)
