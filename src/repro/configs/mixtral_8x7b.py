"""Mixtral-8x7B [arXiv:2401.04088; hf] — 8-expert top-2 MoE with SWA.

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336 per expert (SwiGLU),
vocab 32000, sliding window 4096.
"""
from repro.configs.base import BlockSpec, ModelConfig, ATTN, MLP_MOE

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    unit=(BlockSpec(mixer=ATTN, mlp=MLP_MOE, window=4096),),
    activation="swiglu",
    n_experts=8,
    top_k=2,
    capacity_factor=1.25,
    rope_theta=1_000_000.0,
)
