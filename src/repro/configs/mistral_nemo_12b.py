"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407] — dense, 128k ctx.

40L, d_model=5120, 32 heads (GQA kv=8), head_dim=128, d_ff=14336 (SwiGLU),
vocab 131072, full attention.
"""
from repro.configs.base import BlockSpec, ModelConfig, ATTN, MLP_DENSE

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    unit=(BlockSpec(mixer=ATTN, mlp=MLP_DENSE, window=None),),
    activation="swiglu",
    rope_theta=1_000_000.0,
)
