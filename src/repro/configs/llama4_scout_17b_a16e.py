"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE top-1.

48L, d_model=5120, 40 heads (GQA kv=8), d_ff=8192 per expert, vocab 202048,
16 experts top-1. iRoPE pattern: 3 chunked-local (8192) : 1 global-NoPE.
Early-fusion multimodal frontend is a STUB (text tokens only; the vision
tower contributes via n_prefix_embeds=0 here — Scout's text path).
Note: HF Scout interleaves a shared expert; we fold it into the routed
experts (documented deviation, DESIGN.md §9).
"""
from repro.configs.base import BlockSpec, ModelConfig, ATTN, MLP_MOE

_LOCAL = BlockSpec(mixer=ATTN, mlp=MLP_MOE, chunk=8192)
_GLOBAL = BlockSpec(mixer=ATTN, mlp=MLP_MOE, window=None, rope=False)

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    unit=(_LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    activation="swiglu",
    n_experts=16,
    top_k=1,
    capacity_factor=1.25,
    rope_theta=500_000.0,
)
