"""Gemma-3-12B [hf:google/gemma-3 family] — 5 local : 1 global, 128k context.

48L, d_model=3840, 16 heads (GQA kv=8), head_dim=256, d_ff=15360 (GeGLU),
vocab 262144. Local window 1024; every 6th layer global. Unit = 6 layers,
8 repeats.
"""
from repro.configs.base import BlockSpec, ModelConfig, ATTN, MLP_DENSE

_LOCAL = BlockSpec(mixer=ATTN, mlp=MLP_DENSE, window=1024)
_GLOBAL = BlockSpec(mixer=ATTN, mlp=MLP_DENSE, window=None)

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    unit=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    activation="geglu",
    rope_theta=1_000_000.0,
    logits_softcap=30.0,
    tie_embeddings=True,
)
