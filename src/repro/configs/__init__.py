from repro.configs.base import (  # noqa: F401
    ATTN, MLSTM, SLSTM, RGLRU, MLP_NONE, MLP_DENSE, MLP_MOE,
    TRAIN, PREFILL, DECODE,
    BlockSpec, ModelConfig, ShapeConfig, SHAPES, SHAPE_ORDER,
    shape_applicable, input_specs, param_count, active_param_count,
    model_flops,
)
from repro.configs.registry import ARCH_IDS, get_config, all_configs  # noqa: F401
