"""xLSTM-1.3B [arXiv:2405.04517] — sLSTM + mLSTM blocks, no separate FFN.

48 blocks, d_model=2048, 4 heads, vocab 50304, d_ff=0 (the mLSTM block embeds
a 2x up-projection; the sLSTM block carries a 4/3 GLU ff). Pattern: xLSTM[7:1]
— 7 mLSTM : 1 sLSTM per unit, 6 unit repeats.
"""
from repro.configs.base import BlockSpec, ModelConfig, MLSTM, SLSTM, MLP_NONE

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    unit=tuple([BlockSpec(mixer=MLSTM, mlp=MLP_NONE)] * 7
               + [BlockSpec(mixer=SLSTM, mlp=MLP_NONE)]),
    activation="gelu",
    mlstm_proj_factor=2.0,
    mlstm_qk_blocksize=4,
    slstm_ff_factor=4.0 / 3.0,
    tie_embeddings=False,
)
