"""Nemotron-4-340B [arXiv:2402.16819] — dense GQA + squared-ReLU MLP.

96L, d_model=18432, 96 heads (GQA kv=8), d_ff=73728, vocab 256000.
The capacity-planning flagship: 340B params cannot fit v5e HBM without the
WSMC planner choosing FSDP + factored/low-precision optimizer state.
"""
from repro.configs.base import BlockSpec, ModelConfig, ATTN, MLP_DENSE

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    unit=(BlockSpec(mixer=ATTN, mlp=MLP_DENSE, window=None),),
    activation="squared_relu",
    rope_theta=10_000.0,
)
