"""H2O-Danube-1.8B [arXiv:2401.16818; hf] — llama+mistral mix with SWA.

24L, d_model=2560, 32 heads (GQA kv=8), d_ff=6912 (SwiGLU), vocab 32000,
sliding-window attention (mistral-style, window 4096).
"""
from repro.configs.base import BlockSpec, ModelConfig, ATTN, MLP_DENSE

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    unit=(BlockSpec(mixer=ATTN, mlp=MLP_DENSE, window=4096),),
    activation="swiglu",
)
