"""InternVL2-26B [arXiv:2404.16821; hf] — InternViT-6B + InternLM2-20B.

Backbone (this config): 48L, d_model=6144, 48 heads (GQA kv=8), head_dim=128,
d_ff=16384 (SwiGLU), vocab 92553. The InternViT vision tower is a STUB per
the assignment: input_specs() provides 1024 precomputed patch embeddings
(b, 1024, d_model) which the model prepends to the token embeddings.
"""
from repro.configs.base import BlockSpec, ModelConfig, ATTN, MLP_DENSE

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    unit=(BlockSpec(mixer=ATTN, mlp=MLP_DENSE, window=None),),
    activation="swiglu",
    n_prefix_embeds=1024,
)
