"""Architecture registry: --arch <id> lookup for every driver."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "internvl2-26b": "repro.configs.internvl2_26b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {', '.join(ARCH_IDS)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
