"""Config system: model architecture descriptions and workload shapes.

A model is described as a *pattern* of heterogeneous blocks (attention /
mLSTM / sLSTM / RG-LRU, dense-MLP / MoE) repeated over depth, mirroring how
the assigned architectures interleave block kinds (e.g. gemma3's 5 local : 1
global, recurrentgemma's (rec, rec, attn) unit). The repeated *unit* is the
lax.scan step; a `tail` covers non-divisible depths.

Configs are pure data — no jax imports at module scope beyond dtypes — so
importing a config never touches device state (required for the dry-run's
XLA_FLAGS ordering).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block descriptors
# ---------------------------------------------------------------------------

# Mixer kinds (sequence-mixing half of a block).
ATTN = "attn"          # softmax attention (GQA); window=None => global
MLSTM = "mlstm"        # xLSTM matrix-LSTM (outer-product state)
SLSTM = "slstm"        # xLSTM scalar-LSTM
RGLRU = "rglru"        # RecurrentGemma real-gated linear recurrent unit

# MLP kinds (channel-mixing half). NONE for xLSTM blocks (mixer includes it).
MLP_NONE = "none"
MLP_DENSE = "dense"
MLP_MOE = "moe"


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer block: a sequence mixer + a channel mixer."""
    mixer: str = ATTN
    mlp: str = MLP_DENSE
    # Attention locality: None => global; int => sliding window radius.
    window: Optional[int] = None
    # Chunked ("block-local") attention à la Llama-4 iRoPE: tokens attend only
    # within their chunk of size `chunk`. Mutually exclusive with window.
    chunk: Optional[int] = None
    # Use rotary embeddings for this block (global NoPE layers in llama4 skip).
    rope: bool = True

    @property
    def is_attn(self) -> bool:
        return self.mixer == ATTN

    @property
    def is_recurrent(self) -> bool:
        return self.mixer in (MLSTM, SLSTM, RGLRU)

    def cache_len(self, seq_len: int) -> int:
        """KV cache length this block needs at `seq_len` context (decode)."""
        if not self.is_attn:
            return 0  # recurrent state instead
        if self.window is not None:
            return min(self.window, seq_len)
        if self.chunk is not None:
            return min(self.chunk, seq_len)
        return seq_len


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # Depth pattern: `unit` repeated `repeats` times then `tail` blocks.
    unit: Tuple[BlockSpec, ...] = ()
    tail: Tuple[BlockSpec, ...] = ()
    head_dim: Optional[int] = None   # default d_model // n_heads
    activation: str = "swiglu"       # swiglu | geglu | gelu | squared_relu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # xLSTM
    mlstm_proj_factor: float = 2.0
    mlstm_qk_blocksize: int = 4      # block-diagonal q/k projection block size
    mlstm_conv_width: int = 4
    slstm_ff_factor: float = 4.0 / 3.0
    # RG-LRU
    lru_width: Optional[int] = None
    conv_width: int = 4
    # Embedding / misc
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    logits_softcap: Optional[float] = None
    # Modality frontend stubs (DESIGN.md §4): number of prepended embedding
    # positions provided pre-computed by input_specs() (vlm patches / audio
    # frames). 0 for text-only.
    n_prefix_embeds: int = 0
    param_dtype: str = "bfloat16"

    def __post_init__(self):
        n_pattern = len(self.unit) * self.repeats + len(self.tail)
        if self.unit and n_pattern != self.n_layers:
            raise ValueError(
                f"{self.name}: pattern covers {n_pattern} layers != n_layers={self.n_layers}")

    @property
    def repeats(self) -> int:
        if not self.unit:
            return 0
        return (self.n_layers - len(self.tail)) // len(self.unit)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded to a multiple of 16 so the embedding/head shard
        evenly on the model axis (Megatron-style padding; e.g. InternVL2's
        92553 -> 92560). Logits over pad columns are ordinary (never-target)
        classes."""
        return -(-self.vocab_size // 16) * 16

    @property
    def slstm_ff_dim(self) -> int:
        """sLSTM GLU width rounded to a multiple of 16 (shardable)."""
        return -(-int(self.slstm_ff_factor * self.d_model) // 16) * 16

    @property
    def q_group(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def blocks(self) -> Tuple[BlockSpec, ...]:
        """The full depth-ordered block list."""
        return tuple(self.unit) * self.repeats + tuple(self.tail)

    def has_subquadratic_context(self) -> bool:
        """True if no block needs a full-length quadratic attention prefill.

        Decode over a long cache is linear per step even for global layers, but
        the assignment spec mandates skipping long_500k for *pure* full
        attention archs: those where every attention block is global.
        """
        attn_blocks = [b for b in self.blocks() if b.is_attn]
        if not attn_blocks:
            return True
        return any(b.window is not None or b.chunk is not None for b in attn_blocks)

    # -- reduced config for CPU smoke tests -------------------------------
    def reduced(self) -> "ModelConfig":
        """Same family/pattern, tiny dims: one unit repeat, small widths."""
        tail = self.tail[: min(len(self.tail), 2)]
        n_layers = len(self.unit) + len(tail)
        scale = lambda v, lo, hi: max(lo, min(hi, v))
        d_model = 64
        n_heads = scale(min(self.n_heads, 4), 2, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        small_unit = tuple(
            dataclasses.replace(b,
                                window=None if b.window is None else 8,
                                chunk=None if b.chunk is None else 8)
            for b in self.unit)
        small_tail = tuple(
            dataclasses.replace(b,
                                window=None if b.window is None else 8,
                                chunk=None if b.chunk is None else 8)
            for b in tail)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=512,
            unit=small_unit,
            tail=small_tail,
            n_experts=0 if self.n_experts == 0 else 4,
            top_k=0 if self.top_k == 0 else min(self.top_k, 2),
            lru_width=None if self.lru_width is None else 64,
            n_prefix_embeds=0 if self.n_prefix_embeds == 0 else 4,
        )


def depth_variant(cfg: ModelConfig, n_units: int) -> "ModelConfig":
    """cfg with the unit pattern repeated `n_units` times — the depth-1/2
    probes the roofline extrapolates from."""
    return dataclasses.replace(
        cfg, n_layers=n_units * len(cfg.unit) + len(cfg.tail))


# ---------------------------------------------------------------------------
# Workload shapes (assigned): every arch × each of these = one dry-run cell
# ---------------------------------------------------------------------------

TRAIN = "train"
PREFILL = "prefill"
DECODE = "decode"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        # Tokens processed per step: decode steps emit one token per sequence.
        return self.global_batch * (1 if self.kind == DECODE else self.seq_len)

    @property
    def context(self) -> int:
        """Context length (cache extent for decode, seq for train/prefill)."""
        return self.seq_len


SHAPES = {
    "train_4k": ShapeConfig("train_4k", TRAIN, 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", PREFILL, 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", DECODE, 32_768, 128),
    "long_500k": ShapeConfig("long_500k", DECODE, 524_288, 1),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason). long_500k skips pure full-attention archs."""
    if shape.name == "long_500k" and not cfg.has_subquadratic_context():
        return False, ("skip: pure full-attention arch — long_500k requires "
                       "sub-quadratic attention (DESIGN.md §4)")
    return True, ""


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs; no allocation) — dry-run / AOT entry point
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract input pytree for the step function of (cfg, shape).

    Returns a dict of jax.ShapeDtypeStruct. Modality frontends are stubs: for
    vlm/audio archs the spec includes precomputed prefix embeddings.
    Caches for decode are built by the runtime (they mirror params layout).
    """
    import jax
    import jax.numpy as jnp

    b = shape.global_batch
    # Modality-stub archs: the prefix embeddings occupy the first
    # n_prefix_embeds positions of the context; text tokens fill the rest.
    text_len = shape.seq_len - (cfg.n_prefix_embeds if shape.kind != DECODE else 0)
    if shape.kind == TRAIN:
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, text_len), jnp.int32),
            "targets": jax.ShapeDtypeStruct((b, text_len), jnp.int32),
        }
    elif shape.kind == PREFILL:
        specs = {"tokens": jax.ShapeDtypeStruct((b, text_len), jnp.int32)}
    else:  # DECODE: one new token against a cache of shape.seq_len
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "positions": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
    if cfg.n_prefix_embeds and shape.kind != DECODE:
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
    return specs


# ---------------------------------------------------------------------------
# Analytic parameter count (closed-form; cross-checked by eval_shape in tests)
# ---------------------------------------------------------------------------

def block_param_count(cfg: ModelConfig, blk: BlockSpec) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    n = 0
    if blk.mixer == ATTN:
        q = cfg.n_heads * hd
        kv = cfg.n_kv_heads * hd
        n += d * q + 2 * d * kv + q * d          # q, k, v, o
        n += d                                    # pre-norm
    elif blk.mixer == MLSTM:
        inner = int(cfg.mlstm_proj_factor * d)
        n += d * 2 * inner                        # up (x and z-gate branches)
        n += cfg.mlstm_conv_width * inner         # causal depthwise conv
        n += 2 * inner * cfg.mlstm_qk_blocksize   # block-diagonal q, k
        n += 2 * (inner * cfg.n_heads + cfg.n_heads)   # i, f gate projections
        n += inner * d                            # down
        n += d + inner                            # pre-norm + head groupnorm
    elif blk.mixer == SLSTM:
        hd_ = d // cfg.n_heads
        n += 4 * d * d                            # input gates (z, i, f, o)
        n += cfg.n_heads * hd_ * 4 * hd_          # block-diag recurrent gates
        n += 4 * d                                # biases
        ff = cfg.slstm_ff_dim
        n += d * 2 * ff + ff * d                  # GLU ff
        n += 2 * d                                # norms
    elif blk.mixer == RGLRU:
        w = cfg.lru_width or d
        n += d * 2 * w                            # x/gate in-projections
        n += cfg.conv_width * w                   # depthwise conv
        n += 2 * w                                # recurrence + input gates (diag)
        n += w * d                                # out projection
        n += d
    if blk.mlp == MLP_DENSE:
        mult = 2 if cfg.activation in ("swiglu", "geglu") else 1
        n += d * mult * cfg.d_ff + cfg.d_ff * d
        n += d
    elif blk.mlp == MLP_MOE:
        mult = 2 if cfg.activation in ("swiglu", "geglu") else 1
        n += cfg.n_experts * (d * mult * cfg.d_ff + cfg.d_ff * d)
        n += d * cfg.n_experts                    # router
        n += d
    return n


def param_count(cfg: ModelConfig) -> int:
    n = cfg.padded_vocab_size * cfg.d_model       # embedding
    for blk in cfg.blocks():
        n += block_param_count(cfg, blk)
    n += cfg.d_model                              # final norm
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.padded_vocab_size  # lm head
    return n


def active_param_count(cfg: ModelConfig) -> int:
    """Per-token active params (MoE: only top_k experts count)."""
    if not cfg.is_moe:
        return param_count(cfg)
    n = param_count(cfg)
    mult = 2 if cfg.activation in ("swiglu", "geglu") else 1
    per_expert = cfg.d_model * mult * cfg.d_ff + cfg.d_ff * cfg.d_model
    n_moe_blocks = sum(1 for b in cfg.blocks() if b.mlp == MLP_MOE)
    n -= n_moe_blocks * (cfg.n_experts - cfg.top_k) * per_expert
    return n


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N_active·D (+ attention context term).

    The input-embedding table does no matmul work (gather), so it is
    excluded; tied embeddings keep the table once (it is the head matmul).
    """
    n_active = active_param_count(cfg)
    if not cfg.tie_embeddings:
        n_active -= cfg.padded_vocab_size * cfg.d_model  # lookup-only table
    mult = 3.0 if shape.kind == TRAIN else 1.0           # fwd + 2x bwd
    flops = 2.0 * n_active * shape.tokens * mult
    # Attention score/value FLOPs (not in 2N·D): 4·kv_per_q·H·hd per token.
    hd = cfg.resolved_head_dim
    s = shape.seq_len
    for blk in cfg.blocks():
        if not blk.is_attn:
            continue
        if shape.kind == DECODE:
            kv_per_q = blk.cache_len(shape.context)
        else:
            w = blk.window if blk.window is not None else blk.chunk
            if w is None or w >= s:
                kv_per_q = (s + 1) / 2.0                 # plain causal
            elif blk.chunk is not None:
                kv_per_q = (w + 1) / 2.0                 # causal per chunk
            else:                                        # sliding window
                kv_per_q = (w * (w + 1) / 2.0 + (s - w) * w) / s
        flops += (4.0 * kv_per_q * cfg.n_heads * hd) * shape.tokens * mult
    return flops
