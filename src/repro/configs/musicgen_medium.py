"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.

48L, d_model=1536, 24 heads (MHA: kv=24), d_ff=6144 (GELU), vocab 2048
(EnCodec codebook). Modality frontend (EnCodec encoder + delay-pattern
interleave) is a STUB per the assignment: input_specs() provides the token
stream directly; the backbone is a standard full-attention decoder.
"""
from repro.configs.base import BlockSpec, ModelConfig, ATTN, MLP_DENSE

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    unit=(BlockSpec(mixer=ATTN, mlp=MLP_DENSE, window=None),),
    activation="gelu",
    # Audio frontend stub: 0 prefix embeds — EnCodec tokens ARE the stream.
    n_prefix_embeds=0,
)
