"""TPU v5e hardware constants used by the roofline model and the WSMC planner.

The container runs on CPU; TPU v5e is the *target* platform. All capacity
planning and roofline terms are expressed against these constants.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    hbm_bytes: int              # HBM capacity per chip
    hbm_bw: float               # bytes/s per chip
    ici_link_bw: float          # bytes/s per ICI link (one direction)
    ici_links_per_chip: int     # links on the 2-D torus
    vmem_bytes: int             # VMEM per core (Pallas tiling budget)
    # Runtime reserve: XLA runtime + infeed/outfeed scratch. Plays the role of
    # the paper's "Reserved Memory" (RM, 300MB in Spark's default).
    reserved_bytes: int = 300 * 1024 * 1024


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    ici_links_per_chip=4,
    vmem_bytes=128 * 1024 * 1024,
)

# The paper's Eq. 11 headroom factor: capacity = spark_mem * 4/3 + RM.
# We keep 4/3 as the HBM fragmentation / runtime-scratch margin.
CAPACITY_HEADROOM = 4.0 / 3.0


def capacity_from_requirement(resident_bytes: float, transient_bytes: float,
                              hw: HardwareSpec = TPU_V5E) -> float:
    """Paper Eq. 11: Mem_cap = Mem_spark * 4/3 + RM, per device."""
    return (resident_bytes + transient_bytes) * CAPACITY_HEADROOM + hw.reserved_bytes
