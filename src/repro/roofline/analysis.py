"""Three-term roofline from compiled artifacts (no wall clock — DESIGN.md §7).

cost_analysis() counts a lax.scan body once (measured in-container), so the
layer-stack cost comes from *depth extrapolation*: lower the model unrolled
at depth-1 and depth-2 (same width, shapes, mesh, shardings), then

    unit_cost   = cost(depth2) - cost(depth1)
    outside     = cost(depth1) - unit_cost
    total       = outside + repeats × unit_cost

All quantities are per-device (the HLO text is the partitioned SPMD module).
Terms (TPU v5e): T_comp = FLOPs/197e12, T_mem = bytes/819e9,
T_coll = wire_bytes/50e9. Roofline time = max of the three; the dominant
term is the §Perf hillclimbing target.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro import hw as HW
from repro.configs.base import (ATTN, DECODE, MLSTM, RGLRU, SLSTM, TRAIN,
                                ModelConfig, ShapeConfig, model_flops)
from repro.roofline import hlo as HLO


@dataclasses.dataclass
class ComponentCost:
    flops: float
    bytes_accessed: float
    wire_bytes: float
    collectives: Dict[str, float]


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    t_comp: float
    t_mem: float
    t_coll: float
    model_flops_total: float
    collectives: Dict[str, float]
    t_mem_analytic: float = 0.0    # perfect-fusion lower bound (TPU model)

    @property
    def t_roofline(self) -> float:
        return max(self.t_comp, self.t_mem, self.t_coll)

    @property
    def t_roofline_analytic(self) -> float:
        """Roofline with the perfect-fusion memory bound (TPU-optimistic)."""
        mem = self.t_mem_analytic or self.t_mem
        return max(self.t_comp, mem, self.t_coll)

    @property
    def mfu_bound_analytic(self) -> float:
        per_chip_model = self.model_flops_total / self.n_chips
        return (per_chip_model / HW.TPU_V5E.peak_flops_bf16) / \
            max(self.t_roofline_analytic, 1e-30)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_mem,
                 "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is 'useful'
        (catches remat recompute, masked-block waste, dispatch overhead)."""
        hlo_total = self.flops_per_chip * self.n_chips
        return self.model_flops_total / max(hlo_total, 1.0)

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound: the score if the
        chip hits peak on the dominant term."""
        per_chip_model = self.model_flops_total / self.n_chips
        return (per_chip_model / HW.TPU_V5E.peak_flops_bf16) / \
            max(self.t_roofline, 1e-30)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_roofline=self.t_roofline, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 mfu_bound=self.mfu_bound,
                 t_roofline_analytic=self.t_roofline_analytic,
                 mfu_bound_analytic=self.mfu_bound_analytic)
        return d


def cost_dict(compiled) -> dict:
    """compiled.cost_analysis() across JAX versions: newer releases return
    the dict directly, older ones a one-element list of dicts."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def component_cost(compiled) -> ComponentCost:
    ca = cost_dict(compiled)
    ops = HLO.parse_collectives(compiled.as_text())
    summary = HLO.collective_summary(ops)
    return ComponentCost(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        wire_bytes=float(summary.get("total_wire_bytes", 0.0)),
        collectives={k: v for k, v in summary.items()
                     if k not in ("total_wire_bytes", "n_ops")},
    )


def extrapolate(depth1: ComponentCost, depth2: ComponentCost,
                repeats: int) -> ComponentCost:
    def comb(a1, a2):
        unit = max(a2 - a1, 0.0)
        outside = max(a1 - unit, 0.0)
        return outside + repeats * unit

    coll = {}
    for k in set(depth1.collectives) | set(depth2.collectives):
        coll[k] = comb(depth1.collectives.get(k, 0.0),
                       depth2.collectives.get(k, 0.0))
    return ComponentCost(
        flops=comb(depth1.flops, depth2.flops),
        bytes_accessed=comb(depth1.bytes_accessed, depth2.bytes_accessed),
        # wire must be the sum of per-kind compositions: composing the
        # clamped totals misses kind-mix shifts between depths
        wire_bytes=sum(coll.values()),
        collectives=coll,
    )


def scan_corrections(cfg: ModelConfig, shape: ShapeConfig, n_chips: int,
                     q_block: int = 512, mlstm_chunk: int = 128
                     ) -> ComponentCost:
    """Analytic FLOPs/bytes for the *inner* scans cost_analysis counts once.

    The depth-1/2 extrapolation fixes the layer scan, but the blocked
    attention (lax.map over q blocks × lax.scan over kv blocks), the mLSTM
    chunk scan, the sLSTM time scan and the RG-LRU associative scan are all
    single-counted too. Their work is exactly computable from shapes, so the
    roofline adds it analytically (per chip; batch/head sharding divides by
    n_chips). The ≤(1/n_blocks) double-count of the one lowered block is
    ignored (bounded by 2% at 4k, less at 32k). No collectives live inside
    these scans (batch/head-sharded compute), so only FLOPs/bytes correct.
    """
    if shape.kind == DECODE:
        return ComponentCost(0.0, 0.0, 0.0, {})   # no inner scans in decode
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    mult = 3.0 if shape.kind == TRAIN else 1.0    # fwd + bwd(2x)
    # attention-with-remat recomputes the forward once more in backward
    remat_mult = 4.0 if shape.kind == TRAIN else 1.0
    B = 2.0                                        # bf16 streams
    flops = bytes_ = 0.0
    for blk in cfg.blocks():
        if blk.mixer == ATTN:
            if blk.window is not None:
                w = min(blk.window, s)
                kv_per_q = (w * (w + 1) / 2 + (s - w) * w) / s if w < s \
                    else (s + 1) / 2
                span_reads = -(-s // q_block) * (min(w, s) + q_block)
            elif blk.chunk is not None:
                c = min(blk.chunk, s)
                kv_per_q = (c + 1) / 2
                span_reads = (s // max(c, 1) or 1) * (c / q_block) * c
            else:
                kv_per_q = (s + 1) / 2
                span_reads = -(-s // q_block) * s   # every q block reads all kv
            flops += 4.0 * b * s * kv_per_q * H * hd * mult
            bytes_ += b * (span_reads * K * hd * 2 * B * remat_mult
                           + s * H * hd * 2 * B * mult)
        elif blk.mixer == MLSTM:
            inner = int(cfg.mlstm_proj_factor * cfg.d_model)
            dh = inner // cfg.n_heads
            c = mlstm_chunk
            nc = max(s // c, 1)
            per_chunk = (2 * c * c * (dh + dh)      # qk^T + sw·v
                         + 4 * c * dh * dh)          # state update + inter
            flops += b * cfg.n_heads * nc * per_chunk * mult
            bytes_ += b * s * (3 * inner + 2 * cfg.n_heads) * B * mult \
                + b * cfg.n_heads * nc * dh * dh * 4.0   # state spills (f32)
        elif blk.mixer == SLSTM:
            d = cfg.d_model
            dh = d // cfg.n_heads
            # recurrent matmul per step + per-step weight re-read (the
            # sequential scan cannot keep R in VMEM across big d)
            flops += b * s * (2 * d * 4 * dh) * mult
            bytes_ += s * (d * 4 * dh) * B * mult + b * s * 8 * d * 4.0
        elif blk.mixer == RGLRU:
            w = cfg.lru_width or cfg.d_model
            import math
            passes = 2 * max(math.ceil(math.log2(max(s, 2))), 1)
            flops += b * s * w * passes * 2 * mult
            bytes_ += b * s * w * passes * 4.0 * mult
    return ComponentCost(flops=flops / n_chips, bytes_accessed=bytes_ / n_chips,
                         wire_bytes=0.0, collectives={})


def analytic_hbm_traffic(cfg: ModelConfig, shape: ShapeConfig, n_chips: int,
                         remat: str = "none", microbatches: int = 1,
                         opt_state_bytes: float = 8.0) -> float:
    """Perfect-fusion HBM traffic lower bound, per chip per step (bytes).

    The CPU-HLO 'bytes accessed' proxy counts every op's operands+outputs
    with no fusion credit (upper bound); this model assumes ideal fusion:
    weights streamed once per pass, activations written once at block
    boundaries + re-read by backward, optimizer state r/w once. Truth on a
    TPU lies between the two — the roofline reports both (DESIGN.md §7).
    """
    from repro.configs.base import param_count
    n_params = param_count(cfg)
    w_bytes = 2.0 * n_params / n_chips                  # bf16, sharded
    toks = shape.tokens
    d = cfg.d_model
    B = 2.0
    # per-token activation bytes saved at block boundaries (write + read):
    saved_per_layer = {"none": 14.0, "dots": 8.0, "full": 2.0}[remat] * d * B
    act = 2.0 * toks * saved_per_layer * cfg.n_layers / n_chips
    passes = {"none": 3.0, "dots": 3.5, "full": 4.0}[remat] \
        if shape.kind == TRAIN else 1.0                  # fwd(+bwd)(+remat)
    total = w_bytes * passes * max(microbatches, 1)
    if shape.kind == TRAIN:
        total += act
        total += n_params * (4.0 + 2.0 * opt_state_bytes) / n_chips  # grads+opt
        vocab_passes = 3.0
    else:
        vocab_passes = 1.0
    total += vocab_passes * toks * cfg.padded_vocab_size * 4.0 / n_chips
    if shape.kind == DECODE:                             # cache read + write
        hd = cfg.resolved_head_dim
        for blk in cfg.blocks():
            if blk.is_attn:
                L = blk.cache_len(shape.context)
                total += (shape.global_batch * L * cfg.n_kv_heads * hd
                          * 2 * B) / n_chips
            elif blk.mixer == MLSTM:
                inner = int(cfg.mlstm_proj_factor * d)
                dh = inner // cfg.n_heads
                total += shape.global_batch * cfg.n_heads * dh * dh * 8.0 \
                    / n_chips
    # inner-scan streams (attention kv re-reads etc.) — shared with the
    # corrections model:
    total += scan_corrections(cfg, shape, n_chips).bytes_accessed
    return total


def apply_corrections(cost: ComponentCost, corr: ComponentCost
                      ) -> ComponentCost:
    return ComponentCost(
        flops=cost.flops + corr.flops,
        bytes_accessed=cost.bytes_accessed + corr.bytes_accessed,
        wire_bytes=cost.wire_bytes + corr.wire_bytes,
        collectives=cost.collectives,
    )


def report(cfg: ModelConfig, shape: ShapeConfig, mesh_name: str,
           n_chips: int, cost: ComponentCost,
           hw: HW.HardwareSpec = HW.TPU_V5E,
           remat: str = "none", microbatches: int = 1) -> RooflineReport:
    analytic = analytic_hbm_traffic(cfg, shape, n_chips, remat=remat,
                                    microbatches=1)
    return RooflineReport(
        arch=cfg.name,
        shape=shape.name,
        mesh=mesh_name,
        n_chips=n_chips,
        flops_per_chip=cost.flops,
        bytes_per_chip=cost.bytes_accessed,
        wire_bytes_per_chip=cost.wire_bytes,
        t_comp=cost.flops / hw.peak_flops_bf16,
        t_mem=cost.bytes_accessed / hw.hbm_bw,
        t_coll=cost.wire_bytes / hw.ici_link_bw,
        model_flops_total=model_flops(cfg, shape),
        collectives=cost.collectives,
        t_mem_analytic=analytic / hw.hbm_bw,
    )
