"""HLO-text collective parser: extract per-device collective payloads and
wire (ICI link) bytes from a compiled module.

cost_analysis() has no collective term, so we parse `compiled.as_text()`
for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, take their result shapes (per-device, since the
text is the partitioned SPMD module), recover group sizes from
replica_groups, and apply ring-algorithm wire factors:

  all-reduce       2·B·(g-1)/g      (reduce-scatter + all-gather phases)
  all-gather       B_result·(g-1)/g
  reduce-scatter   B_operand·(g-1)/g
  all-to-all       B·(g-1)/g
  collective-permute B
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_OP_RE = re.compile(
    r"(?P<dtype>[a-z0-9]+)\[(?P<shape>[0-9,]*)\][^=]*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<rest>.*)")

_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    dtype: str
    shape: tuple
    result_bytes: int
    group_size: int
    wire_bytes: float      # per-chip ICI send volume (ring model)


def _tuple_shapes(line: str) -> List[tuple]:
    """Some collectives return tuples: (bf16[..], bf16[..]) all-gather(...)"""
    out = []
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", line.split("=")[1]
                         .split("all-")[0] if "=" in line else line):
        out.append((m.group(1), m.group(2)))
    return out


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not line.startswith("%") and not line.startswith("ROOT"):
            continue
        m = _OP_RE.search(line)
        if m is None:
            continue
        # skip -start/-done duplicates (count the -start only)
        if re.search(r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)-done", line):
            continue
        op = m.group("op")
        dtype = m.group("dtype")
        shape = tuple(int(x) for x in m.group("shape").split(",") if x)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        # XLA:CPU's all-reduce-promotion pass upcasts bf16 reductions to f32
        # (to_apply=%..._promoted). TPU reduces bf16 natively — count the
        # wire at the unpromoted width.
        if dtype == "f32" and "_promoted" in line:
            nbytes = 2
        result_bytes = nbytes
        for d in shape:
            result_bytes *= d

        g = 1
        gm = _GROUPS_BRACKET_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            if gb:
                g = len([x for x in gb.group(1).split(",") if x.strip()])
        if op == "collective-permute":
            g = 2  # pairwise

        ring = (g - 1) / g if g > 1 else 0.0
        if op == "all-reduce":
            wire = 2.0 * result_bytes * ring
        elif op == "all-gather":
            wire = result_bytes * ring
        elif op == "reduce-scatter":
            wire = result_bytes * g * ring   # operand = result × g
        elif op == "all-to-all":
            wire = result_bytes * ring
        else:  # collective-permute
            wire = float(result_bytes)
        ops.append(CollectiveOp(kind=op, dtype=dtype, shape=shape,
                                result_bytes=result_bytes, group_size=g,
                                wire_bytes=wire))
    return ops


def collective_summary(ops: List[CollectiveOp]) -> Dict[str, float]:
    summary: Dict[str, float] = {}
    for op in ops:
        summary[op.kind] = summary.get(op.kind, 0.0) + op.wire_bytes
    summary["total_wire_bytes"] = sum(summary.values())
    summary["n_ops"] = float(len(ops))
    return summary
