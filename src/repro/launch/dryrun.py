import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything below is ordinary.
"""Multi-pod dry-run driver.

For every (architecture × input shape) cell:
  1. offline/online WSMC phases pick the memory plan (knowledge base),
  2. the full-depth step is measured on the single-pod (16,16) mesh AND
     the multi-pod (2,16,16) mesh — under the default compile backend,
     memory_analysis() proves the per-device footprint and the multi-pod
     pass proves the "pod" axis shards; under --backend simulate the same
     sweep runs compile-free in seconds via the analytical measurer,
  3. depth-1/2 unrolled variants provide scan-corrected roofline terms
     (single-pod, compile backend only — §Roofline).

Artifacts: one JSON per cell under --out, plus a summary table.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out artifacts/dryrun [--no-roofline] [--kb artifacts/kb.json] \
      [--backend compile|simulate] [--profile-cache artifacts/profiles.json]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict

from repro.configs import (ARCH_IDS, SHAPES, SHAPE_ORDER, get_config,
                           shape_applicable)
from repro.configs.base import ShapeConfig, depth_variant  # noqa: F401 — depth_variant re-exported for back-compat
from repro.core import measure as MM
from repro.core import profiler as PF
from repro.core.classifier import Classification, Category
from repro.launch import compile as LC
from repro.launch.mesh import make_production_mesh
from repro.models.model import ModelSettings
from repro.roofline import analysis as RA
from repro.search import execplan as XP
from repro.search import strategies as ST

# Mesh shapes the driver sweeps; under --backend simulate no jax Mesh (and
# no fake-device process) is ever constructed — the dicts are enough.
MESH_SHAPES = {
    "single": {"data": 16, "model": 16},
    "multi": {"pod": 2, "data": 16, "model": 16},
}


def classification_for(cfg, shape, measurer: MM.MemoryMeasurer,
                       kb: Dict) -> Classification:
    key = f"{cfg.name}::{shape.kind}"
    if key in kb:
        e = kb[key]
        return Classification(category=Category(e["category"]),
                              alpha=e["alpha"], inc=e["inc"],
                              slope=e["slope"], intercept=e["intercept"])
    cls = PF.classify_workload(cfg, shape, None, n_points=3, base_seq=512,
                               measurer=measurer)
    kb[key] = {"category": cls.category.value, "alpha": cls.alpha,
               "inc": cls.inc, "slope": cls.slope,
               "intercept": cls.intercept, "factor": cls.factor}
    return cls


def paper_faithful_settings(scan_layers: bool = True) -> ModelSettings:
    """Disable the beyond-paper defaults (EXPERIMENTS §Perf) for baseline
    cells: replicated GQA sharding + gather embedding."""
    from repro.models.attention import AttnSettings
    return ModelSettings(scan_layers=scan_layers, embed_onehot=False,
                         attn=AttnSettings(repeat_kv=False))


def run_cell(arch: str, shape: ShapeConfig,
             measurers: Dict[str, MM.MemoryMeasurer],
             kb: Dict, do_roofline: bool = True,
             plan_override=None, settings_fn=ModelSettings,
             strategy: str = "fastest", *, auto_mesh: bool = False,
             backend: str = "simulate", cache=None,
             max_devices: int = 256) -> dict:
    cfg = get_config(arch)
    result = {"arch": arch, "shape": shape.name, "kind": shape.kind}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        result["status"] = "skipped"
        result["reason"] = reason
        return result

    # The single-pod measurer anchors profiling/roofline; a multi-only
    # sweep (--mesh multi) profiles on the multi-pod mesh instead.
    single_m = measurers.get("single") or next(iter(measurers.values()))
    result["backend"] = backend if auto_mesh else single_m.backend
    # --- WSMC online phase (profiling ladder on the single-pod mesh) ----
    t0 = time.time()
    cls = classification_for(cfg, shape, single_m, kb)
    plan = plan_override
    if auto_mesh:
        # plan the mesh, then build it: the measurement target IS the
        # planned mesh (pipe included), not a CLI-fixed one
        sim = (single_m if single_m.backend == "simulate"
               else MM.SimulatedMeasurer(single_m.mesh_shape))
        if backend == "compile":
            # the planned mesh must be buildable on this host's (fake)
            # devices, not just within the abstract budget
            import jax
            max_devices = min(max_devices, len(jax.devices()))
        eplan = XP.plan_execution(cfg, shape, cls, n_devices=max_devices,
                                  strategy=strategy, measurer=sim,
                                  factors=PF.calibrated_factors(kb))
        plan = eplan.plan
        result["execution_plan"] = {
            "mesh": eplan.mesh_shape, "schedule": eplan.schedule,
            "ep": eplan.ep, "plan": dataclasses.asdict(eplan.plan),
            "policy": eplan.policy, "n_devices": eplan.n_devices,
            "strategy": strategy,
        }
        print(f"[{arch} × {shape.name}] planned: {eplan.describe()}",
              flush=True)
        if backend == "simulate":
            planned_m = MM.SimulatedMeasurer(eplan.mesh_shape, cache=cache,
                                             ep=eplan.ep)
        else:
            mesh, _ = eplan.build()
            planned_m = MM.CompileMeasurer(mesh, cache=cache)
        measurers = {"planned": planned_m}
    elif plan is None:
        factors = PF.calibrated_factors(kb)
        decision = ST.plan_for(cfg, shape, cls, single_m.mesh_shape,
                               strategy=strategy, measurer=single_m,
                               factors=factors)
        plan = decision.plan
        result["wsmc"] = {
            "category": cls.category.value,
            "alpha": round(cls.alpha, 3),
            "inc": round(cls.inc, 3),
            "plan": dataclasses.asdict(plan),
            "policy": decision.policy,
            "strategy": strategy,
            "considered": decision.considered,
            "measured": decision.measured,
        }
        if decision.prediction is not None:
            result["wsmc"]["pred_capacity_bytes"] = \
                decision.prediction.capacity_bytes
            result["wsmc"]["pred_fits"] = decision.prediction.fits
        if decision.peak_bytes is not None:
            result["wsmc"]["verified_peak_bytes"] = decision.peak_bytes
    result["profile_s"] = round(time.time() - t0, 1)

    # --- full-depth measurement on each mesh ----------------------------
    for mesh_name, measurer in measurers.items():
        t0 = time.time()
        # re-plan per mesh: microbatch divisibility depends on the dp size
        # (auto mode already planned plan + mesh together)
        if plan_override is None and not auto_mesh:
            mesh_plan = ST.plan_for(cfg, shape, cls, measurer.mesh_shape,
                                    strategy=strategy, measurer=measurer,
                                    factors=PF.calibrated_factors(kb)).plan
        else:
            mesh_plan = plan
        st = settings_fn(scan_layers=True)
        prof = measurer.measure(cfg, shape, mesh_plan, settings=st)
        entry = {
            "argument_bytes": int(prof.argument_bytes),
            "output_bytes": int(prof.output_bytes),
            "temp_bytes": int(prof.transient_bytes),
            "peak_static_bytes": int(prof.peak_bytes),
            "measure_s": round(time.time() - t0, 1),
            "n_devices": int(MM.n_devices_of(measurer.mesh_shape)),
            "alpha_full": round(prof.alpha, 3),
        }
        print(f"[{arch} × {shape.name} × {mesh_name}] "
              f"{measurer.backend} measure: args={entry['argument_bytes']} "
              f"temp={entry['temp_bytes']} out={entry['output_bytes']}",
              flush=True)
        if mesh_name == "single" and measurer.last_compiled is not None:
            # raw HLO flops only exist under the compile backend (and only
            # when the profile wasn't served from the cache)
            ca = RA.cost_dict(measurer.last_compiled)
            print(f"[{arch} × {shape.name} × {mesh_name}] cost_analysis "
                  f"(scan counts body once): flops={ca.get('flops', 0):.3e}",
                  flush=True)
            entry["raw_cost_flops"] = float(ca.get("flops", 0.0))
        measurer.last_compiled = None
        result[f"mesh_{mesh_name}"] = entry

    # --- roofline (depth-extrapolated, single-pod, compile backend) ------
    single = (measurers["single"].mesh
              if "single" in measurers
              and measurers["single"].backend == "compile" else None)
    if do_roofline and single is not None:
        t0 = time.time()
        # microbatches=1: the microbatch loop is a lax.scan whose body
        # cost_analysis counts once; the per-step cost equals the full-batch
        # single-micro cost, so lower that directly.
        rplan = dataclasses.replace(plan, microbatches=1)
        costs = []
        for n_units in (1, 2):
            dcfg = depth_variant(cfg, n_units)
            strategy = PF.strategy_for(dcfg, rplan, single)
            st = settings_fn(scan_layers=False)
            dt = PF._tcfg_for(rplan, settings=st)
            bundle = LC.build(dcfg, shape, single, strategy=strategy,
                              tcfg=dt, settings=st)
            costs.append(RA.component_cost(bundle.compile()))
        total = RA.extrapolate(costs[0], costs[1], cfg.repeats)
        total = RA.apply_corrections(
            total, RA.scan_corrections(cfg, shape, single.devices.size))
        rep = RA.report(cfg, shape, "single", single.devices.size, total,
                        remat=rplan.remat)
        result["roofline"] = rep.to_dict()
        result["roofline"]["analysis_s"] = round(time.time() - t0, 1)

    result["status"] = "ok"
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both", "auto"],
                    help="'auto' = plan the mesh per cell (mesh_space "
                         "search) and measure on the planned mesh")
    ap.add_argument("--max-devices", type=int, default=256,
                    help="device budget for --mesh auto planning")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--kb", default="artifacts/kb.json")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--paper-faithful", action="store_true",
                    help="disable the beyond-paper default optimizations "
                         "(baseline reproduction cells)")
    ap.add_argument("--backend", default="compile",
                    choices=["compile", "simulate"],
                    help="memory-measurement backend: 'compile' = XLA "
                         "memory_analysis() ground truth (slow), 'simulate' "
                         "= closed-form analytical model (zero compiles)")
    ap.add_argument("--strategy", default="fastest",
                    choices=list(ST.CLI_STRATEGIES),
                    help="plan-search strategy: 'fastest' = the paper's "
                         "predicted walk, 'staged' = simulator-screened "
                         "top-k verified on --backend, 'exhaustive' = "
                         "verify every candidate, 'greedy' = coordinate "
                         "hillclimb")
    ap.add_argument("--profile-cache", default=None,
                    help="path of the on-disk MemoryProfile cache (keyed by "
                         "arch × shape × plan × mesh × backend)")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPE_ORDER) if args.shape == "all" else args.shape.split(",")

    cache = MM.ProfileCache(args.profile_cache) if args.profile_cache else None
    measurers = {}
    if args.mesh == "auto":
        # classification screen: always the compile-free simulator; the
        # measurement mesh is planned per cell inside run_cell
        measurers["screen"] = MM.SimulatedMeasurer(MESH_SHAPES["single"],
                                                   cache=cache)
    for name in ("single", "multi"):
        if args.mesh not in (name, "both"):
            continue
        if args.backend == "compile":
            mesh = make_production_mesh(multi_pod=(name == "multi"))
        else:
            mesh = MESH_SHAPES[name]     # no jax mesh needed to simulate
        measurers[name] = MM.measurer_for(args.backend, mesh, cache=cache)

    os.makedirs(args.out, exist_ok=True)
    kb = {}
    if os.path.exists(args.kb):
        kb = PF.load_knowledge_base(args.kb)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            shape = SHAPES[shape_name]
            cell_path = os.path.join(args.out, f"{arch}__{shape_name}.json")
            if os.path.exists(cell_path):
                with open(cell_path) as f:
                    prev = json.load(f)
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[cached] {arch} × {shape_name}:"
                          f" {prev['status']}", flush=True)
                    n_ok += prev["status"] == "ok"
                    n_skip += prev["status"] == "skipped"
                    continue
            t0 = time.time()
            try:
                settings_fn = (paper_faithful_settings if args.paper_faithful
                               else ModelSettings)
                result = run_cell(arch, shape, measurers, kb,
                                  do_roofline=not args.no_roofline,
                                  settings_fn=settings_fn,
                                  strategy=args.strategy,
                                  auto_mesh=args.mesh == "auto",
                                  backend=args.backend, cache=cache,
                                  max_devices=args.max_devices)
            except Exception as e:  # noqa: BLE001 — record and continue
                result = {"arch": arch, "shape": shape_name,
                          "status": "failed", "error": str(e),
                          "traceback": traceback.format_exc()}
            result["total_s"] = round(time.time() - t0, 1)
            with open(cell_path, "w") as f:
                json.dump(result, f, indent=2)
            PF.save_knowledge_base(args.kb, kb)
            st = result["status"]
            n_ok += st == "ok"
            n_skip += st == "skipped"
            n_fail += st == "failed"
            print(f"[{st}] {arch} × {shape_name} ({result['total_s']}s)",
                  flush=True)
            if st == "failed":
                print(result["error"], flush=True)

    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {n_fail} failed",
          flush=True)
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
