"""Generate EXPERIMENTS.md from artifacts (dryrun/, dryrun_opt/, hillclimb/).

  PYTHONPATH=src python -m repro.launch.report_experiments > EXPERIMENTS.md
"""
from __future__ import annotations

import json
import os

from repro.launch.summarize import dryrun_table, load_cells, roofline_table

GB = 1024.0**3

HEADER = """# EXPERIMENTS — WSMC-JAX

Paper: *A Workload-Specific Memory Capacity Configuration Approach for
In-Memory Data Analytic Platforms* (Liang, Chang, Su 2017). Mapping:
DESIGN.md §2. Container is CPU-only; TPU v5e is the target
(197 TFLOP/s bf16, 16 GiB HBM @ 819 GB/s, ~50 GB/s/link ICI). All
dry-run/roofline numbers come from AOT `.lower().compile()` artifacts on the
production meshes — `(16,16)=("data","model")` and
`(2,16,16)=("pod","data","model")` over 512 fake host devices.

## Methodology notes (read first)

- **memory_analysis** is per-device (SPMD). `peak_static = arguments +
  outputs + temp` is the conservative capacity measure (the CPU backend's
  `peak_memory_in_bytes` ignores arguments).
- **cost_analysis counts every lax.scan body once** (measured in-container),
  so roofline terms compose: (a) depth-1/depth-2 *unrolled* lowerings give
  per-layer costs, `total = outside + repeats·unit`; (b) the blocked
  attention / mLSTM / sLSTM / RG-LRU *inner* scans are added analytically
  from shapes (`roofline/analysis.py:scan_corrections`); (c) microbatch
  loops are lowered at microbatches=1.
- **Collective wire bytes** are parsed from partitioned HLO
  (result shapes + replica_groups), ring factors applied (AR 2·B·(g−1)/g,
  AG/RS/A2A B·(g−1)/g, CP B), one 50 GB/s ICI link (no striping credit).
  XLA:CPU's all-reduce-*promotion* pass upcasts bf16 ARs to f32; TPU reduces
  bf16 natively, so promoted ARs are counted at bf16 width.
- **CPU-lowering caveat**: XLA:CPU rejects some bf16×bf16→f32 dots at
  execution, so mixed-precision einsums upcast one operand to f32 on CPU
  lowerings (`layers.einsum_f32`). This inflates the bytes/temp proxies
  (flagged per cell); deltas between variants remain meaningful because
  every variant pays the same tax.
- **WSMC in the loop**: every cell's plan (remat × microbatches × optimizer
  × kv layout) comes from the planner: small-shape profiling ladder on the
  same mesh + offline-calibrated Table III factors (`artifacts/kb.json`).
"""

PERF_LOG = """## §Perf — hillclimbing log (hypothesis → change → measure → validate)

Cells chosen per the assignment: **nemotron-4-340b × prefill_32k** (most
collective-bound: T_coll ≈ 4×T_mem), **llama4-scout × train_4k** (worst
train-cell MFU-bound, 0.036), **gemma3-12b × train_4k** (most
paper-representative: the memory-capacity-constrained training cell WSMC
exists to plan). Baselines are the paper-faithful configuration (planner's
knobs, replicated-attention sharding). Stop rule: three consecutive <5%
changes on the dominant term.

### Iteration 1 — GQA head sharding via repeated KV (all cells)
- **Hypothesis.** Every assigned arch has kv_heads ∉ {16,32} divisible by
  the 16-way model axis, so attention runs *replicated* over "model"; GSPMD
  inserts a full [b,s,d] all-gather per layer (nemotron prefill: ~82 ×
  36 GB). Repeating K/V to H heads (h→h//G map preserved) lets attention
  shard by q-head: predicted T_coll cut ≥3× and attention FLOPs/chip ÷16.
- **Change.** `attention.py`: repeat_kv auto mode + constraints moved out of
  the pre-repeat path (first attempt *refuted* — the early kv-head
  `shard()` constraint forced replication before the repeat; the fix moved
  the constraint into the non-repeat branch).
- **Result (nemotron prefill): T_coll 94.45 → 35.70 s, T_mem 23.5 → 8.8 s,
  roofline 94.45 → 35.70 s, MFU-bound 0.174 → 0.459. CONFIRMED.**
  gemma3 train: T_coll −31%. Now the framework default (auto).

### Iteration 2 — ZeRO-3 gather-on-use weight respec (gather_w)
- **Hypothesis.** Remaining nemotron AR (1748 GB/chip) was thought to be
  activation-partial psums from contracting FSDP-sharded weights;
  re-constraining weights to gather over "data" at use should swap ~2.3 GB
  activation psums for ~0.4 GB weight gathers per layer.
- **Result: REFUTED — zero change on all three cells.** HLO inspection
  showed the ARs are the *Megatron TP pair* (attention-out + MLP-down
  projections), not FSDP traffic. A refuted hypothesis that redirected
  iteration 4.

### Iteration 3 — one-hot embedding + MoE levers
- **Hypothesis.** (a) The token-embedding gather trips GSPMD's "involuntary
  full rematerialization" on vocab-sharded tables (big-vocab archs pay a
  table-sized gather); a one-hot matmul shards cleanly. (b) MoE dispatch
  FLOPs scale ∝ routing-group size (s·g·k·cf·d), and EP (experts→"model")
  keeps dispatch local: llama4's MODEL/HLO = 0.28 said 3.5× compute waste.
- **Result (gemma3 train): T_mem 30.3 → 25.0 s (−20%). CONFIRMED.**
  **(llama4 train): EP −53% T_comp, +group512 −56%, +onehot T_mem
  31.1 → 22.8 s; roofline 38.25 → 22.79 s, MFU-bound 0.036 → 0.061.
  CONFIRMED.** `remat_full` as a bytes-saver was REFUTED on gemma3
  (+26% T_comp, ±0% T_mem): remat trades *capacity*, not traffic — exactly
  the distinction the WSMC planner's knobs encode. EP (when experts divide
  the axis) and one-hot embedding are now framework defaults.

### Iteration 4 — bf16 TP-reduce (+ promotion-aware accounting)
- **Hypothesis.** The TP all-reduce pair travels in f32 because the
  projection matmul requested f32 output (cast to bf16 immediately after);
  reducing in bf16 halves AR wire (standard Megatron practice).
- **Change.** `layers.matmul` drops preferred_element_type (MXU still
  accumulates f32 per shard). Measurement initially showed *no change* —
  HLO inspection found XLA:CPU's all-reduce-promotion pass re-upcasting
  bf16 ARs to f32 (`to_apply=%…_promoted`), a CPU-only artifact; the wire
  parser now counts promoted ARs at bf16 width (TPU-faithful).
- **Result (nemotron prefill): T_coll 35.70 → 18.21 s; roofline
  35.70 → 18.21 s; MFU-bound 0.459 → 0.900. CONFIRMED.**

### Closing iterations (stop rule)
- gemma3: gather_w+onehot ±0%, onehot+dots ±0%, bf16-reduce ±4% → stopped.
- llama4: ep+g512+oh+gw ±0%, bf16-reduce ±4%, qb_1024 ±2% → stopped.
- nemotron: qb_1024 ±1% (coll-dominant unchanged) → stopped. The remaining
  2 s gap between T_coll (18.2) and T_comp (16.2) is schedule overlap — on
  TPU the latency-hiding scheduler overlaps the TP AR with the next
  layer's matmuls (deployment flag, not a lowering change).

### Scoreboard (roofline time, paper-faithful baseline → optimized)

| cell | baseline | optimized | × | bottleneck | MFU-bound |
|---|---|---|---|---|---|
| nemotron-4-340b × prefill_32k | 94.45 s | 18.21 s | **5.2×** | collective→(coll≈comp) | 0.174 → **0.900** |
| llama4-scout × train_4k | 38.25 s | 22.79 s | **1.68×** | memory | 0.036 → 0.061 |
| gemma3-12b × train_4k | 31.31 s | 25.04 s | **1.25×** | memory | 0.049 → 0.061 |

Beyond-paper optimizations adopted as defaults: repeat-KV head sharding,
EP-when-divisible, routing-group-512 planning option, one-hot embedding,
bf16 TP-reduce. All are *sharding/schedule* changes invisible to the
paper's capacity model except through smaller transients — the WSMC
predictor's factors were re-calibrated afterwards (kb_opt.json).

### Planner lessons surfaced by the optimized re-run

1. **Scan-carry stashes beat the capacity model.** The first optimized
   xlstm×train_4k compile hit 69.5 GiB/device: under remat=none, scan-vjp
   stashes the mLSTM chunk-scan carries (32 chunks × dk×dv f32 state) for
   all 42 layers simultaneously — a transient the ladder-fit α could not
   see at small seq. Fix: flash-style `jax.checkpoint` around the mLSTM
   chunk scan and the sLSTM time scan (69.5 → 3.1 GiB with the planner's
   full/16 plan). The paper's analogue: shuffle spill behaviour that only
   appears beyond the profiled input range — exactly why its factors are
   conservative.
2. **Calibration is order-dependent.** The optimized sweep re-calibrated
   its Table III factors from scratch; the first workload profiled
   (xlstm) got a low envelope and the planner briefly chose an unsafe
   remat=none/µb=64 plan. The paper's procedure — complete the *offline*
   phase over the benchmark suite before serving ad-hoc workloads — is
   load-bearing, not optional.
3. **Microbatch divisibility must be strict for training**: a per-micro
   batch below the dp extent replicates compute/memory 16× (planner rule
   fixed; serving bs=1 cells legitimately replicate).
"""


def headline_table(dryrun_dir: str = "artifacts/dryrun",
                   kb_path: str = "artifacts/kb.json") -> str:
    """The paper's headline (§IV): WSMC vs default — memory saved at what
    step-time cost — computed at FULL scale from the dry-run artifacts.
    'default' = static full-HBM request with the conservative config;
    'WSMC' = planned capacity (Eq. 11 over the *measured* per-device peak,
    i.e. what the planner would reserve knowing this workload)."""
    import repro.hw as HW
    from repro.configs import SHAPES, get_config
    from repro.core import planner as PL
    out = ["| cell | WSMC plan | capacity req (GiB) | mem saved vs 16 GiB "
           "default | step-time penalty vs fastest | default's penalty |",
           "|---|---|---|---|---|---|"]
    saves, pens = [], []
    cells = load_cells(dryrun_dir)
    for key, c in sorted(cells.items()):
        if c.get("status") != "ok" or "wsmc" not in c:
            continue
        cfg = get_config(c["arch"])
        shape = SHAPES[c["shape"]]
        ms = c["mesh_single"]
        peak = ms["peak_static_bytes"]
        cap = min(HW.capacity_from_requirement(peak, 0.0), HW.TPU_V5E.hbm_bytes)
        p = c["wsmc"]["plan"]
        plan = PL.MemoryPlan(remat=p["remat"], microbatches=p["microbatches"],
                             optimizer=p["optimizer"], kv_shard=p["kv_shard"])
        dflt = PL.default_plan(cfg, shape)
        saved = 1.0 - cap / HW.TPU_V5E.hbm_bytes
        pen = plan.step_time_penalty()
        saves.append(saved)
        pens.append(pen / dflt.step_time_penalty())
        out.append(f"| {c['arch']} × {c['shape']} | {p['remat']}/"
                   f"{p['microbatches']}/{p['optimizer']} | {cap/GB:.2f} | "
                   f"{saved:.0%} | {pen:.2f}× | "
                   f"{dflt.step_time_penalty():.2f}× |")
    if saves:
        mean_save = sum(saves) / len(saves)
        mean_pen = sum(pens) / len(pens)
        out.append("")
        out.append(f"**Mean memory saved vs the static default request: "
                   f"{mean_save:.0%}** (paper: >40%); **mean step-time "
                   f"ratio vs the conservative default's configuration: "
                   f"{mean_pen:.2f}×** (the planner picks *faster* knobs "
                   f"than the default wherever the prediction fits — the "
                   f"paper's ~1% speedup vs 'proper', inverted to our "
                   f"conservative-default framing).")
    return "\n".join(out)


def paper_eval(bench_path: str = "bench_output.txt") -> str:
    out = ["## §Paper-evaluation (Figs. 2/3/6/7/8, Tables III/IV analogues)",
           "",
           "### Headline at full scale (from the dry-run artifacts)",
           "",
           headline_table(),
           ""]
    if os.path.exists(bench_path):
        interesting = [l.strip() for l in open(bench_path)
                       if l.startswith(("fig2.predict", "fig7.time",
                                        "fig8.mem", "policies.search"))]
        out.append("From `benchmarks.run` (reduced-scale, 8-dev mesh; full "
                   "CSV in bench_output.txt):")
        out.append("```")
        out.extend(interesting)
        out.append("```")
    kb_path = "artifacts/kb.json"
    if os.path.exists(kb_path):
        kb = json.load(open(kb_path))
        out.append("")
        out.append("Offline knowledge base (Table III analogue — per-"
                   "workload classifications at full scale):")
        out.append("")
        out.append("| workload | category | α (per-stage) | inc |")
        out.append("|---|---|---|---|")
        for k in sorted(kb):
            e = kb[k]
            out.append(f"| {k} | {e['category']} | {e['alpha']:.2f} | "
                       f"{e['inc']:.2f} |")
    return "\n".join(out)


def main():
    parts = [HEADER]

    base = load_cells("artifacts/dryrun")
    parts.append("## §Dry-run — paper-faithful baseline "
                 f"({sum(c['status'] == 'ok' for c in base.values())} ok / "
                 f"{sum(c['status'] == 'skipped' for c in base.values())} "
                 "skipped / 0 failed of 40 cells; both meshes compile "
                 "per cell)\n")
    parts.append(dryrun_table(base))

    if os.path.isdir("artifacts/dryrun_opt"):
        opt = load_cells("artifacts/dryrun_opt")
        n_ok = sum(c["status"] == "ok" for c in opt.values())
        if n_ok:
            parts.append(f"\n### Optimized defaults re-run ({n_ok} ok)\n")
            parts.append(dryrun_table(opt))
            parts.append("\n## §Roofline — optimized defaults "
                         "(single-pod 16×16, per chip)\n")
            parts.append(roofline_table(opt))
    parts.append("\n## §Roofline — paper-faithful baseline "
                 "(single-pod 16×16, per chip)\n")
    parts.append(roofline_table(base))
    parts.append("\n" + PERF_LOG)
    parts.append(paper_eval())
    print("\n".join(parts))


if __name__ == "__main__":
    main()
