"""Summarize dry-run artifacts into the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.launch.summarize artifacts/dryrun
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

from repro.configs import ARCH_IDS, SHAPE_ORDER

GB = 1024.0**3


def load_cells(d: str) -> Dict[str, dict]:
    cells = {}
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                cells[name[:-5]] = json.load(f)
    return cells


def fmt_gb(b):
    return f"{b / GB:.2f}"


def dryrun_table(cells) -> str:
    rows = ["| arch | shape | plan (remat/µb/opt/kv) | category | α(ladder→full) | "
            "args GiB/dev | temp GiB/dev | multi-pod temp | status |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_IDS:
        for shape in SHAPE_ORDER:
            c = cells.get(f"{arch}__{shape}")
            if c is None:
                continue
            if c["status"] == "skipped":
                rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                            f"SKIP (pure full-attention, sub-quadratic "
                            f"required) |")
                continue
            if c["status"] != "ok":
                rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                            f"FAILED |")
                continue
            w = c.get("wsmc", {})
            p = w.get("plan", {})
            plan = (f"{p.get('remat','?')}/{p.get('microbatches','?')}/"
                    f"{p.get('optimizer','?')}/{p.get('kv_shard','?')}")
            ms = c.get("mesh_single", {})
            mm = c.get("mesh_multi", {})
            rows.append(
                f"| {arch} | {shape} | {plan} | {w.get('category','?')} | "
                f"{w.get('alpha','?')}→{ms.get('alpha_full','?')} | "
                f"{fmt_gb(ms.get('argument_bytes', 0))} | "
                f"{fmt_gb(ms.get('temp_bytes', 0))} | "
                f"{fmt_gb(mm.get('temp_bytes', 0))} | ok |")
    return "\n".join(rows)


def roofline_table(cells) -> str:
    rows = ["| arch | shape | T_comp s | T_mem s (HLO⁄analytic) | T_coll s |"
            " bottleneck | MODEL/HLO | MFU-bound (HLO⁄analytic) | lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    levers = {
        "memory": "cut bytes: fused attn kernel, fewer saves, bigger blocks",
        "collective": "reshard (repeat-kv/EP), bf16 reduce, overlap w/ compute",
        "compute": "raise MXU occupancy / cut dispatch+mask waste",
    }
    for arch in ARCH_IDS:
        for shape in SHAPE_ORDER:
            c = cells.get(f"{arch}__{shape}")
            if not c or c.get("status") != "ok" or "roofline" not in c:
                continue
            r = c["roofline"]
            tma = r.get("t_mem_analytic", 0.0)
            mfa = r.get("mfu_bound_analytic", r["mfu_bound"])
            rows.append(
                f"| {arch} | {shape} | {r['t_comp']:.3f} | "
                f"{r['t_mem']:.3f}⁄{tma:.3f} |"
                f" {r['t_coll']:.3f} | **{r['bottleneck']}** | "
                f"{r['useful_flops_ratio']:.2f} | "
                f"{r['mfu_bound']:.3f}⁄{mfa:.3f} | "
                f"{levers[r['bottleneck']]} |")
    return "\n".join(rows)


def pick_hillclimb(cells) -> List[str]:
    """worst MFU-bound, most collective-bound, most paper-representative."""
    ok = [(k, c) for k, c in cells.items()
          if c.get("status") == "ok" and "roofline" in c]
    if not ok:
        return []
    worst = min(ok, key=lambda kc: kc[1]["roofline"]["mfu_bound"])
    coll = max(ok, key=lambda kc: (kc[1]["roofline"]["t_coll"]
                                   / max(kc[1]["roofline"]["t_roofline"],
                                         1e-9)))
    return [worst[0], coll[0]]


def main(d: str = "artifacts/dryrun"):
    cells = load_cells(d)
    n_ok = sum(c["status"] == "ok" for c in cells.values())
    n_skip = sum(c["status"] == "skipped" for c in cells.values())
    n_fail = sum(c["status"] == "failed" for c in cells.values())
    print(f"## Dry-run summary: {n_ok} ok / {n_skip} skipped / "
          f"{n_fail} failed of {len(cells)} cells\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod 16×16, per chip)\n")
    print(roofline_table(cells))
    print("\nhillclimb candidates:", pick_hillclimb(cells))


if __name__ == "__main__":
    main(*sys.argv[1:])
