"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init)."""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across JAX versions: `axis_types` (and the
    jax.sharding.AxisType enum backing it) only exists on newer releases;
    older ones default every axis to Auto anyway, which is what we want."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-mesh)."""
    return _make_mesh(tuple(shape), tuple(axes))


def host_mesh_for(n_devices: int, model_parallel: int = 1):
    """Elastic helper: best-effort (data, model) mesh over surviving devices."""
    model = max(1, model_parallel)
    while n_devices % model:
        model -= 1
    return make_mesh((n_devices // model, model), ("data", "model"))
