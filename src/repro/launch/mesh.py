"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init)."""
from __future__ import annotations

from typing import Mapping, Optional, Sequence

import jax

# Physical placement order for planned meshes: the weakest links go
# outermost (pipelining tolerates them; DESIGN.md §5), TP innermost.
CANONICAL_AXES = ("pod", "pipe", "data", "model")


def _make_mesh(shape, axes, devices=None):
    """jax.make_mesh across JAX versions: `axis_types` (and the
    jax.sharding.AxisType enum backing it) only exists on newer releases;
    older ones default every axis to Auto anyway, which is what we want."""
    kw = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes), **kw)
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-mesh)."""
    return _make_mesh(tuple(shape), tuple(axes))


def build_mesh(mesh_shape: Mapping[str, int],
               devices: Optional[Sequence] = None):
    """Build a *planned* mesh from an {axis: size} dict (the ExecutionPlan
    output): axes ordered canonically (pod, pipe, data, model — unknown
    axes last), over the first prod(sizes) of `devices` (default
    jax.devices()), so a plan smaller than the host still builds."""
    items = sorted(mesh_shape.items(),
                   key=lambda kv: (CANONICAL_AXES.index(kv[0])
                                   if kv[0] in CANONICAL_AXES
                                   else len(CANONICAL_AXES), kv[0]))
    axes = tuple(a for a, _ in items)
    sizes = tuple(int(n) for _, n in items)
    n = 1
    for s in sizes:
        n *= s
    devices = list(jax.devices()) if devices is None else list(devices)
    if n > len(devices):
        raise ValueError(f"planned mesh {dict(mesh_shape)} needs {n} "
                         f"devices; only {len(devices)} available")
    return _make_mesh(sizes, axes, devices=devices[:n])
