import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf hillclimbing harness (§Perf): lower one (arch × shape) cell under
named optimization variants and report the three roofline terms + deltas.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch h2o-danube-1.8b \
      --shape train_4k --variants baseline,onehot_embed,remat_dots [--memory]

Each variant is a (plan, settings, strategy) override bundle — the exact
knobs the WSMC planner owns, plus beyond-paper levers (one-hot embedding,
EP, DP-replicated weights, attention block sizes).
"""
import argparse
import dataclasses
import json
import time
from typing import Dict, Optional

import jax

from repro.configs import SHAPES, get_config
from repro.core.predictor import MemoryPlan
from repro.core import profiler as PF
from repro.launch import compile as LC
from repro.launch.dryrun import depth_variant
from repro.launch.mesh import make_production_mesh
from repro.models.attention import AttnSettings
from repro.models.model import ModelSettings
from repro.parallel import sharding as S
from repro.roofline import analysis as RA


@dataclasses.dataclass
class Variant:
    name: str
    plan: Dict = dataclasses.field(default_factory=dict)
    settings: Dict = dataclasses.field(default_factory=dict)
    attn: Dict = dataclasses.field(default_factory=dict)
    strategy: Dict = dataclasses.field(default_factory=dict)


VARIANTS = {
    "baseline": Variant("baseline"),
    # --- beyond-paper levers ---
    "onehot_embed": Variant("onehot_embed",
                            settings=dict(embed_onehot=True)),
    "attn_replicated": Variant("attn_replicated",
                               attn=dict(repeat_kv=False)),
    "repeat_kv": Variant("repeat_kv", attn=dict(repeat_kv=True)),
    "gather_w": Variant("gather_w", attn=dict(gather_weights=True)),
    "gather_w+onehot": Variant("gather_w+onehot",
                               attn=dict(gather_weights=True),
                               settings=dict(embed_onehot=True)),
    "remat_dots": Variant("remat_dots", plan=dict(remat="dots")),
    "remat_full": Variant("remat_full", plan=dict(remat="full")),
    "no_fsdp": Variant("no_fsdp", strategy=dict(fsdp=False)),
    "ep": Variant("ep", strategy=dict(ep=True)),
    "kv_heads": Variant("kv_heads", plan=dict(kv_shard="heads"),
                        strategy=dict(kv_shard="heads")),
    "kv_seq": Variant("kv_seq", plan=dict(kv_shard="seq"),
                      strategy=dict(kv_shard="seq")),
    "qb_1024": Variant("qb_1024", attn=dict(q_block=1024, kv_block=1024)),
    "qb_256": Variant("qb_256", attn=dict(q_block=256, kv_block=256)),
    "micro_4": Variant("micro_4", plan=dict(microbatches=4)),
    "moe_group_512": Variant("moe_group_512", settings=dict(moe_group=512)),
    "moe_group_1024": Variant("moe_group_1024",
                              settings=dict(moe_group=1024)),
    "ep+group512": Variant("ep+group512", strategy=dict(ep=True),
                           settings=dict(moe_group=512)),
    "ep+g512+onehot": Variant("ep+g512+onehot", strategy=dict(ep=True),
                              settings=dict(moe_group=512,
                                            embed_onehot=True)),
    "ep+g512+oh+gw": Variant("ep+g512+oh+gw", strategy=dict(ep=True),
                             attn=dict(gather_weights=True),
                             settings=dict(moe_group=512,
                                           embed_onehot=True)),
    "ep+g512+oh+qb1k": Variant("ep+g512+oh+qb1k", strategy=dict(ep=True),
                               attn=dict(q_block=1024, kv_block=1024),
                               settings=dict(moe_group=512,
                                             embed_onehot=True)),
    "onehot+dots": Variant("onehot+dots", plan=dict(remat="dots"),
                           settings=dict(embed_onehot=True)),
    "onehot+nofsdp": Variant("onehot+nofsdp",
                             settings=dict(embed_onehot=True),
                             strategy=dict(fsdp=False)),
}


def run_variant(cfg, shape, mesh, base_plan: MemoryPlan, var: Variant,
                measure_memory: bool = False):
    plan = dataclasses.replace(base_plan, **var.plan)
    rplan = dataclasses.replace(plan, microbatches=1)
    strategy = dataclasses.replace(
        PF.strategy_for(cfg, rplan, mesh), **var.strategy)
    attn = AttnSettings(**{**dataclasses.asdict(AttnSettings()), **var.attn})
    costs = []
    t0 = time.time()
    for n_units in (1, 2):
        dcfg = depth_variant(cfg, n_units)
        st = ModelSettings(scan_layers=False, attn=attn, **var.settings)
        bundle = LC.build(dcfg, shape, mesh, strategy=strategy,
                          tcfg=PF._tcfg_for(rplan, settings=st), settings=st)
        costs.append(RA.component_cost(bundle.compile()))
    total = RA.extrapolate(costs[0], costs[1], cfg.repeats)
    total = RA.apply_corrections(
        total, RA.scan_corrections(cfg, shape, mesh.devices.size,
                                   q_block=attn.q_block))
    rep = RA.report(cfg, shape, "single", mesh.devices.size, total,
                    remat=rplan.remat)
    out = rep.to_dict()
    out["lower_s"] = round(time.time() - t0, 1)
    if measure_memory:
        st = ModelSettings(scan_layers=True, attn=attn, **var.settings)
        bundle = LC.build(cfg, shape, mesh, strategy=strategy,
                          tcfg=PF._tcfg_for(plan, settings=st), settings=st)
        ma = bundle.compile().memory_analysis()
        out["temp_bytes"] = int(ma.temp_size_in_bytes)
        out["argument_bytes"] = int(ma.argument_size_in_bytes)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--plan", default="",
                    help="remat,microbatches,optimizer,kv_shard")
    ap.add_argument("--memory", action="store_true")
    ap.add_argument("--out", default="artifacts/hillclimb")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=False)
    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    base_plan = MemoryPlan()
    if args.plan:
        r, m, o, kv = args.plan.split(",")
        base_plan = MemoryPlan(remat=r, microbatches=int(m), optimizer=o,
                               kv_shard=kv)

    os.makedirs(args.out, exist_ok=True)
    results = {}
    base = None
    for vname in args.variants.split(","):
        var = VARIANTS[vname]
        try:
            r = run_variant(cfg, shape, mesh, base_plan, var, args.memory)
        except Exception as e:  # noqa: BLE001
            print(f"{vname:16s} FAILED: {e}", flush=True)
            continue
        results[vname] = r
        if base is None:
            base = r
        d = lambda k: (r[k] / base[k] - 1.0) * 100 if base[k] else 0.0
        extra = (f" temp={r.get('temp_bytes', 0)/2**30:.2f}GiB"
                 if args.memory and "temp_bytes" in r else "")
        print(f"{vname:16s} comp={r['t_comp']:.3f}s({d('t_comp'):+.0f}%) "
              f"mem={r['t_mem']:.3f}s({d('t_mem'):+.0f}%) "
              f"coll={r['t_coll']:.3f}s({d('t_coll'):+.0f}%) "
              f"roof={r['t_roofline']:.3f}s "
              f"bottleneck={r['bottleneck']} "
              f"mfu_bound={r['mfu_bound']:.3f}{extra}", flush=True)
    path = os.path.join(args.out, f"{args.arch}__{args.shape}.json")
    existing = {}
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
    existing.update(results)
    with open(path, "w") as f:
        json.dump(existing, f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
