"""Perf hillclimbing harness (§Perf): search the optimization-variant space
and lower one (arch × shape) cell under named variants, reporting the three
roofline terms + deltas.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch h2o-danube-1.8b \
      --shape train_4k --variants baseline,onehot_embed,remat_dots [--memory]

Each named variant is a point in the hillclimb ConfigSpace
(`repro.search.space.hillclimb_space`) — the exact knobs the WSMC planner
owns plus the beyond-paper levers (one-hot embedding, EP, DP-replicated
weights, attention block sizes, MoE routing group).

The driver always runs a *planning phase* first: the selected --strategy
searches the space through the --backend measurer. Under the default
`--backend simulate` that phase does zero XLA compiles (ROADMAP: plan
screening before the compile-verified pass). With no --variants the driver
stops there. Listing variants no longer compiles variant-by-variant: the
measurement phase first scores every requested variant with the
simulate-backed feasibility score (the same ordering greedy_coordinate
climbs on) and compiles only the --compile-budget best, plus the first
listed variant as the delta baseline.
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time
from typing import Dict

from repro import hw as HW
from repro.configs import SHAPES, get_config
from repro.core import measure as MM
from repro.core import profiler as PF
from repro.core.predictor import MemoryPlan
from repro.configs.base import depth_variant
from repro.models.attention import AttnSettings
from repro.models.model import ModelSettings
from repro.roofline import analysis as RA
from repro.search import space as SP
from repro.search import strategies as ST

# The planning phase scores candidates against the single-pod production
# mesh shape — a plain dict is all the simulator needs.
PLAN_MESH_SHAPE = {"data": 16, "model": 16}

SPACE = SP.hillclimb_space(PLAN_MESH_SHAPE)

# Enumeration-based strategies (fastest/staged/exhaustive) see only what a
# measurer can distinguish: the plan knobs + ep. The other extras are
# ordering-neutral twins — pinning them to their baselines shrinks the
# lattice ~400x without changing any decision; greedy walks the full SPACE
# point-by-point and keeps every lever.
MEASURE_SPACE = SPACE.subspace(
    "hillclimb/measure",
    **{k.name: (k.values[0],) for k in SPACE.knobs
       if k.group == "extra" and k.name != "ep"})

# Named points in SPACE: the old hand-rolled VARIANTS dict reduced to knob
# assignments the space validates (unknown knobs / values raise at lookup).
VARIANTS: Dict[str, Dict[str, object]] = {
    "baseline": {},
    # --- beyond-paper levers ---
    "onehot_embed": dict(embed_onehot=True),
    "attn_replicated": dict(repeat_kv=False),
    "repeat_kv": dict(repeat_kv=True),
    "gather_w": dict(gather_weights=True),
    "gather_w+onehot": dict(gather_weights=True, embed_onehot=True),
    "remat_dots": dict(remat="dots"),
    "remat_full": dict(remat="full"),
    "no_fsdp": dict(fsdp=False),
    "ep": dict(ep=True),
    "kv_heads": dict(kv_shard="heads"),
    "kv_seq": dict(kv_shard="seq"),
    "qb_1024": dict(q_block=1024, kv_block=1024),
    "qb_256": dict(q_block=256, kv_block=256),
    "micro_4": dict(microbatches=4),
    "moe_group_512": dict(moe_group=512),
    "moe_group_1024": dict(moe_group=1024),
    "ep+group512": dict(ep=True, moe_group=512),
    "ep+g512+onehot": dict(ep=True, moe_group=512, embed_onehot=True),
    "ep+g512+oh+gw": dict(ep=True, moe_group=512, embed_onehot=True,
                          gather_weights=True),
    "ep+g512+oh+qb1k": dict(ep=True, moe_group=512, embed_onehot=True,
                            q_block=1024, kv_block=1024),
    "onehot+dots": dict(remat="dots", embed_onehot=True),
    "onehot+nofsdp": dict(embed_onehot=True, fsdp=False),
}

def run_variant(cfg, shape, mesh, cand: SP.Candidate,
                measure_memory: bool = False):
    plan = cand.plan
    rplan = dataclasses.replace(plan, microbatches=1)
    over = SP.candidate_overrides(cand)
    strategy = dataclasses.replace(
        PF.strategy_for(cfg, rplan, mesh), **over["strategy"])
    attn = dataclasses.replace(AttnSettings(), **over["attn"])
    costs = []
    t0 = time.time()
    for n_units in (1, 2):
        dcfg = depth_variant(cfg, n_units)
        st = ModelSettings(scan_layers=False, attn=attn, **over["settings"])
        bundle = LC_build(dcfg, shape, mesh, strategy=strategy,
                          tcfg=PF._tcfg_for(rplan, settings=st), settings=st)
        costs.append(RA.component_cost(bundle.compile()))
    total = RA.extrapolate(costs[0], costs[1], cfg.repeats)
    total = RA.apply_corrections(
        total, RA.scan_corrections(cfg, shape, mesh.devices.size,
                                   q_block=attn.q_block))
    rep = RA.report(cfg, shape, "single", mesh.devices.size, total,
                    remat=rplan.remat)
    out = rep.to_dict()
    out["lower_s"] = round(time.time() - t0, 1)
    if measure_memory:
        st = ModelSettings(scan_layers=True, attn=attn, **over["settings"])
        bundle = LC_build(cfg, shape, mesh, strategy=strategy,
                          tcfg=PF._tcfg_for(plan, settings=st), settings=st)
        ma = bundle.compile().memory_analysis()
        out["temp_bytes"] = int(ma.temp_size_in_bytes)
        out["argument_bytes"] = int(ma.argument_size_in_bytes)
    return out


def LC_build(*args, **kwargs):
    """Lazy launch.compile.build so the simulate-only planning path never
    imports the AOT stack (and the hermetic tests can assert zero compiles)."""
    from repro.launch import compile as LC
    return LC.build(*args, **kwargs)


def plan_phase(cfg, shape, base_cand: SP.Candidate, strategy: str,
               backend: str):
    """Search the variant space through the MemoryMeasurer interface.
    Under --backend simulate this is compile-free; under compile every
    verification is a real lowering on the production mesh."""
    if backend == "simulate":
        measurer = MM.SimulatedMeasurer(PLAN_MESH_SHAPE)
    else:
        from repro.launch.mesh import make_production_mesh
        measurer = MM.CompileMeasurer(make_production_mesh(multi_pod=False))
    scorer = ST.CandidateScorer(measurer=measurer)
    budget = ST.plan_budget(HW.TPU_V5E)

    name = ST.get_strategy(strategy).__name__
    if name == "greedy_coordinate":
        res = ST.greedy_coordinate(
            SPACE, cfg, shape, start=base_cand, scorer=scorer,
            score=ST.feasibility_score(scorer, cfg, shape))
    elif name == "staged":
        res = ST.staged(MEASURE_SPACE, cfg, shape,
                        screener=MM.SimulatedMeasurer(PLAN_MESH_SHAPE),
                        verifier=measurer)
    elif name == "exhaustive_verified":
        res = ST.exhaustive_verified(MEASURE_SPACE, cfg, shape,
                                     measurer=measurer)
    else:   # fastest_first needs a classification from the profiling ladder
        cls = PF.classify_workload(cfg, shape, None, n_points=2, base_seq=64,
                                   measurer=measurer)
        res = ST.fastest_first(MEASURE_SPACE, cfg, shape, cls)
    # report the already-verified peak: greedy's winner is memoized in the
    # scorer (free), and the simulator costs nothing — never re-compile
    peak = res.peak_bytes
    if peak is None and (backend == "simulate"
                         or name == "greedy_coordinate"):
        peak = scorer.peak(cfg, shape, res.candidate)
    if peak is not None:
        mem = f"peak={peak / 2**30:.2f}GiB fits={peak <= budget}"
    else:
        mem = (f"pred_capacity="
               f"{res.prediction.capacity_bytes / 2**30:.2f}GiB")
    print(f"plan[{strategy}/{backend}]: {res.candidate.describe()} "
          f"policy={res.policy} considered={res.considered} "
          f"measured={res.measured} {mem}", flush=True)
    return res


def prune_variants(cfg, shape, named_cands, keep: int):
    """The measurement-phase shortlist (ROADMAP open item): score every
    requested variant through the compile-free simulator with the same
    feasibility ordering greedy_coordinate uses, and compile only the
    `keep` best. The first listed variant (the delta baseline) always
    survives. Extras-only twins (ordering-neutral levers the memory screen
    cannot tell apart) tie; ties break by LISTING ORDER — put the variants
    whose roofline deltas you care about most first, or raise
    --compile-budget (0 = compile all). Pruned names are printed, never
    silently skipped. Returns the kept names in their original order."""
    names = list(named_cands)
    if keep <= 0 or len(names) <= keep:
        return names
    scorer = ST.CandidateScorer(measurer=MM.SimulatedMeasurer(PLAN_MESH_SHAPE))
    score = ST.feasibility_score(scorer, cfg, shape)
    ranked = sorted(names[1:],
                    key=lambda n: (score(named_cands[n]), names.index(n)))
    kept = {names[0], *ranked[:max(keep - 1, 0)]}
    dropped = [n for n in names if n not in kept]
    print(f"prune[simulate]: compiling {len(kept)}/{len(names)} variants "
          f"(budget {keep}; ties break by listing order); pruned: "
          f"{','.join(dropped)}", flush=True)
    return [n for n in names if n in kept]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="",
                    help="comma-separated named variants to lower + compile; "
                         "empty = planning phase only")
    ap.add_argument("--compile-budget", type=int, default=5,
                    help="max variants to compile after the simulate-backed "
                         "shortlist screen (0 = compile all)")
    ap.add_argument("--plan", default="",
                    help="remat,microbatches,optimizer,kv_shard")
    ap.add_argument("--strategy", default="greedy",
                    choices=list(ST.CLI_STRATEGIES),
                    help="planning-phase search strategy over the variant "
                         "space")
    ap.add_argument("--backend", default="simulate",
                    choices=["simulate", "compile"],
                    help="measurement backend for the planning phase; "
                         "simulate = zero XLA compiles")
    ap.add_argument("--memory", action="store_true")
    ap.add_argument("--out", default="artifacts/hillclimb")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    base_plan = MemoryPlan()
    if args.plan:
        r, m, o, kv = args.plan.split(",")
        base_plan = MemoryPlan(remat=r, microbatches=int(m), optimizer=o,
                               kv_shard=kv)
    base_cand = SPACE.point(cfg, base=SP.Candidate(plan=base_plan))

    plan_phase(cfg, shape, base_cand, args.strategy, args.backend)
    if not args.variants:
        return 0

    named_cands = {vname: SPACE.point(cfg, base=base_cand, **VARIANTS[vname])
                   for vname in args.variants.split(",")}
    shortlist = prune_variants(cfg, shape, named_cands, args.compile_budget)

    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=False)
    os.makedirs(args.out, exist_ok=True)
    results = {}
    base = None
    for vname in shortlist:
        cand = named_cands[vname]
        try:
            r = run_variant(cfg, shape, mesh, cand, args.memory)
        except Exception as e:  # noqa: BLE001
            print(f"{vname:16s} FAILED: {e}", flush=True)
            continue
        results[vname] = r
        if base is None:
            base = r

        def delta(k):
            return (r[k] / base[k] - 1.0) * 100 if base[k] else 0.0

        extra = (f" temp={r.get('temp_bytes', 0) / 2**30:.2f}GiB"
                 if args.memory and "temp_bytes" in r else "")
        print(f"{vname:16s} comp={r['t_comp']:.3f}s({delta('t_comp'):+.0f}%) "
              f"mem={r['t_mem']:.3f}s({delta('t_mem'):+.0f}%) "
              f"coll={r['t_coll']:.3f}s({delta('t_coll'):+.0f}%) "
              f"roof={r['t_roofline']:.3f}s "
              f"bottleneck={r['bottleneck']} "
              f"mfu_bound={r['mfu_bound']:.3f}{extra}", flush=True)
    path = os.path.join(args.out, f"{args.arch}__{args.shape}.json")
    existing = {}
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
    existing.update(results)
    with open(path, "w") as f:
        json.dump(existing, f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
