"""Serving driver: replay a deterministic synthetic trace through the
memory-governed engine.

The driver is deliberately thin — all scheduling lives in
`repro.serving.Engine`, all capacity governance in
`search.execplan.plan_serving` (which inverts the WSMC requirement model:
`predictor.serving_capacity` turns the per-device HBM budget into a
maximum concurrent-sequence count, and the engine's slot pool is sized
from it; everything beyond queues). Planning defaults to the compile-free
simulator, so the only compiles in a run are the prefill/decode steps that
actually serve traffic; `--backend compile` classifies and verifies with
real compiles instead (honored on the `--mesh auto` path too).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --reduced \
      --requests 8 --prompt-lens 4,8 --gen-lens 2,4,8 [--mesh auto] \
      [--backend simulate|compile] [--policy continuous|static|both]
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.configs.base import DECODE, ShapeConfig
from repro.core import measure as MM
from repro.core.predictor import MemoryPlan
from repro.models import init_params
from repro.parallel.axes import axis_rules
from repro.search import execplan as XP
from repro.search import space as SP
from repro.serving import (AUDIT_MODES, BlockAllocator, ChaosAllocator,
                           ChaosExecutor, Engine, FaultPlan, LadderConfig,
                           OnlineLengthStats, describe_trace, leak_check,
                           length_stats, survivor_mismatches,
                           synthetic_trace, trace_context)
from repro.serving.executor import JaxExecutor, PagedJaxExecutor


def _int_list(s: str):
    return tuple(int(v) for v in s.split(",") if v)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    # trace knobs (deterministic: same seed + knobs => same trace)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-lens", type=_int_list, default=(4, 8))
    ap.add_argument("--gen-lens", type=_int_list, default=(2, 4, 8))
    ap.add_argument("--arrival-mean", type=float, default=1.0,
                    help="mean inter-arrival ticks; <=0 = burst at tick 0")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--context", type=int, default=0,
                    help="ring-cache extent; 0 = max prompt+gen in the trace")
    # planning knobs
    ap.add_argument("--mesh", default="", choices=["", "auto"],
                    help="'' = (data, model) host mesh from --model-parallel; "
                         "'auto' = search the serving lattice for the mesh "
                         "that maximizes admitted concurrency")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--backend", default="simulate",
                    choices=["simulate", "compile"],
                    help="measurement backend for workload classification; "
                         "simulate = zero throwaway compiles at startup")
    ap.add_argument("--hbm-budget-gb", type=float, default=0.0,
                    help="per-device HBM budget for admission; 0 = the "
                         "target hardware's full HBM")
    ap.add_argument("--kv", default="ring", choices=["ring", "paged"],
                    help="KV pool layout: 'ring' = worst-case whole-"
                         "sequence slots (baseline); 'paged' = block pool "
                         "with per-sequence block tables — admission "
                         "charges actual footprint, so short requests "
                         "stop paying max-context bytes")
    ap.add_argument("--kv-block", type=int, default=0,
                    help="paged KV block size in positions; 0 = search "
                         "the serving lattice for it")
    ap.add_argument("--compact", action="store_true",
                    help="paged only: compile the decode step at bucketed "
                         "lane widths and pack active lanes into the "
                         "smallest covering bucket each tick — partially "
                         "occupied ticks stop paying full pool width")
    ap.add_argument("--chunk-prefill", type=int, default=0,
                    help="paged only: split prompts longer than this into "
                         "chunks of this many positions, interleaved with "
                         "decode ticks (rounded up to a kv-block multiple; "
                         "0 = whole-prompt prefill at admission)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="paged only: cap on prompt tokens prefilled per "
                         "tick across all mid-prefill lanes, fair-shared "
                         "over SLO classes (needs --chunk-prefill; 0 = "
                         "every pending lane advances one chunk per tick). "
                         "The planner charges the budget — not the whole "
                         "prompt — as the prefill transient, so a tight "
                         "budget converts transient headroom into lanes")
    ap.add_argument("--prefill-kernel", default="tiled",
                    choices=["tiled", "dense"],
                    help="prefill transient model for planning: 'tiled' = "
                         "the fused flash-prefill kernel (O(chunk x block) "
                         "tiles, no score matrix or dequantized fp context "
                         "in HBM); 'dense' = the jnp oracle path that "
                         "materializes O(chunk x context) scores")
    ap.add_argument("--admission", default="worst",
                    choices=["worst", "optimistic"],
                    help="paged only: block reservation discipline. "
                         "'worst' reserves every block a request can "
                         "write (deadlock-free; nothing is preempted); "
                         "'optimistic' reserves E[blocks] + sigma-k "
                         "margin from the trace's length stats and "
                         "evicts-and-requeues (SLO class, then lowest "
                         "progress) when the prediction misses")
    ap.add_argument("--sigma-k", type=float, default=1.0,
                    help="safety margin in per-bucket std deviations for "
                         "--admission optimistic reservations")
    ap.add_argument("--prefix-share", action="store_true",
                    help="paged only: refcount-share the physical blocks "
                         "of the common system-prompt prefix across "
                         "requests (one prefill per unique prefix); "
                         "needs --prefix-len and chunked prefill "
                         "(defaults --chunk-prefill to one kv block)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared system-prompt tokens prepended to every "
                         "request's own prompt (0 = no shared prefix)")
    ap.add_argument("--kv-quant", default="none",
                    choices=["none", "int8", "int4"],
                    help="paged only: per-block KV quantization. Blocks "
                         "store int8 (or nibble-packed int4) codes plus "
                         "per-position absmax scales; the decode kernel "
                         "dequantizes on the block-table DMA path, so "
                         "the fp pool is never materialized")
    ap.add_argument("--kv-retain", type=int, default=0,
                    help="paged only: keep only the k most-attended "
                         "blocks per sequence (plus the write tail), "
                         "evicting cold blocks back to the allocator "
                         "free list after each decode tick (0 = exact, "
                         "keep everything)")
    ap.add_argument("--min-agreement", type=float, default=0.0,
                    help="planner floor on predicted token agreement: "
                         "bending candidates (quantized/retained) whose "
                         "agreement prior falls below this are dropped "
                         "before capacity scoring")
    ap.add_argument("--measure-agreement", action="store_true",
                    help="after serving, replay every request through "
                         "exact greedy_generate and report the measured "
                         "token-agreement fraction (slow: one reference "
                         "decode per unique prompt)")
    ap.add_argument("--slo", type=_int_list, default=(0,),
                    help="SLO classes requests draw from (0 = strictest, "
                         "evicted last under pool pressure)")
    ap.add_argument("--max-slots", type=int, default=8,
                    help="cap on the engine's slot pool / decode lanes "
                         "(the WSMC capacity is the bound; this caps it "
                         "for small hosts)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="paged only: arm the deterministic chaos harness "
                         "with this seed — transient executor/allocator "
                         "faults, one mid-run 25%% pool shrink, request "
                         "cancellations and a lane stall, all replayed "
                         "identically per seed. After the run the driver "
                         "leak-checks the allocator ledger and replays "
                         "the trace fault-free to prove every surviving "
                         "completion is token-identical")
    ap.add_argument("--deadline", type=int, default=0,
                    help="per-request deadline in ticks from arrival; "
                         "requests still unfinished are cancelled cleanly "
                         "(blocks freed, cause-tagged in the report). "
                         "0 = no deadline")
    ap.add_argument("--audit", default="off", choices=list(AUDIT_MODES),
                    help="paged only: every-tick allocator ledger audit. "
                         "'strict' fails the run on the first corrupt "
                         "tick, 'count' tallies violations into the "
                         "report, 'off' skips the sweep")
    ap.add_argument("--policy", default="continuous",
                    choices=["continuous", "static", "both"])
    ap.add_argument("--forbid-plan-compiles", action="store_true",
                    help="fail if planning attempts an XLA compile (CI "
                         "guard; incompatible with --backend compile)")
    args = ap.parse_args(argv)

    if args.forbid_plan_compiles and args.backend == "compile":
        ap.error("--forbid-plan-compiles contradicts --backend compile")
    if args.kv != "paged" and (args.compact or args.chunk_prefill):
        ap.error("--compact/--chunk-prefill need --kv paged (the ring "
                 "executor has no lane buckets or block tables)")
    if args.kv != "paged" and (args.admission != "worst"
                               or args.prefix_share):
        ap.error("--admission optimistic/--prefix-share need --kv paged "
                 "(the reservation ledger lives on the BlockAllocator)")
    if args.prefix_share and not args.prefix_len:
        ap.error("--prefix-share needs --prefix-len > 0 (there is no "
                 "shared prefix to share otherwise)")
    if args.prefill_budget < 0:
        ap.error("--prefill-budget must be >= 0")
    if args.prefill_budget and not (args.kv == "paged"
                                    and args.chunk_prefill):
        ap.error("--prefill-budget needs --kv paged and --chunk-prefill "
                 "(the budget schedules prompt chunks over block tables; "
                 "whole-prompt prefill is all-or-nothing)")
    if args.kv != "paged" and (args.kv_quant != "none" or args.kv_retain):
        ap.error("--kv-quant/--kv-retain need --kv paged (quantized "
                 "codes and retention both live on the block pool)")
    if args.kv_retain < 0:
        ap.error("--kv-retain must be >= 0")
    if args.kv != "paged" and args.chaos_seed is not None:
        ap.error("--chaos-seed needs --kv paged (pool shrinks and "
                 "allocation faults inject into the block ledger)")
    if args.kv != "paged" and args.audit != "off":
        ap.error("--audit needs --kv paged (the audit sweeps the "
                 "BlockAllocator ledger)")
    if args.deadline < 0:
        ap.error("--deadline must be >= 0")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    trace = synthetic_trace(args.requests, vocab_size=cfg.vocab_size,
                            seed=args.seed, prompt_lens=args.prompt_lens,
                            gen_lens=args.gen_lens,
                            mean_interarrival=args.arrival_mean,
                            prefix_len=args.prefix_len,
                            slo_classes=args.slo)
    context = args.context or trace_context(trace)
    devices = jax.devices()
    shape = ShapeConfig("serve_trace", DECODE, context,
                        max(args.max_slots, 1))
    budget = (args.hbm_budget_gb * 2**30) if args.hbm_budget_gb else None

    # -- plan: mesh + kv sharding + admission bound -------------------------
    # The compile guard is scoped to planning only (restored after), so a
    # later call in the same process can still compile legitimately.
    guard = None
    if args.forbid_plan_compiles:
        from repro.launch import compile as LC

        def _forbidden(*a, **k):
            raise AssertionError(
                "throwaway XLA compile during serve planning "
                "(--forbid-plan-compiles)")
        guard, LC.build = (LC, LC.build), _forbidden
    kv_blocks = ((args.kv_block,) if args.kv_block
                 else tuple(b for b in XP.DEFAULT_KV_BLOCKS if b <= context)
                 or (context,))
    paged_kw = {}
    if args.kv == "paged":
        # the planner maximizes EXPECTED admitted concurrency under the
        # trace's own length distribution (written positions per request)
        # the pool is always sized expected-case (plan_serving default);
        # optimistic admission additionally reserves a sigma-k margin, so
        # the planner carries the same margin into the pool size
        paged_kw = dict(kv="paged", kv_blocks=kv_blocks,
                        seq_lens=[len(r.prompt) + r.max_new - 1
                                  for r in trace],
                        compact=args.compact,
                        sigma_k=(args.sigma_k
                                 if args.admission == "optimistic" else 0.0),
                        kv_quants=(args.kv_quant,),
                        kv_retains=(args.kv_retain,),
                        min_agreement=args.min_agreement,
                        prefill_budget=args.prefill_budget,
                        prefill_kernel=args.prefill_kernel,
                        chunk=args.chunk_prefill)
    try:
        if args.mesh == "auto":
            measurer = None
            if args.backend == "compile":
                from repro.launch.mesh import build_mesh
                measurer = MM.CompileMeasurer(
                    build_mesh({"data": len(devices)}, devices))
            cls, splan = XP.plan_serving(cfg, shape, n_devices=len(devices),
                                         hbm_budget=budget,
                                         measurer=measurer, **paged_kw)
        else:
            host = XP.host_execution(cfg, shape, MemoryPlan(),
                                     len(devices), args.model_parallel)
            if args.backend == "compile":
                measurer = MM.CompileMeasurer(host.build(devices)[0])
            else:
                measurer = MM.SimulatedMeasurer(host.mesh_shape)
            pinned = SP.serving_space(
                cfg, shape, max_devices=len(devices),
                data=(host.mesh_shape.get("data", 1),),
                model=(host.mesh_shape.get("model", 1),),
                kv_blocks=kv_blocks if args.kv == "paged" else (0,),
                kv_quants=((args.kv_quant,) if args.kv == "paged"
                           else ("none",)),
                kv_retains=((args.kv_retain,) if args.kv == "paged"
                            else (0,)))
            cls, splan = XP.plan_serving(cfg, shape, n_devices=len(devices),
                                         hbm_budget=budget,
                                         measurer=measurer, space=pinned,
                                         **paged_kw)
    finally:
        if guard is not None:
            guard[0].build = guard[1]
    print(f"WSMC[serving/{args.backend}]: {cls.category.value} -> "
          f"{splan.describe()}")
    print("trace:", describe_trace(trace))

    n_slots = splan.slots(cap=min(args.max_slots, len(trace)))
    if n_slots < 1:
        print("no serving capacity under the budget; nothing admitted")
        return 1
    n_blocks = splan.pool_blocks(n_slots, context)
    mesh, strategy = splan.execution.build(devices)

    # -- chaos plan ---------------------------------------------------------
    chaos = args.chaos_seed is not None
    plan = None
    if chaos:
        # place shrinks inside the run: rough tick horizon = arrival span
        # plus serial work over the lane count
        work = sum(len(r.prompt) + r.max_new for r in trace)
        horizon = max(64, max(r.arrival for r in trace)
                      + work // max(n_slots, 1))
        plan = FaultPlan.generate(args.chaos_seed, ticks=horizon,
                                  n_requests=len(trace), n_lanes=n_slots,
                                  n_cancels=max(1, len(trace) // 8),
                                  n_stalls=1)
        print("chaos:", plan.describe())

    # -- serve --------------------------------------------------------------
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    policies = (["continuous", "static"] if args.policy == "both"
                else [args.policy])
    reports = []
    failures = []
    with mesh, axis_rules(strategy.rules(), mesh=mesh):
        for policy in policies:
            chunk = 0
            if args.kv == "paged":
                if args.chunk_prefill:       # align up to the block size
                    chunk = -(-args.chunk_prefill // splan.kv_block) \
                        * splan.kv_block
                elif args.prefix_share:      # suffixes ride the chunked path
                    chunk = splan.kv_block
                executor = PagedJaxExecutor(
                    params, cfg, n_lanes=n_slots, n_blocks=n_blocks,
                    kv_block=splan.kv_block, context=context,
                    compact=args.compact, chunk=chunk,
                    kv_quant=args.kv_quant, kv_retain=args.kv_retain)
                reservation = ("expected"
                               if args.admission == "optimistic"
                               else "worst")
                if chaos:
                    allocator = ChaosAllocator(n_blocks, splan.kv_block,
                                               reservation, plan=plan)
                else:
                    allocator = BlockAllocator(n_blocks, splan.kv_block,
                                               reservation=reservation)
            else:
                executor = JaxExecutor(params, cfg, n_slots=n_slots,
                                       context=context)
                allocator = None

            def mk_stats():
                # EW-updated online stats: reservations track the live
                # length distribution, and the report carries observed
                # sigma_k per prompt bucket
                if args.admission != "optimistic":
                    return None
                return OnlineLengthStats(base=length_stats(trace))
            run_exec = ChaosExecutor(executor, plan) if chaos else executor
            engine = Engine(run_exec, n_slots, policy=policy,
                            allocator=allocator, chunk_prefill=chunk,
                            prefill_budget=args.prefill_budget,
                            prefix_share=args.prefix_share,
                            stats=mk_stats(), sigma_k=args.sigma_k,
                            kv_retain=(args.kv_retain
                                       if args.kv == "paged" else 0),
                            deadline=args.deadline, faults=plan,
                            ladder=(LadderConfig() if chaos else None),
                            audit=args.audit)
            t0 = time.time()
            report = engine.run(trace)
            dt = time.time() - t0
            lp = report.latency_percentiles()
            tp = report.ttft_percentiles()
            print(report.describe() + f" wall={dt:.2f}s "
                  f"compiles={executor.compile_counts()}")
            if lp and tp:  # both empty when nothing completed
                print(f"  latency p50/p95/p99={lp['p50']:.0f}/"
                      f"{lp['p95']:.0f}/{lp['p99']:.0f} ticks "
                      f"ttft p50/p95/p99={tp['p50']:.0f}/{tp['p95']:.0f}/"
                      f"{tp['p99']:.0f} mean_ttft={report.mean_ttft():.1f} "
                      f"evictions={report.evictions}")
            if args.measure_agreement:
                from repro.serving.quality import token_agreement
                agree = token_agreement(params, cfg, trace, report,
                                        context=context)
                print(f"  {agree.describe()}")
            if chaos:
                # prove the harness didn't corrupt anything: the drained
                # ledger must be whole, and every request the chaos run
                # completed must be token-identical to a fault-free
                # replay (same executor, reset pool, clean allocator)
                problems = leak_check(allocator)
                executor.reset()
                clean = Engine(
                    executor, n_slots, policy=policy,
                    allocator=BlockAllocator(n_blocks, splan.kv_block,
                                             reservation=reservation),
                    chunk_prefill=chunk,
                    prefill_budget=args.prefill_budget,
                    prefix_share=args.prefix_share,
                    stats=mk_stats(), sigma_k=args.sigma_k,
                    kv_retain=(args.kv_retain
                               if args.kv == "paged" else 0)).run(trace)
                problems += survivor_mismatches(report, clean)
                if problems:
                    for p in problems:
                        print(f"  CHAOS FAILURE: {p}")
                    failures.extend(problems)
                else:
                    print(f"  chaos: ledger clean, "
                          f"{len(report.completions)} survivors "
                          f"token-identical to fault-free replay")
            reports.append(report)

    if args.policy == "both" and len(reports) == 2:
        cont, stat = reports
        print(f"occupancy: continuous={cont.occupancy():.3f} vs "
              f"static={stat.occupancy():.3f} "
              f"(+{(cont.occupancy() - stat.occupancy()) * 100:.1f} pts)")
    if failures:
        print(f"ERROR: {len(failures)} chaos check(s) failed")
        return 1
    if chaos or args.deadline:
        # faults and deadlines may legitimately cancel requests; every
        # request must still be ACCOUNTED for — completed or cause-tagged
        done = min(len(r.completions) + len(r.cancellations)
                   for r in reports)
        if done != len(trace):
            print(f"ERROR: {done}/{len(trace)} requests accounted for")
            return 1
        return 0
    completed = min(len(r.completions) for r in reports)
    if completed != len(trace):
        print(f"ERROR: {completed}/{len(trace)} requests completed")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
