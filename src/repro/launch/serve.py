"""Serving driver: batched prefill + decode with WSMC-planned cache layout.

Plan selection goes through the pluggable `repro.search` subsystem: the
default `--backend simulate` screens candidates with the analytical
MemoryMeasurer, so serving startup performs zero throwaway compiles (the
only compiles are the prefill/decode steps that actually serve).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --reduced \
      --prompt-len 32 --gen 16 --batch 4 [--backend simulate|compile] \
      [--strategy fastest|staged|exhaustive|greedy]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import DECODE, ShapeConfig
from repro.core import measure as MM
from repro.core import profiler as PF
from repro.core.predictor import MemoryPlan
from repro.models import init_params
from repro.parallel.axes import axis_rules
from repro.runtime.serve_step import make_decode_step, make_prefill_step
from repro.search import execplan as XP
from repro.search import strategies as ST


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mesh", default="", choices=["", "auto"],
                    help="'' = (data, model) host mesh from "
                         "--model-parallel; 'auto' = search mesh_space and "
                         "build the planned mesh")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="simulate",
                    choices=["simulate", "compile"],
                    help="memory-measurement backend for plan selection; "
                         "simulate = zero throwaway compiles at startup")
    ap.add_argument("--strategy", default="fastest",
                    choices=list(ST.CLI_STRATEGIES))
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    context = args.prompt_len + args.gen
    devices = jax.devices()
    shape = ShapeConfig("serve_cli", DECODE, context, args.batch)

    if args.mesh == "auto":
        # plan the serving mesh (decode pins pipe=1), then build it
        if args.backend == "compile":
            print("note: --mesh auto plans with the compile-free simulator; "
                  "--backend compile only affects fixed-mesh planning")
        cls, eplan = XP.auto_plan(cfg, shape, n_devices=len(devices),
                                  strategy=args.strategy)
        print(f"WSMC[auto/{args.strategy}]: {cls.category.value} -> "
              f"{eplan.describe()}")
        mesh, strategy = eplan.build(devices)
    else:
        eplan = XP.host_execution(cfg, shape, MemoryPlan(),
                                  len(devices), args.model_parallel)
        mesh, _ = eplan.build(devices)
        mesh_shape = eplan.mesh_shape
        if args.backend == "simulate":
            measurer = MM.SimulatedMeasurer(mesh_shape)
        else:
            measurer = MM.CompileMeasurer(mesh)
        cls = PF.classify_workload(cfg, shape, mesh, n_points=2, base_seq=64,
                                   measurer=measurer)
        res = ST.plan_for(cfg, shape, cls, mesh_shape,
                          strategy=args.strategy, measurer=measurer)
        print(f"WSMC[{args.strategy}/{args.backend}]: {cls.category.value} "
              f"-> kv_shard={res.plan.kv_shard} policy={res.policy} "
              f"{res.describe_outcome()}")
        eplan = XP.from_search_result(cfg, shape, res, mesh_shape)
        strategy = eplan.strategy()

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                (args.batch, args.prompt_len), 2,
                                cfg.vocab_size)

    prefill = jax.jit(make_prefill_step(cfg), static_argnames=("context",))
    decode = jax.jit(make_decode_step(cfg), static_argnames=("context",),
                     donate_argnums=(3,))

    with mesh, axis_rules(strategy.rules(), mesh=mesh):
        t0 = time.time()
        logits, cache = prefill(params, prompt, context=context)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_prefill = time.time() - t0
        out = [tok]
        t0 = time.time()
        for t in range(args.gen - 1):
            pos = jnp.full((args.batch,), args.prompt_len + t, jnp.int32)
            logits, cache = decode(params, tok[:, None], pos, cache,
                                   context=context)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
        gen = np.asarray(jnp.stack(out, axis=1))
        t_decode = time.time() - t0

    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decode: {args.gen - 1} steps in {t_decode:.2f}s "
          f"({t_decode / max(args.gen - 1, 1) * 1e3:.1f} ms/tok/batch)")
    print("generated tokens (first row):", gen[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
