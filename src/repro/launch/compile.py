"""Shared AOT build/lower/compile facility.

Everything that needs a compiled step without allocating real tensors goes
through here: the multi-pod dry-run, the WSMC online profiler (small-shape
ladder), the oracle planner ("proper configuration" search), and the
roofline analysis. Mirrors the paper's workflow: the workload is *described*
(ShapeDtypeStructs + shardings), never executed.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (DECODE, PREFILL, TRAIN, ModelConfig,
                                ShapeConfig, input_specs)
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.parallel import sharding as S
from repro.parallel.axes import axis_rules
from repro.runtime.schedule import fallback_schedule, make_train_step
from repro.runtime.train_step import TrainStepConfig
from repro.runtime.serve_step import make_decode_step, make_prefill_step


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(M.init_params, cfg=cfg), jax.random.PRNGKey(0))


def abstract_opt_state(ocfg: opt.OptimizerConfig, params_abs):
    return jax.eval_shape(functools.partial(opt.init_state, ocfg), params_abs)


@dataclasses.dataclass
class Bundle:
    """Everything needed to lower one workload cell."""
    fn: Any
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    mesh: Any
    strategy: S.Strategy
    cfg: ModelConfig
    shape: ShapeConfig

    def lower(self):
        with self.mesh:
            with axis_rules(self.strategy.rules(), mesh=self.mesh):
                jitted = jax.jit(self.fn,
                                 in_shardings=self.in_shardings,
                                 out_shardings=self.out_shardings,
                                 donate_argnums=self.donate_argnums)
                return jitted.lower(*self.args)

    def compile(self, lowered=None):
        lowered = lowered if lowered is not None else self.lower()
        return lowered.compile()


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def build(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
          strategy: Optional[S.Strategy] = None,
          tcfg: Optional[TrainStepConfig] = None,
          settings: Optional[M.ModelSettings] = None) -> Bundle:
    strategy = strategy or S.default_strategy(cfg, mesh)
    params_abs = abstract_params(cfg)
    pspecs = S.param_specs(cfg, params_abs, strategy, mesh)
    p_sh = _named(mesh, pspecs)
    batch_abs = input_specs(cfg, shape)
    b_sh = _named(mesh, S.input_specs_sharding(batch_abs, strategy, mesh))
    scalar = NamedSharding(mesh, P())

    if shape.kind == TRAIN:
        tcfg = tcfg or TrainStepConfig()
        if settings is not None:
            tcfg = dataclasses.replace(tcfg, settings=settings)
        opt_abs = abstract_opt_state(tcfg.optimizer, params_abs)
        o_sh = _named(mesh, opt.state_specs(tcfg.optimizer, pspecs))
        # schedule-aware: a mesh with a pipe axis > 1 lowers the 1F1B
        # pipeline step, so compile-backed measurement (and the oracle
        # planner) scores the schedule that will actually run; probe plans
        # the pipeline cannot execute (baseline ladder, micro < pipe) fall
        # back to scan/single on the same mesh instead of erroring
        step_fn = make_train_step(
            cfg, tcfg, mesh=mesh,
            schedule=fallback_schedule(cfg, tcfg, mesh,
                                       global_batch=shape.global_batch))
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        return Bundle(
            fn=step_fn,
            args=(params_abs, opt_abs, batch_abs, step_abs),
            in_shardings=(p_sh, o_sh, b_sh, scalar),
            out_shardings=(p_sh, o_sh, scalar),
            donate_argnums=(0, 1),
            mesh=mesh, strategy=strategy, cfg=cfg, shape=shape)

    settings = settings or M.ModelSettings()
    if shape.kind == PREFILL:
        fn = make_prefill_step(cfg, settings)
        cache_abs = M.init_cache(cfg, shape.global_batch, shape.context,
                                 abstract=True)
        c_sh = _named(mesh, S.cache_specs(cfg, cache_abs, strategy, mesh))
        logits_sh = NamedSharding(
            mesh, S.input_specs_sharding(
                {"tokens": batch_abs["tokens"]}, strategy, mesh)["tokens"])
        args = [params_abs, batch_abs["tokens"]]
        in_sh = [p_sh, b_sh["tokens"]]
        if "prefix_embeds" in batch_abs:
            def step(params, tokens, prefix_embeds, _fn=fn):
                return _fn(params, tokens, shape.context,
                           prefix_embeds=prefix_embeds)
            args.append(batch_abs["prefix_embeds"])
            in_sh.append(b_sh["prefix_embeds"])
        else:
            step = functools.partial(fn, context=shape.context)
        return Bundle(
            fn=step, args=tuple(args), in_shardings=tuple(in_sh),
            out_shardings=(logits_sh, c_sh),
            donate_argnums=(),
            mesh=mesh, strategy=strategy, cfg=cfg, shape=shape)

    if shape.kind == DECODE:
        fn = make_decode_step(cfg, settings)
        cache_abs = M.init_cache(cfg, shape.global_batch, shape.context,
                                 abstract=True)
        c_sh = _named(mesh, S.cache_specs(cfg, cache_abs, strategy, mesh))
        logits_sh = NamedSharding(
            mesh, S.input_specs_sharding(
                {"tokens": batch_abs["tokens"]}, strategy, mesh)["tokens"])

        def step(params, tokens, positions, cache, _fn=fn):
            return _fn(params, tokens, positions, cache,
                       context=shape.context)

        return Bundle(
            fn=step,
            args=(params_abs, batch_abs["tokens"], batch_abs["positions"],
                  cache_abs),
            in_shardings=(p_sh, b_sh["tokens"], b_sh["positions"], c_sh),
            out_shardings=(logits_sh, c_sh),
            donate_argnums=(3,),
            mesh=mesh, strategy=strategy, cfg=cfg, shape=shape)

    raise ValueError(shape.kind)
