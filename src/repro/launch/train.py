"""End-to-end training driver.

WSMC is in the loop: unless knobs are forced, the driver profiles the
workload on a small-shape ladder, classifies it, and applies the planned
memory configuration before the first real step (paper §III-E online phase).

Examples:
  # tiny CPU run (reduced config), 50 steps:
  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --reduced --seq 128 --batch 8 --steps 50

  # ~100M model, a few hundred steps (examples/train_100m.py wraps this):
  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --reduced-100m --seq 512 --batch 8 --steps 200
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeConfig, TRAIN
from repro.core import planner as PL
from repro.core import profiler as PF
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import host_mesh_for
from repro.models import init_params
from repro.optim import optimizers as opt
from repro.parallel import sharding as S
from repro.parallel.axes import axis_rules
from repro.runtime import fault as F
from repro.runtime.train_step import TrainStepConfig, make_train_step


def reduced_100m(cfg):
    """~100M-parameter variant of an arch family (examples deliverable)."""
    return dataclasses.replace(
        cfg.reduced(), name=cfg.name + "-100m",
        d_model=512, head_dim=64, n_heads=8,
        n_kv_heads=min(8, max(1, cfg.n_kv_heads)),
        d_ff=0 if cfg.d_ff == 0 else 2048, vocab_size=32000,
        lru_width=None if cfg.lru_width is None else 512)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--reduced-100m", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-interval", type=int, default=100)
    ap.add_argument("--remat", default="")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--optimizer", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced_100m:
        cfg = reduced_100m(cfg)
    elif args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("train_cli", TRAIN, args.seq, args.batch)

    mesh = host_mesh_for(len(jax.devices()), args.model_parallel)
    strategy = S.default_strategy(cfg, mesh)

    # ---- WSMC online phase (unless fully forced) ------------------------
    if args.remat and args.microbatches and args.optimizer:
        plan = PL.MemoryPlan(remat=args.remat,
                             microbatches=args.microbatches,
                             optimizer=args.optimizer)
        print(f"plan (forced): {plan}")
    else:
        cls = PF.classify_workload(cfg, shape, mesh, n_points=2,
                                   base_seq=min(64, args.seq))
        decision = PL.wsmc_plan(cfg, shape, cls, dict(mesh.shape))
        plan = decision.plan
        if args.remat:
            plan = dataclasses.replace(plan, remat=args.remat)
        if args.microbatches:
            plan = dataclasses.replace(plan, microbatches=args.microbatches)
        if args.optimizer:
            plan = dataclasses.replace(plan, optimizer=args.optimizer)
        print(f"WSMC: {cls.category.value} (alpha={cls.alpha:.2f}, "
              f"inc={cls.inc:.2f}) -> plan {plan} "
              f"capacity={decision.prediction.capacity_bytes/2**20:.0f} MiB")

    tcfg = TrainStepConfig(
        remat=plan.remat, microbatches=plan.microbatches,
        optimizer=opt.OptimizerConfig(kind=plan.optimizer, lr=args.lr),
        warmup_steps=max(args.steps // 10, 1), total_steps=args.steps)

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_state = opt.init_state(tcfg.optimizer, params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,} mesh={dict(mesh.shape)}")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch,
                                    seed=args.seed))

    ckpt_mgr = (F.CheckpointManager(args.ckpt_dir, args.ckpt_interval)
                if args.ckpt_dir else None)
    start_step = 0
    if ckpt_mgr and ckpt_mgr.latest_step() is not None:
        tree = {"params": params, "opt": opt_state}
        tree, manifest = ckpt_mgr.restore(tree)
        params, opt_state = tree["params"], tree["opt"]
        start_step = manifest["extra"].get("step", manifest["step"])
        print(f"resumed from step {start_step}")

    guard = F.PreemptionGuard(install=True)
    watchdog = F.Watchdog()

    def on_metrics(step, m):
        if step % args.log_every == 0:
            print(f"step {step:5d} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e}", flush=True)

    with mesh, axis_rules(strategy.rules(), mesh=mesh):
        t0 = time.time()
        params, opt_state, last, hist = F.run_train_loop(
            train_step=step_fn, params=params, opt_state=opt_state,
            pipeline=pipe, n_steps=args.steps, ckpt_mgr=ckpt_mgr,
            watchdog=watchdog, guard=guard, start_step=start_step,
            on_metrics=on_metrics)
        dt = time.time() - t0
    if hist:
        print(f"done: {last - start_step} steps in {dt:.1f}s "
              f"({dt / max(last - start_step, 1):.2f}s/step), "
              f"final loss {hist[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
