"""End-to-end training driver.

WSMC is in the loop: unless knobs are forced, the driver profiles the
workload on a small-shape ladder, classifies it, and applies the planned
memory configuration before the first real step (paper §III-E online phase).
With `--mesh auto` the mesh itself is a planned output: the driver searches
the runnable mesh_space (data / model / pipe axes), builds the winning mesh
and executes the matching runtime schedule — including the 1F1B pipeline
when the plan says pipe > 1.

Examples:
  # tiny CPU run (reduced config), 50 steps:
  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --reduced --seq 128 --batch 8 --steps 50

  # plan the mesh, then build it (8 fake host devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --arch h2o-danube-1.8b --reduced \
      --depth 4 --seq 64 --batch 8 --steps 10 --mesh auto

  # force a pipelined mesh (pipe=2 stages x data=2):
  ... --mesh data:2,pipe:2 --microbatches 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeConfig, TRAIN, depth_variant
from repro.core import measure as MM
from repro.core import planner as PL
from repro.core import profiler as PF
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import build_mesh
from repro.models import init_params
from repro.optim import optimizers as opt
from repro.parallel.axes import axis_rules
from repro.runtime import fault as F
from repro.runtime import schedule as SCH
from repro.runtime import schedule_kinds as SK
from repro.runtime.train_step import TrainStepConfig
from repro.search import execplan as XP
from repro.search import space as SP
from repro.search import strategies as ST


def reduced_100m(cfg):
    """~100M-parameter variant of an arch family (examples deliverable)."""
    return dataclasses.replace(
        cfg.reduced(), name=cfg.name + "-100m",
        d_model=512, head_dim=64, n_heads=8,
        n_kv_heads=min(8, max(1, cfg.n_kv_heads)),
        d_ff=0 if cfg.d_ff == 0 else 2048, vocab_size=32000,
        lru_width=None if cfg.lru_width is None else 512)


def fit_microbatches(cfg, plan, mesh_shape: dict, batch: int):
    """Clamp the plan's microbatch count to what the mesh can execute: it
    must divide the global batch and — on pipe meshes — satisfy the shared
    1F1B executability predicate (schedule_kinds.pipeline_problems: fill
    the pipeline, per-microbatch batch divisible by the data axes; the
    flat scan schedule needs only batch divisibility). Planned results
    from mesh_space already satisfy this — forced meshes, CLI overrides,
    and the staged/exhaustive paper-space strategies (which skip the
    fastest-first dp filter) may not. Prefers the nearest valid value to
    the planned one."""
    pipe = max(int(mesh_shape.get("pipe", 1)), 1)

    def ok(m):
        if batch % m:
            return False
        if pipe <= 1:
            return True
        return SK.pipeline_executable(cfg, m, mesh_shape, batch)

    m0 = max(plan.microbatches, 1)
    if ok(m0):
        return plan
    fits = [m for m in range(1, batch + 1) if ok(m)]
    if not fits:
        why = "; ".join(SK.pipeline_problems(cfg, m0, mesh_shape, batch))
        raise ValueError(f"global batch {batch} cannot run on mesh "
                         f"{mesh_shape}: {why}")
    micro = min(fits, key=lambda m: (abs(m - m0), m))
    print(f"note: adjusted microbatches {m0} -> {micro} to fit "
          f"pipe={pipe}, batch={batch}")
    return dataclasses.replace(plan, microbatches=micro)


def parse_mesh(spec: str) -> dict:
    """'data:2,pipe:2' -> {'data': 2, 'pipe': 2}. Unknown axis names are
    rejected — a typo ('pip:2') would otherwise train on a silently inert
    axis."""
    from repro.launch.mesh import CANONICAL_AXES
    out = {}
    for part in spec.split(","):
        axis, sep, n = part.partition(":")
        axis = axis.strip()
        if not sep or not n.strip().isdigit() or int(n) < 1:
            raise ValueError(f"bad --mesh entry {part!r}; want axis:size "
                             "with size >= 1")
        if axis not in CANONICAL_AXES:
            raise ValueError(f"unknown mesh axis {axis!r}; "
                             f"known: {CANONICAL_AXES}")
        if axis in out:
            raise ValueError(f"duplicate mesh axis {axis!r} in {spec!r}")
        out[axis] = int(n)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--reduced-100m", action="store_true")
    ap.add_argument("--depth", type=int, default=0,
                    help="override depth to N unit repeats (pipeline stages "
                         "split the repeats: pick a multiple of pipe)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="",
                    help="'' = legacy (data, model) host mesh from "
                         "--model-parallel; 'auto' = search mesh_space and "
                         "build the planned mesh (pipe included); "
                         "'data:2,pipe:2' = forced mesh")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--strategy", default="fastest",
                    choices=list(ST.CLI_STRATEGIES),
                    help="plan-search strategy for the WSMC online phase")
    ap.add_argument("--backend", default="simulate",
                    choices=["simulate", "compile"],
                    help="memory-measurement backend for the profiling "
                         "ladder; simulate = zero planning compiles")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-interval", type=int, default=100)
    ap.add_argument("--remat", default="")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--optimizer", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced_100m:
        cfg = reduced_100m(cfg)
    elif args.reduced:
        cfg = cfg.reduced()
    if args.depth:
        cfg = depth_variant(cfg, args.depth)
    shape = ShapeConfig("train_cli", TRAIN, args.seq, args.batch)
    devices = jax.devices()

    forced_plan = None
    if args.remat and args.microbatches and args.optimizer:
        forced_plan = PL.MemoryPlan(remat=args.remat,
                                    microbatches=args.microbatches,
                                    optimizer=args.optimizer)

    def apply_overrides(plan):
        if args.remat:
            plan = dataclasses.replace(plan, remat=args.remat)
        if args.microbatches:
            plan = dataclasses.replace(plan, microbatches=args.microbatches)
        if args.optimizer:
            plan = dataclasses.replace(plan, optimizer=args.optimizer)
        return plan

    if args.mesh and args.model_parallel != 1:
        print("note: --model-parallel only shapes the legacy host mesh; "
              "with --mesh the model axis comes from the plan/spec")

    # ---- WSMC online phase: plan (and possibly the mesh) ----------------
    if args.mesh == "auto":
        # mesh is a planned OUTPUT: classify compile-free, search the
        # runnable mesh_space, promote the winner to an ExecutionPlan
        if args.backend == "compile":
            print("note: --mesh auto plans with the compile-free simulator; "
                  "--backend compile only affects fixed-mesh planning")
        cls, eplan = XP.auto_plan(cfg, shape, n_devices=len(devices),
                                  strategy=args.strategy,
                                  base_seq=min(64, args.seq))
        plan = fit_microbatches(cfg, apply_overrides(eplan.plan),
                                eplan.mesh_shape, args.batch)
        if plan != eplan.plan:
            eplan = dataclasses.replace(
                eplan, plan=plan,
                schedule=SCH.schedule_kind(TRAIN, plan.microbatches,
                                           eplan.pipe))
        print(f"WSMC[auto]: {cls.category.value} (alpha={cls.alpha:.2f}, "
              f"inc={cls.inc:.2f}) -> {eplan.describe()}")
        mesh, strategy = eplan.build(devices)
    else:
        if args.mesh:
            mesh_shape = parse_mesh(args.mesh)
        else:
            mesh_shape = XP.host_execution(cfg, shape, PL.MemoryPlan(),
                                           len(devices),
                                           args.model_parallel).mesh_shape
        mesh = build_mesh(mesh_shape, devices)
        if forced_plan is not None:
            # the CLI has no kv flag: resolve the cache layout against the
            # mesh's model axis like default_strategy always did
            plan = dataclasses.replace(
                forced_plan,
                kv_shard=SP.kv_auto(cfg, int(mesh_shape.get("model", 1))))
            policy = "forced"
            print(f"plan (forced): {plan}")
        else:
            if args.backend == "simulate":
                measurer = MM.SimulatedMeasurer(mesh_shape)
            else:
                measurer = MM.CompileMeasurer(mesh)
            cls = PF.classify_workload(cfg, shape, mesh, n_points=2,
                                       base_seq=min(64, args.seq),
                                       measurer=measurer)
            res = ST.plan_for(cfg, shape, cls, mesh_shape,
                              strategy=args.strategy, measurer=measurer)
            plan = apply_overrides(res.plan)
            policy = res.policy
            print(f"WSMC[{args.strategy}/{args.backend}]: "
                  f"{cls.category.value} (alpha={cls.alpha:.2f}, "
                  f"inc={cls.inc:.2f}) -> plan {plan} "
                  f"{res.describe_outcome()}")
        plan = fit_microbatches(cfg, plan, mesh_shape, args.batch)
        eplan = XP.for_mesh(cfg, shape, plan, mesh_shape, policy=policy)
        strategy = eplan.strategy()
        print(f"execution: {eplan.describe()}")

    plan = eplan.plan
    tcfg = TrainStepConfig(
        remat=plan.remat, microbatches=plan.microbatches,
        optimizer=opt.OptimizerConfig(kind=plan.optimizer, lr=args.lr),
        warmup_steps=max(args.steps // 10, 1), total_steps=args.steps)

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_state = opt.init_state(tcfg.optimizer, params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,} mesh={dict(mesh.shape)} "
          f"schedule={eplan.schedule}")

    step_fn = jax.jit(
        SCH.make_train_step(cfg, tcfg, mesh=mesh, schedule=eplan.schedule),
        donate_argnums=(0, 1))
    data_pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=args.seq,
                                         global_batch=args.batch,
                                         seed=args.seed))

    ckpt_mgr = (F.CheckpointManager(args.ckpt_dir, args.ckpt_interval)
                if args.ckpt_dir else None)
    start_step = 0
    if ckpt_mgr and ckpt_mgr.latest_step() is not None:
        tree = {"params": params, "opt": opt_state}
        tree, manifest = ckpt_mgr.restore(tree)
        params, opt_state = tree["params"], tree["opt"]
        start_step = manifest["extra"].get("step", manifest["step"])
        print(f"resumed from step {start_step}")

    guard = F.PreemptionGuard(install=True)
    watchdog = F.Watchdog()

    def on_metrics(step, m):
        if step % args.log_every == 0:
            print(f"step {step:5d} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e}", flush=True)

    with mesh, axis_rules(strategy.rules(), mesh=mesh):
        t0 = time.time()
        params, opt_state, last, hist = F.run_train_loop(
            train_step=step_fn, params=params, opt_state=opt_state,
            pipeline=data_pipe, n_steps=args.steps, ckpt_mgr=ckpt_mgr,
            watchdog=watchdog, guard=guard, start_step=start_step,
            on_metrics=on_metrics)
        dt = time.time() - t0
    if hist:
        print(f"done: {last - start_step} steps in {dt:.1f}s "
              f"({dt / max(last - start_step, 1):.2f}s/step), "
              f"final loss {hist[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
