"""Capacity prediction model — paper Eqs. 6-11 mapped to the TPU memory
model (DESIGN.md §2). Every term is "retrievable" (closed-form from config
and sharding) except the transient slope, which the online profiler fits
from the small-shape ladder — exactly the paper's split between config
parameters and the profiled Data Expansion Ratio.

Two transient modes:
  paper  — Eq. 6 verbatim: pred_temp = factor_shuf(category) × Data_input
  fitted — beyond-paper: slope·Data_input + intercept from the ladder fit,
           with the category factor replaced by a 15% safety margin.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro import hw as HW
from repro.configs.base import (DECODE, PREFILL, TRAIN, ModelConfig,
                                ShapeConfig, block_param_count, param_count)
from repro.core.classifier import Classification
from repro.core.expansion import BYTES_ACT, embedded_input_bytes

BYTES_PARAM = 2       # bf16 params
BYTES_GRAD_ACC = 4    # f32 gradient accumulator
BYTES_TOKEN = 4       # int32 ids

# Empirical remat transient scalers (validated in benchmarks/fig2): fraction
# of the no-remat transient that survives under each policy.
REMAT_SCALE = {"none": 1.0, "dots": 0.55, "full": 0.30}
FITTED_SAFETY = 1.15

# Paged-KV storage codecs: bytes per K/V element under each kv_quant mode.
# The dtype is EXPLICIT here (not inferred from the model dtype) so the
# predictor, the allocator's byte ledger, and the pool layout in
# runtime/serve_step.init_paged_pool can never silently disagree. int4
# packs two elements per byte (head_dim is even on every config).
KV_QUANTS = ("none", "int8", "int4")
KV_ELEM_BYTES = {"none": float(BYTES_ACT), "int8": 1.0, "int4": 0.5}
KV_SCALE_BYTES = 4    # f32 absmax scale per (position, kv head), K and V each


def kv_elem_bytes(kv_quant: str) -> float:
    """Bytes per stored K/V element for a kv_quant mode (excl. scales)."""
    if kv_quant not in KV_ELEM_BYTES:
        raise ValueError(f"unknown kv_quant {kv_quant!r}; known: {KV_QUANTS}")
    return KV_ELEM_BYTES[kv_quant]


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    """The configuration surface the planner searches (the analogue of
    spark.executor.memory + parallelism knobs)."""
    remat: str = "none"              # none | dots | full
    microbatches: int = 1
    optimizer: str = "adamw_f32"     # adamw_f32 | adamw_bf16 | adafactor
    kv_shard: str = "heads"          # heads | seq
    # Paged-KV serving: positions per KV block (0 = whole-sequence ring
    # slots). Only full-context attention layers page; the block size is the
    # allocation granule the serving engine's BlockAllocator hands out.
    kv_block_size: int = 0
    # Paged-pool storage codec: "none" (bf16), "int8" or "int4" — per-token
    # per-head absmax scales stored alongside the pool. A quantized block is
    # a CHEAPER block, multiplying serving_block_capacity directly.
    kv_quant: str = "none"
    # Block-granular retention: keep at most this many attended KV blocks
    # per sequence (0 = keep all). The engine evicts the coldest blocks back
    # to the allocator free list, so a sequence's steady-state footprint is
    # bounded by kv_retain + 1 blocks (retained + the growing tail block).
    kv_retain: int = 0

    def __post_init__(self):
        if self.kv_quant not in KV_QUANTS:
            raise ValueError(f"MemoryPlan.kv_quant {self.kv_quant!r} not in "
                             f"{KV_QUANTS}")
        if self.kv_retain < 0:
            raise ValueError("MemoryPlan.kv_retain must be >= 0, got "
                             f"{self.kv_retain}")

    @property
    def opt_state_bytes(self) -> float:
        return {"adamw_f32": 8.0, "adamw_bf16": 4.0,
                "adafactor": 0.05}[self.optimizer]

    def step_time_penalty(self) -> float:
        """Relative step-time cost (roofline-validated ordering): remat
        recomputes ~the forward pass; microbatching adds per-step overhead;
        adafactor adds reduction work."""
        remat_pen = {"none": 1.0, "dots": 1.18, "full": 1.33}[self.remat]
        micro_pen = 1.0 + 0.015 * max(self.microbatches.bit_length() - 1, 0)
        opt_pen = {"adamw_f32": 1.0, "adamw_bf16": 1.0,
                   "adafactor": 1.03}[self.optimizer]
        return remat_pen * micro_pen * opt_pen


@dataclasses.dataclass(frozen=True)
class CapacityPrediction:
    resident_bytes: float
    transient_bytes: float
    capacity_bytes: float            # Eq. 11
    fits: bool
    hbm_bytes: float

    @property
    def utilization(self) -> float:
        return self.capacity_bytes / self.hbm_bytes


def mesh_factors(mesh_shape: dict) -> Tuple[int, int, int]:
    """(weight_shards, dp_size, model_size) from a mesh {axis: size} dict.
    A "pipe" axis splits the layer stack across pipeline stages, so each
    device holds 1/pipe of the weights — it multiplies the shard count."""
    data = mesh_shape.get("data", 1)
    model = mesh_shape.get("model", 1)
    pod = mesh_shape.get("pod", 1)
    pipe = mesh_shape.get("pipe", 1)
    return data * model * pipe, pod * data, model


def _attn_ring_bytes(cfg: ModelConfig, plan: MemoryPlan, L: int,
                     model: int, kv_quant: str = "none") -> float:
    """One sequence's ring-cache bytes for an attention layer of ring
    length L, per device under the plan's kv sharding. `kv_quant` names the
    storage codec EXPLICITLY (only the paged pool quantizes; lane rings and
    ring-slot engines stay bf16), so element size is never inferred from
    the model dtype."""
    hd = cfg.resolved_head_dim
    if plan.kv_shard == "seq":
        L = -(-L // model)
        kvh = cfg.n_kv_heads
    else:
        kvh = -(-cfg.n_kv_heads // model)      # padded uneven sharding
    eb = kv_elem_bytes(kv_quant)
    # f32 absmax scale per (position, kv head) for K and V each
    scales = 0.0 if kv_quant == "none" else 2 * kvh * KV_SCALE_BYTES
    return 2 * L * kvh * hd * eb + L * scales + L * 4     # K/V + pos buffer


def _seq_cache_terms(cfg: ModelConfig, shape: ShapeConfig, plan: MemoryPlan,
                     mesh_shape: dict) -> Tuple[float, float]:
    """(paged_bytes, lane_bytes) for ONE decoding sequence, per device.

    `paged_bytes` is the full-context attention state the paged KV pool can
    allocate block-by-block (layers whose ring spans the whole context);
    `lane_bytes` is everything a sequence pins for its whole lifetime
    regardless of progress: recurrent states and short windowed/chunked
    rings (cheap, fixed-size — paging them would buy nothing).
    """
    _, _, model = mesh_factors(mesh_shape)
    paged = lane = 0.0
    for blk in cfg.blocks():
        if blk.is_attn:
            L = blk.cache_len(shape.context)
            full = L == shape.context
            # full-context layers live in the (possibly quantized) paged
            # pool when the plan pages; short windowed rings stay bf16
            quant = plan.kv_quant if (full and plan.kv_block_size) else "none"
            bytes_ = _attn_ring_bytes(cfg, plan, L, model, kv_quant=quant)
            if full:
                paged += bytes_
            else:
                lane += bytes_
        elif blk.mixer == "mlstm":
            inner = int(cfg.mlstm_proj_factor * cfg.d_model)
            dh = inner // cfg.n_heads
            lane += cfg.n_heads * (dh * dh + dh + 1) * 4
            lane += (cfg.mlstm_conv_width - 1) * inner * BYTES_ACT
        elif blk.mixer == "slstm":
            lane += 4 * cfg.d_model * 4
        elif blk.mixer == "rglru":
            w = cfg.lru_width or cfg.d_model
            lane += w * 4
            lane += (cfg.conv_width - 1) * w * BYTES_ACT
    # pipeline stages each hold the caches of their own 1/pipe of the layers
    pipe = max(int(mesh_shape.get("pipe", 1)), 1)
    return paged / pipe, lane / pipe


def cache_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                           plan: MemoryPlan, mesh_shape: dict) -> float:
    """Decode-resident state: ring KV caches + recurrent states (Eq. 7's
    'data kept in Storage Memory' for the serving stages)."""
    if shape.kind != DECODE:
        return 0.0
    _, dp, _ = mesh_factors(mesh_shape)
    batch_per = max(shape.global_batch // dp, 1)
    paged, lane = _seq_cache_terms(cfg, shape, plan, mesh_shape)
    return batch_per * (paged + lane)


def kv_block_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                              plan: MemoryPlan, mesh_shape: dict) -> float:
    """Bytes of ONE paged KV block per device: `kv_block_size` positions of
    K/V (+ the pos stripe) across every full-context attention layer, under
    the plan's kv sharding. The block-size term of the paper's requirement
    model made first-class: a sequence's paged footprint is
    ceil(written_positions / kv_block_size) of these, instead of a
    whole-context ring."""
    if plan.kv_block_size < 1:
        raise ValueError("kv_block_bytes_per_device needs "
                         f"plan.kv_block_size >= 1, got {plan.kv_block_size}")
    _, _, model = mesh_factors(mesh_shape)
    pipe = max(int(mesh_shape.get("pipe", 1)), 1)
    total = 0.0
    for blk in cfg.blocks():
        if blk.is_attn and blk.cache_len(shape.context) == shape.context:
            total += _attn_ring_bytes(cfg, plan, plan.kv_block_size, model,
                                      kv_quant=plan.kv_quant)
    return total / pipe


def lane_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                          plan: MemoryPlan, mesh_shape: dict) -> float:
    """Per-active-sequence fixed bytes under paged KV: the non-paged cache
    state one decode lane pins (recurrent states, short windowed rings)."""
    _, lane = _seq_cache_terms(cfg, shape, plan, mesh_shape)
    return lane


def sharded_param_count(cfg: ModelConfig, mesh_shape: dict) -> float:
    """Per-device parameter count under the mesh. The pipe axis splits only
    the stacked unit layers (what the 1F1B runtime's split_stages owns);
    embedding, head, final norm and tail blocks are replicated across
    stages — validated against the executed pipeline's compile-measured
    residents."""
    shards, _, _ = mesh_factors(mesh_shape)      # data * model * pipe
    n = param_count(cfg)
    pipe = max(int(mesh_shape.get("pipe", 1)), 1)
    if pipe == 1:
        return n / shards
    unit_n = sum(block_param_count(cfg, b) for b in cfg.unit) * cfg.repeats
    return unit_n / shards + (n - unit_n) / (shards // pipe)


def pipeline_would_execute(cfg: ModelConfig, plan: MemoryPlan,
                           mesh_shape: dict,
                           global_batch: Optional[int] = None) -> bool:
    """Whether a pipe>1 mesh actually runs the 1F1B schedule for this
    (cfg, plan, batch). Shares runtime.schedule_kinds.pipeline_executable
    with validate_pipeline and launch.compile's fallback: non-executable
    probes (micro < pipe, MoE units, indivisible repeats, prefix embeds,
    TP in play, batch/dp indivisible) fall back to scan/single on the same
    mesh, and the memory model must follow. schedule_kinds is jax-free, so
    this import keeps the compile-free planning path light."""
    from repro.runtime.schedule_kinds import pipeline_executable
    return pipeline_executable(cfg, plan.microbatches, mesh_shape,
                               global_batch)


def resident_bytes(cfg: ModelConfig, shape: ShapeConfig, plan: MemoryPlan,
                   mesh_shape: dict) -> float:
    """Eq. 7 analogue: everything that must sit in HBM before the first
    'stage' runs — params, optimizer state, grad accumulator, inputs, caches."""
    _, dp, _ = mesh_factors(mesh_shape)
    n_per = sharded_param_count(cfg, mesh_shape)
    total = n_per * BYTES_PARAM
    if shape.kind == TRAIN:
        total += n_per * plan.opt_state_bytes
        if (plan.microbatches > 1
                and not pipeline_would_execute(cfg, plan, mesh_shape,
                                               shape.global_batch)):
            # the scan schedule carries an f32 gradient accumulator; the
            # 1F1B pipeline schedule accumulates inside the pipelined
            # backward instead, so no extra resident
            total += n_per * BYTES_GRAD_ACC
    batch_per = max(shape.global_batch // dp, 1)
    toks = batch_per * (1 if shape.kind == DECODE else shape.seq_len)
    total += toks * BYTES_TOKEN * (2 if shape.kind == TRAIN else 1)
    if cfg.n_prefix_embeds and shape.kind != DECODE:
        total += batch_per * cfg.n_prefix_embeds * cfg.d_model * BYTES_ACT
    total += cache_bytes_per_device(cfg, shape, plan, mesh_shape)
    return total


def transient_bytes(cfg: ModelConfig, shape: ShapeConfig, plan: MemoryPlan,
                    cls: Classification, mesh_shape: dict,
                    mode: str = "paper",
                    factors: Optional[dict] = None) -> float:
    """Eq. 6: the shuffle-data prediction, per device, for the plan's
    microbatch slice. The profiled α/slope is per *stage* (expansion.py);
    live stages multiply back in — remat controls how many residual sets
    survive, microbatching shrinks the per-stage slice. The classification
    comes from the baseline plan; knobs apply analytically."""
    _, dp, _ = mesh_factors(mesh_shape)
    data_input = embedded_input_bytes(cfg, shape, 0, dp)
    per_micro = data_input / max(plan.microbatches, 1)
    n_stages = cfg.n_layers
    pipe = int(mesh_shape.get("pipe", 1))
    if pipe > 1:
        # each pipeline stage holds 1/pipe of the layers, with up to `pipe`
        # microbatches in flight (1F1B) keeping their activations live
        n_stages = -(-cfg.n_layers // pipe) * min(max(plan.microbatches, 1),
                                                  pipe)
    if mode == "paper":
        # Eq. 6 per stage. The factor table is the paper's Table III —
        # *calibrated on this platform* by the offline phase when available
        # (the paper likewise derived {4,3,2,1} empirically on SparkBench).
        factor = cls.factor
        if factors:
            factor = factors.get(cls.category.value,
                                 factors.get(cls.category, factor))
        pred = factor * per_micro * n_stages
    elif mode == "fitted":
        pred = (cls.slope * per_micro + cls.intercept) * FITTED_SAFETY
    else:
        raise ValueError(mode)
    if shape.kind == TRAIN:
        pred *= REMAT_SCALE[plan.remat]
    return pred


PREFILL_KERNELS = ("dense", "tiled")


def prefill_transient_bytes(cfg: ModelConfig, shape: ShapeConfig,
                            plan: MemoryPlan, cls: Classification,
                            mesh_shape: dict, *, prefill_tokens: int,
                            reach: int, width: int = 1,
                            kernel: str = "dense", mode: str = "paper",
                            factors: Optional[dict] = None) -> float:
    """Transient of one chunked-prefill tick appending `prefill_tokens`
    prompt positions (summed over the `width` mid-prefill lanes the token
    budget admits) that attend up to `reach` earlier positions.

    Both kernels pay Eq. 6 on the chunk itself (a prefill-shaped input of
    `prefill_tokens` positions). The DENSE jnp path additionally
    materializes, per full-context attention layer step, the f32 score
    matrix (q_heads × tokens × reach) plus a dequantized fp gather of each
    lane's attended ring (reach × kv_heads × head_dim) — O(tokens × reach)
    HBM the tiled flash kernel never allocates: it streams K/V block tiles
    through VMEM with an online softmax, so its only extra state is
    O(tokens × head_dim) accumulators, already inside the Eq. 6 term.
    Layers run sequentially (lax.scan body), so one layer's score matrix
    is live at the peak, not n_layers of them.
    """
    if kernel not in PREFILL_KERNELS:
        raise ValueError(f"unknown prefill kernel {kernel!r}; known: "
                         f"{PREFILL_KERNELS}")
    _, dp, model = mesh_factors(mesh_shape)
    sh_p = dataclasses.replace(shape, kind=PREFILL, global_batch=dp,
                               seq_len=max(int(prefill_tokens), 1))
    base = transient_bytes(cfg, sh_p, plan, cls, mesh_shape, mode, factors)
    if kernel == "tiled":
        return base
    qh = -(-cfg.n_heads // model)
    kvh = -(-cfg.n_kv_heads // model)
    hd = cfg.resolved_head_dim
    scores = qh * prefill_tokens * reach * 4.0
    gathered = max(int(width), 1) * reach * kvh * hd * 4.0
    return base + scores + gathered


def predict(cfg: ModelConfig, shape: ShapeConfig, plan: MemoryPlan,
            cls: Classification, mesh_shape: dict, mode: str = "paper",
            hw: HW.HardwareSpec = HW.TPU_V5E,
            factors: Optional[dict] = None) -> CapacityPrediction:
    res = resident_bytes(cfg, shape, plan, mesh_shape)
    tra = transient_bytes(cfg, shape, plan, cls, mesh_shape, mode, factors)
    cap = HW.capacity_from_requirement(res, tra, hw)     # Eq. 11
    return CapacityPrediction(resident_bytes=res, transient_bytes=tra,
                              capacity_bytes=cap, fits=cap <= hw.hbm_bytes,
                              hbm_bytes=hw.hbm_bytes)


def serving_capacity(cfg: ModelConfig, shape: ShapeConfig, plan: MemoryPlan,
                     cls: Classification, mesh_shape: dict,
                     mode: str = "paper", hw: HW.HardwareSpec = HW.TPU_V5E,
                     hbm_budget: Optional[float] = None,
                     factors: Optional[dict] = None,
                     max_per_device: int = 1 << 16) -> int:
    """Eq. 11 run backwards: the serving-side inverse of `predict`.

    The forward model answers "how much memory does a workload of batch B
    need?"; online serving asks the inverse — "given this HBM budget, how
    many concurrent sequences can be admitted?". Because every batch-scaled
    term (KV/recurrent caches via cache_bytes_per_device, token inputs,
    decode transients) is monotone in the per-device batch, the inverse is
    an exact search over whole per-device sequence slots: the largest
    `per` whose predicted capacity (resident + transient, Eq. 11 headroom
    included) still fits `hbm_budget`. Returns the GLOBAL concurrent
    sequence count (per-device slots x dp); 0 if even one sequence per
    device does not fit.
    """
    budget = hw.hbm_bytes if hbm_budget is None else float(hbm_budget)
    _, dp, _ = mesh_factors(mesh_shape)

    def fits(per: int) -> bool:
        sh = dataclasses.replace(shape, kind=DECODE, global_batch=per * dp)
        pred = predict(cfg, sh, plan, cls, mesh_shape, mode, hw, factors)
        return pred.capacity_bytes <= budget

    if not fits(1):
        return 0
    lo, hi = 1, 2
    while hi < max_per_device and fits(hi):
        lo, hi = hi, hi * 2
    if hi >= max_per_device:
        if fits(max_per_device):             # saturated: report the cap
            return max_per_device * dp
        hi = max_per_device
    while hi - lo > 1:                       # invariant: fits(lo), not fits(hi)
        mid = (lo + hi) // 2
        lo, hi = (mid, hi) if fits(mid) else (lo, mid)
    return lo * dp


def serving_block_capacity(cfg: ModelConfig, shape: ShapeConfig,
                           plan: MemoryPlan, cls: Classification,
                           mesh_shape: dict, *, lanes: int = 1,
                           mode: str = "paper",
                           hw: HW.HardwareSpec = HW.TPU_V5E,
                           hbm_budget: Optional[float] = None,
                           factors: Optional[dict] = None,
                           avg_context: Optional[int] = None,
                           decode_width: Optional[int] = None,
                           admission: str = "optimistic",
                           prefill_tokens: int = 0,
                           prefill_kernel: str = "dense",
                           prefill_width: int = 1,
                           max_per_device: int = 1 << 22) -> int:
    """Eq. 11 run backwards over KV BLOCKS instead of whole-sequence slots.

    `serving_capacity` answers "how many worst-case sequences fit?" — every
    admitted sequence is charged a full-context ring. Under paged KV the
    question splits: `lanes` decode lanes pin their fixed per-sequence state
    (recurrent caches, short windowed rings, token buffers, decode
    transients at batch = lanes), and the remaining budget holds KV blocks
    of `plan.kv_block_size` positions each. Because the block term is
    monotone, the inverse is an exact doubling + bisection search over
    whole per-device blocks. Returns the GLOBAL block count (per-device
    blocks x dp); 0 if the lanes alone do not fit.

    `avg_context` is the expected attended context per lane (the trace's
    mean written length): paged decode reads the cache THROUGH block
    tables, so a lane's transient working set is the blocks it actually
    allocated, not the pool-wide max context the ring engine's padded
    decode streams. Defaults to worst-case `shape.context`.

    `decode_width` models lane compaction: a compacting engine runs its
    decode step at the bucketed width covering the ACTIVE lanes, so the
    step transient scales with that width, not the pool width — lane-fixed
    resident state stays charged at `lanes` above. Defaults to `lanes`
    (full-width decode).

    `admission` names the engine reservation discipline the inversion
    assumes. "optimistic" (default, matches every pre-existing call site)
    honors the workload-specific `avg_context` / `decode_width` discounts
    — the expected-occupancy inversion that pairs with
    `BlockAllocator(reservation="expected")` and eviction-on-miss.
    "worst" charges the transient at full context and pool width
    regardless, the deadlock-free-by-construction sizing for
    `reservation="worst"` engines where a prediction miss has no eviction
    path to fall back on.

    `prefill_tokens` > 0 makes the prefill transient a first-class term:
    a chunked engine's ticks alternate decode steps with prefill chunks
    of up to that many prompt tokens (the engine's token budget, spread
    over `prefill_width` lanes), and the charged transient is the MAX of
    the two — whichever tick shape peaks governs the headroom Eq. 11
    must hold back. `prefill_kernel` picks the prefill cost model:
    "dense" charges the O(tokens × context) score matrix the jnp SDPA
    fallback materializes; "tiled" the fused flash-prefill kernel's
    O(tokens × d) working set (see prefill_transient_bytes) — at tight
    budgets the tiled term frees headroom that converts into more
    admitted blocks/lanes.
    """
    if plan.kv_block_size < 1:
        raise ValueError("serving_block_capacity needs a paged plan "
                         f"(kv_block_size >= 1, got {plan.kv_block_size})")
    if lanes < 1:
        raise ValueError(f"serving_block_capacity needs lanes >= 1 "
                         f"(got {lanes})")
    if admission not in ("optimistic", "worst"):
        raise ValueError(f"unknown admission mode {admission!r}; known: "
                         "('optimistic', 'worst')")
    if admission == "worst":
        avg_context = None
        decode_width = None
    budget = hw.hbm_bytes if hbm_budget is None else float(hbm_budget)
    _, dp, _ = mesh_factors(mesh_shape)
    sh = dataclasses.replace(shape, kind=DECODE, global_batch=lanes * dp)
    # resident minus the ring-cache term the block pool replaces
    base = (resident_bytes(cfg, sh, plan, mesh_shape)
            - cache_bytes_per_device(cfg, sh, plan, mesh_shape))
    base += lanes * lane_bytes_per_device(cfg, sh, plan, mesh_shape)
    sh_t = sh
    b = plan.kv_block_size
    reach = shape.context
    if avg_context is not None:
        # block-align the expected reach; never beyond the worst case
        reach = min(-(-max(int(avg_context), 1) // b) * b, shape.context)
    if plan.kv_retain > 0:
        # block-granular retention bounds the attended context
        # DETERMINISTICALLY (the engine never holds more than kv_retain
        # live blocks plus the growing tail), so the cap applies even
        # under worst-case admission
        reach = min(reach, (plan.kv_retain + 1) * b)
    if reach != shape.context:
        sh_t = dataclasses.replace(sh_t, seq_len=reach)
    if decode_width is not None:
        w = min(max(int(decode_width), 1), lanes)
        sh_t = dataclasses.replace(sh_t, global_batch=w * dp)
    tra = transient_bytes(cfg, sh_t, plan, cls, mesh_shape, mode, factors)
    if prefill_tokens > 0:
        tra = max(tra, prefill_transient_bytes(
            cfg, shape, plan, cls, mesh_shape,
            prefill_tokens=int(prefill_tokens), reach=reach,
            width=prefill_width, kernel=prefill_kernel,
            mode=mode, factors=factors))
    per_block = kv_block_bytes_per_device(cfg, sh, plan, mesh_shape)

    def fits(nb: int) -> bool:
        cap = HW.capacity_from_requirement(base + nb * per_block, tra, hw)
        return cap <= budget

    if per_block <= 0.0:                     # no full-context attn layers
        return (max_per_device * dp) if fits(0) else 0
    if not fits(1):
        return 0
    lo, hi = 1, 2
    while hi < max_per_device and fits(hi):
        lo, hi = hi, hi * 2
    if hi >= max_per_device:
        if fits(max_per_device):             # saturated: report the cap
            return max_per_device * dp
        hi = max_per_device
    while hi - lo > 1:                       # invariant: fits(lo), not fits(hi)
        mid = (lo + hi) // 2
        lo, hi = (mid, hi) if fits(mid) else (lo, mid)
    return lo * dp


def min_devices(cfg: ModelConfig, shape: ShapeConfig, plan: MemoryPlan,
                cls: Classification, mode: str = "paper",
                hw: HW.HardwareSpec = HW.TPU_V5E,
                model_parallel: int = 16) -> int:
    """Eq. 9 analogue (Num_ex): the smallest device count whose per-device
    capacity fits — the elastic-scaling entry point."""
    for dp in (1, 2, 4, 8, 16, 32, 64, 128):
        mesh_shape = {"data": dp, "model": model_parallel}
        if predict(cfg, shape, plan, cls, mesh_shape, mode, hw).fits:
            return dp * model_parallel
    return -1
