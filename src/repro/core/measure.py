"""Pluggable memory-measurement backends (the paper's two cost regimes).

The paper's pitch is that WSMC predicts a workload's memory requirement
*without* exhaustively running candidate configurations; the expensive
alternative it replaces is compile-and-measure per candidate. This module
makes that split explicit as a `MemoryMeasurer` interface with two
interchangeable backends:

  CompileMeasurer    — the ground truth: AOT-lower + compile the step and
                       read XLA's memory_analysis(). One XLA compile per
                       point (seconds each); what the oracle planner and
                       the parity tests use.
  SimulatedMeasurer  — closed-form analytical estimation from
                       ModelConfig × ShapeConfig × MemoryPlan × mesh:
                       params / optimizer-state / grad-accum residents and
                       decode caches via predictor.resident_bytes, plus a
                       per-stage activation-transient model under each
                       remat / microbatch setting. Zero compiles,
                       microseconds per point — this is what lets the
                       profile → classify → predict → plan pipeline run
                       over hundreds of workload × mesh × plan cells
                       (and lets the fast test tier be hermetic).

Both backends produce the same `expansion.MemoryProfile` record, so every
consumer (profiler ladder, classifier, planner, dry-run, benchmarks) is
backend-agnostic. An on-disk `ProfileCache` keyed by
(arch, shape, plan, mesh, backend) makes repeated ladder points free.
"""
from __future__ import annotations

import abc
import dataclasses
import json
import os
import tempfile
from typing import Callable, Dict, Optional, Union

from repro.configs.base import (DECODE, MLP_DENSE, MLP_MOE, TRAIN,
                                ModelConfig, ShapeConfig)
from repro.core import expansion as E
from repro.core import predictor as PR
from repro.core.predictor import MemoryPlan

# The baseline profiling plan: the slope is measured here and the planner
# scales it analytically for other knob settings (predictor.transient_bytes).
BASELINE_PLAN = MemoryPlan(remat="none", microbatches=1,
                           optimizer="adamw_f32")

# bf16 metric/loss scalars + softmax statistics kept in f32.
BYTES_F32 = 4

MeshLike = Union[dict, object]   # a jax Mesh or a plain {axis: size} dict


def mesh_shape_of(mesh: MeshLike) -> Dict[str, int]:
    """Normalize a jax Mesh (or any .shape mapping holder) to {axis: size}."""
    if isinstance(mesh, dict):
        return dict(mesh)
    return dict(mesh.shape)


def n_devices_of(mesh_shape: Dict[str, int]) -> int:
    n = 1
    for v in mesh_shape.values():
        n *= int(v)
    return n


def dp_size_of(mesh_shape: Dict[str, int]) -> int:
    dp = 1
    for ax in ("pod", "data"):
        dp *= int(mesh_shape.get(ax, 1))
    return dp


# ---------------------------------------------------------------------------
# On-disk profile cache
# ---------------------------------------------------------------------------

def profile_key(backend: str, cfg: ModelConfig, shape: ShapeConfig,
                plan: MemoryPlan, mesh_shape: Dict[str, int],
                settings_tag: str = "default") -> str:
    """Stable cache key over everything that determines a profile."""
    mesh_tag = ",".join(f"{k}={v}" for k, v in sorted(mesh_shape.items()))
    plan_tag = (f"{plan.remat}|m{plan.microbatches}|{plan.optimizer}"
                f"|kv={plan.kv_shard}")
    arch_tag = f"{cfg.name}@{cfg.n_layers}x{cfg.d_model}"
    shape_tag = f"{shape.kind}|s{shape.seq_len}|b{shape.global_batch}"
    return "::".join((backend, arch_tag, shape_tag, plan_tag, mesh_tag,
                      settings_tag))


class ProfileCache:
    """Write-through JSON cache of MemoryProfiles.

    One file; entries keyed by profile_key(). Safe to share between the
    profiler ladder, the dry-run, and benchmarks — a ladder point measured
    once is free everywhere after.
    """

    VERSION = 1

    def __init__(self, path: str):
        self.path = path
        self.hits = 0
        self.misses = 0
        self._data: Dict[str, dict] = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    payload = json.load(f)
                if payload.get("version") == self.VERSION:
                    self._data = payload.get("profiles", {})
            except (OSError, ValueError):
                self._data = {}

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: str) -> Optional[E.MemoryProfile]:
        entry = self._data.get(key)
        if entry is not None:
            try:
                prof = E.MemoryProfile(**entry)
            except TypeError:       # schema drifted under the same version
                self._data.pop(key, None)
            else:
                self.hits += 1
                return prof
        self.misses += 1
        return None

    def put(self, key: str, profile: E.MemoryProfile) -> None:
        self._data[key] = dataclasses.asdict(profile)
        self._flush()

    def _flush(self) -> None:
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"version": self.VERSION, "profiles": self._data},
                          f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise


# ---------------------------------------------------------------------------
# The measurer interface
# ---------------------------------------------------------------------------

class MemoryMeasurer(abc.ABC):
    """One measurement backend bound to one mesh.

    measure() is the single entry point every WSMC consumer goes through;
    the cache wraps it transparently.
    """

    backend: str = "?"
    # Extra cache-key discriminator for measurer-level knobs the plan/settings
    # don't carry (e.g. the simulator's EP mode).
    key_suffix: str = ""

    def __init__(self, mesh: MeshLike, cache: Optional[ProfileCache] = None):
        self.mesh = mesh
        self.mesh_shape = mesh_shape_of(mesh)
        self.cache = cache
        # The compile backend parks its most recent compiled step here so
        # callers that also need cost_analysis() (dry-run roofline flops)
        # don't pay a second compile. None after a cache hit / simulate.
        self.last_compiled = None

    def measure(self, cfg: ModelConfig, shape: ShapeConfig,
                plan: MemoryPlan = BASELINE_PLAN,
                settings=None) -> E.MemoryProfile:
        tag = "default" if settings is None else repr(settings)
        key = profile_key(self.backend, cfg, shape, plan, self.mesh_shape,
                          tag + self.key_suffix)
        self.last_compiled = None   # compile backend refreshes this below
        if self.cache is not None:
            hit = self.cache.get(key)
            if hit is not None:
                return hit
        prof = self._measure(cfg, shape, plan, settings)
        if self.cache is not None:
            self.cache.put(key, prof)
        return prof

    @abc.abstractmethod
    def _measure(self, cfg: ModelConfig, shape: ShapeConfig,
                 plan: MemoryPlan, settings) -> E.MemoryProfile:
        ...

    def measure_peak(self, cfg: ModelConfig, shape: ShapeConfig,
                     plan: MemoryPlan, settings=None) -> float:
        """Static peak bytes/device — the oracle planner's verification
        quantity (argument + transient + output)."""
        return self.measure(cfg, shape, plan, settings).peak_bytes

    def peak_fn(self, cfg: ModelConfig, shape: ShapeConfig,
                settings=None) -> Callable[[MemoryPlan], float]:
        """Adapter for planner.oracle_plan's `measure(plan)` callable."""
        return lambda plan: self.measure_peak(cfg, shape, plan, settings)


class CompileMeasurer(MemoryMeasurer):
    """Ground-truth backend: one XLA compile per point (expensive).

    Extracted from the original profiler.profile_point — AOT-lower the step
    on the real mesh and read memory_analysis().
    """

    backend = "compile"

    def __init__(self, mesh, cache: Optional[ProfileCache] = None):
        if isinstance(mesh, dict):
            raise TypeError("CompileMeasurer needs a real jax Mesh to lower "
                            "against; {axis: size} dicts are only valid for "
                            "SimulatedMeasurer")
        super().__init__(mesh, cache)

    def _measure(self, cfg, shape, plan, settings) -> E.MemoryProfile:
        # Lazy: keep core.measure importable without the launch/runtime
        # stack (the simulator path never needs it).
        from repro.core import profiler as PF
        from repro.launch import compile as LC
        strategy = PF.strategy_for(cfg, plan, self.mesh)
        bundle = LC.build(cfg, shape, self.mesh, strategy=strategy,
                          tcfg=PF._tcfg_for(plan, settings),
                          settings=settings)
        compiled = bundle.compile()
        self.last_compiled = compiled
        return E.profile_from_compiled(
            compiled, cfg, shape, self.mesh.devices.size,
            dp_size_of(self.mesh_shape))


class SimulatedMeasurer(MemoryMeasurer):
    """Analytical backend: closed-form MemoryProfile, zero compiles.

    Residents come from predictor.resident_bytes (params, optimizer state,
    grad accumulator, token inputs, decode KV/recurrent caches — Eq. 7);
    transients from the per-stage activation model below (Eq. 4's numerator),
    scaled by the plan's remat/microbatch knobs exactly as the capacity
    predictor assumes. Accepts a plain {axis: size} dict — no jax mesh (and
    hence no fake-device subprocess) required.

    Mesh axes understood: data/pod (DP), model (TP), and pipe (pipeline
    stages: weights and caches split across stages, 1F1B in-flight
    microbatches keep activations live). `ep=True` models expert-parallel
    MoE sharding (all-to-all dispatch/combine buffers instead of
    intra-expert TP) — a Strategy-level knob the plan doesn't carry, so it
    lives on the measurer and discriminates the cache key.
    """

    backend = "simulate"

    def __init__(self, mesh: MeshLike, cache: Optional[ProfileCache] = None,
                 ep: bool = False):
        super().__init__(mesh, cache)
        self.ep = bool(ep)
        if self.ep:
            self.key_suffix = "|ep"

    def _measure(self, cfg, shape, plan, settings) -> E.MemoryProfile:
        ms = self.mesh_shape
        resident = PR.resident_bytes(cfg, shape, plan, ms)
        transient = simulated_transient_bytes(cfg, shape, plan, ms,
                                              ep=self.ep)
        output = simulated_output_bytes(cfg, shape, ms)
        n_dev = n_devices_of(ms)
        return E.MemoryProfile(
            arch=cfg.name,
            shape_name=shape.name,
            kind=shape.kind,
            n_devices=n_dev,
            seq_len=shape.seq_len,
            global_batch=shape.global_batch,
            n_stages=cfg.n_layers,
            input_bytes=E.embedded_input_bytes(cfg, shape, n_dev,
                                               dp_size_of(ms)),
            argument_bytes=resident,
            transient_bytes=transient,
            output_bytes=output,
            reported_peak=resident + transient + output,
        )


def measurer_for(backend: str, mesh: MeshLike,
                 cache: Optional[ProfileCache] = None) -> MemoryMeasurer:
    """Factory: 'compile' needs a real jax Mesh; 'simulate' takes either a
    Mesh or a plain {axis: size} dict."""
    if backend == "compile":
        return CompileMeasurer(mesh, cache=cache)
    if backend == "simulate":
        return SimulatedMeasurer(mesh, cache=cache)
    raise ValueError(f"unknown measurement backend: {backend!r}")


# ---------------------------------------------------------------------------
# The analytical transient model
# ---------------------------------------------------------------------------

def _tokens_per_device(cfg: ModelConfig, shape: ShapeConfig,
                       mesh_shape: Dict[str, int]) -> float:
    dp = dp_size_of(mesh_shape)
    batch_per = max(shape.global_batch // dp, 1)
    return float(batch_per * (1 if shape.kind == DECODE else shape.seq_len))


def block_transient_bytes(cfg: ModelConfig, blk, toks: float,
                          shape: ShapeConfig,
                          mesh_shape: Dict[str, int],
                          ep: bool = False) -> float:
    """Live activation bytes one block materializes for `toks` tokens on one
    device (bf16 unless noted). This is the simulator's per-stage unit: the
    same quantity expansion.MemoryProfile.stage_transient_bytes estimates
    from a compile."""
    _, _, model = PR.mesh_factors(mesh_shape)
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    A = E.BYTES_ACT
    # Fusion/collective scratch floor (size-independent; dominates only at
    # smoke scale) + residual stream in + post-mixer out.
    total = SCRATCH_PER_BLOCK + 2.0 * toks * d * A
    if blk.is_attn:
        q = cfg.n_heads * hd / model
        kv = cfg.n_kv_heads * hd / model
        total += toks * (q + 2 * kv + q) * A           # q, k, v, attn-out
        # Score/probability rows (softmax stats in f32): each query attends
        # kv_len keys. This is the superlinear term that makes full-attention
        # training Expanding.Rapid (Table II) while windowed/chunked and
        # recurrent mixers stay linear.
        if shape.kind == DECODE:
            kv_len = blk.cache_len(shape.context)
        else:
            kv_len = blk.cache_len(shape.seq_len)
        total += toks * kv_len * (cfg.n_heads / model) * A
    elif blk.mixer == "mlstm":
        inner = int(cfg.mlstm_proj_factor * d)
        # up/z projections + conv + gate pre-activations + down input
        total += toks * (2 * inner + inner) / max(model, 1) * A
        total += toks * 2 * cfg.n_heads * BYTES_F32     # i/f gate scalars
        if shape.kind != DECODE:
            # chunkwise-parallel scan: per-chunk decay/gate matrices
            # (chunk × chunk per head, f32) — toks/chunk chunks of them.
            total += toks * MLSTM_CHUNK * cfg.n_heads * BYTES_F32
    elif blk.mixer == "slstm":
        total += toks * (4 * d + 2 * cfg.slstm_ff_dim / max(model, 1)) * A
    elif blk.mixer == "rglru":
        w = cfg.lru_width or d
        total += toks * (3 * w) / max(model, 1) * A     # x, gate, conv
    if blk.mlp == MLP_DENSE and cfg.d_ff:
        mult = 2 if cfg.activation in ("swiglu", "geglu") else 1
        total += toks * (mult + 1) * cfg.d_ff / model * A
    elif blk.mlp == MLP_MOE:
        mult = 2 if cfg.activation in ("swiglu", "geglu") else 1
        routed = toks * cfg.top_k * cfg.capacity_factor
        # Per-device expert activations are the same bytes either way:
        # intra-expert TP shards d_ff over `model`; EP keeps d_ff whole but
        # each device serves only routed/model tokens (capacity-balanced).
        total += routed * (mult + 1) * cfg.d_ff / model * A
        total += toks * cfg.n_experts * BYTES_F32       # router logits
        if ep:
            # EP adds the all-to-all dispatch + combine buffers: the routed
            # token slices at model width, in and out.
            total += 2 * (routed / max(model, 1)) * d * A
    return total


def head_transient_bytes(cfg: ModelConfig, toks: float,
                         mesh_shape: Dict[str, int], kind: str) -> float:
    """LM-head logits + softmax statistics (once per step, not per stage).
    Training keeps the f32 loss row alongside the bf16 logits."""
    _, _, model = PR.mesh_factors(mesh_shape)
    logits = toks * cfg.padded_vocab_size / model
    per = E.BYTES_ACT + (BYTES_F32 if kind == TRAIN else 0)
    return logits * per


# How many stages' activations are simultaneously live. Training keeps every
# layer's residuals for BPTT (remat then scales them down); inference frees
# layer-by-layer, so only ~2 stages (current + in-flight next) are resident.
INFERENCE_LIVE_STAGES = 2.0
# The backward pass holds activation *gradients* mirroring the forward
# residuals (plus f32 accumulation scratch) — empirically ~1x the live
# forward set on top of it (validated against memory_analysis() in the
# parity tests).
TRAIN_BWD_SCALE = 2.0
# mLSTM chunkwise-parallel scan chunk length (ModelSettings.mlstm_chunk
# default; the simulator has no per-call settings dependence).
MLSTM_CHUNK = 128
# Per-block XLA fusion/collective scratch floor.
SCRATCH_PER_BLOCK = 48 * 1024


def simulated_transient_bytes(cfg: ModelConfig, shape: ShapeConfig,
                              plan: MemoryPlan,
                              mesh_shape: Dict[str, int],
                              ep: bool = False) -> float:
    """Per-device XLA-temp estimate for (cfg, shape) under `plan`."""
    toks_full = _tokens_per_device(cfg, shape, mesh_shape)
    toks = toks_full
    if shape.kind == TRAIN:
        toks /= max(plan.microbatches, 1)
    per_block = [block_transient_bytes(cfg, b, toks, shape, mesh_shape, ep)
                 for b in cfg.blocks()]
    toks_head = toks
    if shape.kind == TRAIN:
        pipe = int(mesh_shape.get("pipe", 1))
        if PR.pipeline_would_execute(cfg, plan, mesh_shape,
                                     shape.global_batch):
            # the executed 1F1B schedule (runtime.schedule) remats each
            # stage body per tick, so what stays live is ONE stage's
            # recompute set (1/pipe of the UNIT stack) plus the scan-saved
            # boundary carries (one inter-stage activation per tick) —
            # validated against the compiled pipeline step on fake devices.
            # Tail blocks, norm, head and the loss run OUTSIDE the stages
            # on the FULL batch (runtime.schedule.make_pipeline_loss_fn),
            # so they keep full-batch token scaling.
            micro = max(plan.microbatches, 1)
            n_unit = len(cfg.unit) * cfg.repeats
            unit_live = (sum(per_block[:n_unit])
                         * PR.REMAT_SCALE[plan.remat] * TRAIN_BWD_SCALE
                         / pipe)
            tail_live = sum(
                block_transient_bytes(cfg, b, toks_full, shape, mesh_shape,
                                      ep)
                for b in cfg.tail) * TRAIN_BWD_SCALE
            live = (unit_live + tail_live
                    + (micro + pipe - 1) * toks * cfg.d_model * E.BYTES_ACT)
            toks_head = toks_full
        else:
            # flat scan/single schedule (also the compile fallback for
            # probe plans a pipe mesh cannot pipeline)
            live = (sum(per_block) * PR.REMAT_SCALE[plan.remat]
                    * TRAIN_BWD_SCALE)
        # plus the remat-recompute scratch of the block currently in bwd
        live += max(per_block, default=0.0)
    else:
        live = max(per_block, default=0.0) * INFERENCE_LIVE_STAGES
        if shape.kind == DECODE:
            # ring-cache update: XLA materializes the updated cache before
            # the donation alias kicks in — a transient copy of the cache.
            live += PR.cache_bytes_per_device(cfg, shape, plan, mesh_shape)
    return live + head_transient_bytes(cfg, toks_head, mesh_shape,
                                       shape.kind)


def simulated_output_bytes(cfg: ModelConfig, shape: ShapeConfig,
                           mesh_shape: Dict[str, int]) -> float:
    """Non-aliased step outputs. Train donates params/opt-state (aliased)
    and returns scalars; prefill returns logits + a freshly built cache;
    decode donates its cache and returns one token row of logits."""
    _, dp, model = PR.mesh_factors(mesh_shape)
    batch_per = max(shape.global_batch // dp, 1)
    if shape.kind == TRAIN:
        return 64.0 * BYTES_F32                        # metric scalars
    logits_rows = batch_per * (1 if shape.kind == DECODE else shape.seq_len)
    out = logits_rows * cfg.padded_vocab_size / model * E.BYTES_ACT
    if shape.kind != DECODE:
        # prefill emits the filled cache as a fresh output
        decode_like = dataclasses.replace(shape, kind=DECODE)
        out += PR.cache_bytes_per_device(cfg, decode_like, BASELINE_PLAN,
                                         mesh_shape)
    return out
