"""Workload classification — the paper's Tables I and II, verbatim.

Four categories over (α, inc):
  Expanding.Rapid   α >= 1, inc >= 2
  Expanding.Medium  α >= 1, inc <  2
  Medium            0.5 < α < 1
  Shrinking         α <= 0.5

Table III capacity factors {4, 3, 2, 1} — kept as the per-category
conservative multipliers (DESIGN.md §9 records the reinterpretation over
the fitted slope for the beyond-paper predictor mode).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

from repro.core import expansion as E


class Category(str, enum.Enum):
    EXPANDING_RAPID = "Expanding.Rapid"
    EXPANDING_MEDIUM = "Expanding.Medium"
    MEDIUM = "Medium"
    SHRINKING = "Shrinking"


# Paper Table III.
FACTOR_SHUF = {
    Category.EXPANDING_RAPID: 4.0,
    Category.EXPANDING_MEDIUM: 3.0,
    Category.MEDIUM: 2.0,
    Category.SHRINKING: 1.0,
}

ALPHA_EXPANDING = 1.0     # Table I
ALPHA_SHRINKING = 0.5     # Table I
INC_RAPID = 2.0           # Table II


@dataclasses.dataclass(frozen=True)
class Classification:
    category: Category
    alpha: float
    inc: float
    slope: float            # fitted bytes/byte (beyond-paper mode)
    intercept: float

    @property
    def factor(self) -> float:
        return FACTOR_SHUF[self.category]


def classify(alpha: float, inc: float) -> Category:
    if alpha >= ALPHA_EXPANDING:
        return (Category.EXPANDING_RAPID if inc >= INC_RAPID
                else Category.EXPANDING_MEDIUM)
    if alpha <= ALPHA_SHRINKING:
        return Category.SHRINKING
    return Category.MEDIUM


def classify_profiles(profiles: Sequence[E.MemoryProfile]) -> Classification:
    alpha = E.mean_expansion_ratio(profiles)
    inc = E.increasing_rate(profiles)
    return Classification(
        category=classify(alpha, inc),
        alpha=alpha,
        inc=inc,
        slope=E.fitted_slope(profiles),
        intercept=E.fitted_intercept(profiles),
    )
