"""Memory Expansion Ratio — the paper's Data Expansion Ratio (Eqs. 2-5)
re-grounded in the XLA memory model (DESIGN.md §2).

  Data_input  -> per-device *embedded input bytes*: the batch tokens this
                 device processes, materialized at model width (the paper's
                 "data loaded into Storage Memory", Eq. 7).
  Data_shuf   -> per-device *transient bytes*: XLA temp allocation — live
                 activations, remat residuals, collective buffers — the
                 intermediate data the workload "shuffles" between its
                 stages (layers/microbatches).
  α           -> per-stage transient / embedded-input            (Eq. 4)
  inc         -> mean Δ(per-stage transient) / Δinput, relative
                 to the base α (dimensionless growth rate)       (Eq. 5)

Stage normalization (DESIGN.md §9): Spark stages execute serially and the
paper takes the max over stages; under BPTT every layer's residuals stay
live simultaneously, so XLA's temp covers *all* stages. We therefore define
the expansion ratio per stage (layer) — temp / (n_stages · input) — keeping
the paper's α thresholds discriminative, and the capacity predictor
multiplies back by the live-stage count (remat controls how many survive).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.configs.base import ModelConfig, ShapeConfig

BYTES_ACT = 2  # bf16 activations


@dataclasses.dataclass(frozen=True)
class MemoryProfile:
    arch: str
    shape_name: str
    kind: str
    n_devices: int
    seq_len: int
    global_batch: int
    n_stages: int             # serial "stages" = layer blocks
    input_bytes: float        # per-device embedded input (α denominator)
    argument_bytes: float     # per-device resident (params+opt+cache+inputs)
    transient_bytes: float    # per-device temp (α numerator, all stages)
    output_bytes: float
    reported_peak: float

    @property
    def peak_bytes(self) -> float:
        """Static peak: resident + transients + outputs (conservative; the
        CPU backend's reported peak ignores arguments)."""
        return self.argument_bytes + self.transient_bytes + self.output_bytes

    @property
    def stage_transient_bytes(self) -> float:
        return self.transient_bytes / max(self.n_stages, 1)

    @property
    def alpha(self) -> float:
        return self.stage_transient_bytes / max(self.input_bytes, 1.0)


def embedded_input_bytes(cfg: ModelConfig, shape: ShapeConfig,
                         n_devices: int, dp_size: int) -> float:
    """Per-device Data_input: the data this step *loads* at model width —
    the token batch for train/prefill, the attended context for decode
    (Eq. 7's 'data loading' stage; the decode step's working set is its
    cache read, exactly as KMeans' was its cached dataset)."""
    batch_per_dp = max(shape.global_batch // max(dp_size, 1), 1)
    toks = batch_per_dp * shape.seq_len   # DECODE: seq_len = context
    per_tok = cfg.d_model * BYTES_ACT
    return float(toks * per_tok)


def profile_from_compiled(compiled, cfg: ModelConfig, shape: ShapeConfig,
                          n_devices: int, dp_size: int) -> MemoryProfile:
    ma = compiled.memory_analysis()
    # peak_memory_in_bytes is a newer-JAX addition; fall back to the static
    # sum (what peak_bytes reports anyway) on older versions.
    reported = getattr(ma, "peak_memory_in_bytes", None)
    if reported is None:
        reported = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                    + ma.output_size_in_bytes)
    return MemoryProfile(
        arch=cfg.name,
        shape_name=shape.name,
        kind=shape.kind,
        n_devices=n_devices,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        n_stages=cfg.n_layers,
        input_bytes=embedded_input_bytes(cfg, shape, n_devices, dp_size),
        argument_bytes=float(ma.argument_size_in_bytes),
        transient_bytes=float(ma.temp_size_in_bytes),
        output_bytes=float(ma.output_size_in_bytes),
        reported_peak=float(reported),
    )


def expansion_ratio(profile: MemoryProfile) -> float:
    """Paper Eq. 4."""
    return profile.alpha


def mean_expansion_ratio(profiles: Sequence[MemoryProfile]) -> float:
    """Paper §III-C: 'the Data Expansion Ratio of the workload is the
    average over the input data set DS'."""
    return sum(p.alpha for p in profiles) / max(len(profiles), 1)


def increasing_rate(profiles: Sequence[MemoryProfile]) -> float:
    """Paper Eq. 5: mean finite-difference slope of (per-stage) transient vs
    input over the ascending ladder, normalized by the base α so inc is the
    dimensionless growth rate: 1 = linear scaling, >= 2 = superlinear
    (Table II's Expanding.Rapid threshold)."""
    ps = sorted(profiles, key=lambda p: p.input_bytes)
    if len(ps) < 2:
        return 1.0
    base_alpha = max(ps[0].alpha, 1e-9)
    slopes = []
    for a, b in zip(ps[:-1], ps[1:]):
        dx = b.input_bytes - a.input_bytes
        if dx <= 0:
            continue
        slopes.append((b.stage_transient_bytes - a.stage_transient_bytes) / dx)
    if not slopes:
        return 1.0
    return (sum(slopes) / len(slopes)) / base_alpha


def fitted_slope(profiles: Sequence[MemoryProfile]) -> float:
    """Least-squares transient = slope·input + const (beyond-paper 'fitted'
    predictor mode); returns slope in bytes/byte."""
    ps = sorted(profiles, key=lambda p: p.input_bytes)
    n = len(ps)
    if n == 1:
        return ps[0].alpha
    xs = [p.input_bytes for p in ps]
    ys = [p.transient_bytes for p in ps]
    mx = sum(xs) / n
    my = sum(ys) / n
    denom = sum((x - mx) ** 2 for x in xs)
    if denom <= 0:
        return ps[0].alpha
    return sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / denom


def fitted_intercept(profiles: Sequence[MemoryProfile]) -> float:
    ps = sorted(profiles, key=lambda p: p.input_bytes)
    slope = fitted_slope(ps)
    n = len(ps)
    return (sum(p.transient_bytes for p in ps)
            - slope * sum(p.input_bytes for p in ps)) / n
