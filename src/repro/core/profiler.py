"""Online workload profiling (paper §III-E) and the offline knowledge base.

Online phase: an ad-hoc workload (arch × shape × kind) is measured on a
*ladder of small shapes* (the paper's 50-250 MB inputs), its per-device
transient/input bytes classified, and the classification handed to the
planner. Measurement goes through a pluggable `core.measure.MemoryMeasurer`:
the compile backend (AOT-compile + memory_analysis(); zero data movement,
compile-time only) or the analytical simulator (closed form, zero compiles).

Offline phase: the same over the benchmark suite (the 10 assigned archs),
persisted as JSON — the paper's Table III knowledge base.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional

from repro.configs.base import DECODE, ModelConfig, ShapeConfig
from repro.core import expansion as E
from repro.core.classifier import Classification, classify_profiles
from repro.core.measure import BASELINE_PLAN, CompileMeasurer, MemoryMeasurer
from repro.core.predictor import MemoryPlan
from repro.models import model as M
from repro.optim.optimizers import OptimizerConfig
from repro.parallel import sharding as S
from repro.runtime.train_step import TrainStepConfig


def ladder_shapes(shape: ShapeConfig, n_points: int = 3,
                  base_seq: int = 512,
                  min_seq: int = 0) -> List[ShapeConfig]:
    """Ascending small-shape ladder of the same kind (paper's input set DS).
    `min_seq` floors the ladder (prefix-embed archs need seq > n_prefix)."""
    while base_seq <= min_seq:
        base_seq *= 2
    out = []
    for i in range(n_points):
        s = min(base_seq * (2 ** i), shape.seq_len)
        if shape.kind == DECODE:
            out.append(dataclasses.replace(shape, name=f"{shape.name}@{s}",
                                           seq_len=max(s, 1024)))
        else:
            out.append(dataclasses.replace(shape, name=f"{shape.name}@{s}",
                                           seq_len=s))
    # dedupe (tiny target shapes collapse the ladder)
    seen, uniq = set(), []
    for sh in out:
        if sh.seq_len not in seen:
            uniq.append(sh)
            seen.add(sh.seq_len)
    return uniq


def _tcfg_for(plan: MemoryPlan, settings: Optional[M.ModelSettings] = None
              ) -> TrainStepConfig:
    return TrainStepConfig(
        remat=plan.remat,
        microbatches=plan.microbatches,
        optimizer=OptimizerConfig(kind=plan.optimizer),
        settings=settings or M.ModelSettings(),
    )


def strategy_for(cfg: ModelConfig, plan: MemoryPlan, mesh) -> S.Strategy:
    base = S.default_strategy(cfg, mesh)
    return dataclasses.replace(base, kv_shard=plan.kv_shard)


def _measurer_or_default(mesh, measurer: Optional[MemoryMeasurer]
                         ) -> MemoryMeasurer:
    """Back-compat default: no explicit measurer means the compile backend
    on the given mesh (the original behaviour of these entry points)."""
    return measurer if measurer is not None else CompileMeasurer(mesh)


def profile_point(cfg: ModelConfig, shape: ShapeConfig, mesh,
                  plan: MemoryPlan = BASELINE_PLAN,
                  settings: Optional[M.ModelSettings] = None,
                  measurer: Optional[MemoryMeasurer] = None
                  ) -> E.MemoryProfile:
    """One measurement -> one MemoryProfile (per-device numbers)."""
    return _measurer_or_default(mesh, measurer).measure(cfg, shape, plan,
                                                        settings)


def profile_ladder(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   plan: MemoryPlan = BASELINE_PLAN,
                   n_points: int = 3, base_seq: int = 512,
                   settings: Optional[M.ModelSettings] = None,
                   measurer: Optional[MemoryMeasurer] = None
                   ) -> List[E.MemoryProfile]:
    m = _measurer_or_default(mesh, measurer)
    min_seq = cfg.n_prefix_embeds if shape.kind != "decode" else 0
    return [m.measure(cfg, sh, plan, settings)
            for sh in ladder_shapes(shape, n_points, base_seq, min_seq)]


def classify_workload(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      plan: MemoryPlan = BASELINE_PLAN,
                      n_points: int = 3, base_seq: int = 512,
                      settings: Optional[M.ModelSettings] = None,
                      measurer: Optional[MemoryMeasurer] = None
                      ) -> Classification:
    return classify_profiles(
        profile_ladder(cfg, shape, mesh, plan, n_points, base_seq, settings,
                       measurer))


# ---------------------------------------------------------------------------
# Offline knowledge base (paper Table III)
# ---------------------------------------------------------------------------

def calibrated_factors(kb: dict) -> Dict[str, float]:
    """Platform-calibrated Table III: per category, the conservative envelope
    (max observed per-stage α across the benchmark suite, +10%) — the same
    empirical procedure the paper used to derive {4,3,2,1} on SparkBench.
    Falls back to the paper's values for unseen categories."""
    from repro.core.classifier import FACTOR_SHUF
    out = {c.value: f for c, f in FACTOR_SHUF.items()}
    seen: Dict[str, float] = {}
    for entry in kb.values():
        cat = entry["category"]
        seen[cat] = max(seen.get(cat, 0.0), float(entry["alpha"]))
    for cat, amax in seen.items():
        out[cat] = max(out[cat], amax * 1.10)
    return out


def build_knowledge_base(entries: Dict[str, Classification]) -> dict:
    return {
        name: {
            "category": cls.category.value,
            "alpha": cls.alpha,
            "inc": cls.inc,
            "slope": cls.slope,
            "intercept": cls.intercept,
            "factor": cls.factor,
        }
        for name, cls in entries.items()
    }


def save_knowledge_base(path: str, kb: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(kb, f, indent=2, sort_keys=True)


def load_knowledge_base(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
