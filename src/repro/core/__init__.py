"""WSMC — the paper's contribution: workload-specific memory capacity
configuration via expansion-ratio profiling, classification, closed-form
capacity prediction, and knob planning (Liang, Chang, Su 2017; DESIGN.md §2).
"""
from repro.core.classifier import (  # noqa: F401
    Category, Classification, FACTOR_SHUF, classify, classify_profiles,
)
from repro.core.expansion import (  # noqa: F401
    MemoryProfile, expansion_ratio, increasing_rate, mean_expansion_ratio,
)
from repro.core.measure import (  # noqa: F401
    BASELINE_PLAN, CompileMeasurer, MemoryMeasurer, ProfileCache,
    SimulatedMeasurer, measurer_for,
)
from repro.core.planner import (  # noqa: F401
    PlanDecision, candidate_plans, default_plan, oracle_plan, wsmc_plan,
)
from repro.core.predictor import (  # noqa: F401
    CapacityPrediction, MemoryPlan, min_devices, predict,
)
