"""WSMC — the paper's contribution: workload-specific memory capacity
configuration via expansion-ratio profiling, classification, closed-form
capacity prediction, and knob planning (Liang, Chang, Su 2017; DESIGN.md §2).
"""
from repro.core.classifier import (  # noqa: F401
    Category, Classification, FACTOR_SHUF, classify, classify_profiles,
)
from repro.core.expansion import (  # noqa: F401
    MemoryProfile, expansion_ratio, increasing_rate, mean_expansion_ratio,
)
from repro.core.measure import (  # noqa: F401
    BASELINE_PLAN, CompileMeasurer, MemoryMeasurer, ProfileCache,
    SimulatedMeasurer, measurer_for,
)
from repro.core.predictor import (  # noqa: F401
    CapacityPrediction, MemoryPlan, min_devices, predict,
)

# planner sits on top of repro.search, which itself imports
# repro.core.predictor/measure — importing it lazily here keeps
# `import repro.search` (and this package) cycle-free.
_PLANNER_EXPORTS = ("PlanDecision", "candidate_plans", "default_plan",
                    "oracle_plan", "wsmc_plan")


def __getattr__(name):
    if name in _PLANNER_EXPORTS:
        from repro.core import planner
        return getattr(planner, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
