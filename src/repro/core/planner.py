"""Capacity planner — the online phase's decision step: turn a capacity
prediction into the workload's memory configuration (paper §III-E).

Since the unified plan-search refactor this module is a thin façade over
`repro.search`: the knob lattice is `search.space.paper_space` and the
policies are `search.strategies` —
  default_plan — the static conservative configuration every workload gets
                 without WSMC: full remat, deep microbatching, factored
                 optimizer, full-HBM capacity request. Always fits; slowest.
                 (The analogue of Spark's static 2 GB executor default.)
  wsmc_plan    — strategies.fastest_first: walk the lattice fastest-first,
                 pick the first plan whose *predicted* capacity fits.
  oracle_plan  — strategies.exhaustive_verified: the paper's manually-found
                 "proper configuration", each candidate verified by a
                 measurement backend (compile = real memory_analysis()).
Decision parity with the pre-refactor inline loops is pinned by
tests/test_search.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

from repro import hw as HW
from repro.configs.base import TRAIN, ModelConfig, ShapeConfig
from repro.core.classifier import Classification
from repro.core.predictor import CapacityPrediction, MemoryPlan
from repro.search import space as SP
from repro.search import strategies as ST

REMATS = SP.REMATS
OPTIMIZERS = SP.OPTIMIZERS


@dataclasses.dataclass(frozen=True)
class PlanDecision:
    plan: MemoryPlan
    prediction: CapacityPrediction
    policy: str
    considered: int


def _kv_default(cfg: ModelConfig, model_size: int = 16) -> str:
    return SP.kv_auto(cfg, model_size)


def candidate_plans(cfg: ModelConfig, shape: ShapeConfig,
                    model_size: int = 16) -> List[MemoryPlan]:
    """The knob lattice, ordered fastest-first by step_time_penalty."""
    space = SP.paper_space(cfg, shape, model_size=model_size)
    return [c.plan for c in space.candidates(cfg, shape)]


def default_plan(cfg: ModelConfig, shape: ShapeConfig,
                 model_size: int = 16) -> MemoryPlan:
    kv = _kv_default(cfg, model_size)
    if shape.kind != TRAIN:
        return MemoryPlan(remat="none", microbatches=1,
                          optimizer="adamw_f32", kv_shard=kv)
    micros = [m for m in (32, 16, 8, 4, 2, 1) if shape.global_batch % m == 0]
    return MemoryPlan(remat="full", microbatches=micros[0],
                      optimizer="adafactor", kv_shard=kv)


def wsmc_plan(cfg: ModelConfig, shape: ShapeConfig, cls: Classification,
              mesh_shape: dict, mode: str = "paper",
              hw: HW.HardwareSpec = HW.TPU_V5E,
              factors: Optional[dict] = None) -> PlanDecision:
    """Paper §III-E: predict per candidate, take the fastest that fits.
    `factors` is the offline-calibrated Table III (profiler.calibrated_factors)."""
    space = SP.paper_space(cfg, shape, mesh_shape)
    res = ST.fastest_first(space, cfg, shape, cls, mode=mode, hw=hw,
                           factors=factors)
    return PlanDecision(plan=res.plan, prediction=res.prediction,
                        policy=res.policy, considered=res.considered)


def oracle_plan(cfg: ModelConfig, shape: ShapeConfig,
                measure: Optional[Callable[[MemoryPlan], float]] = None,
                hw: HW.HardwareSpec = HW.TPU_V5E,
                max_candidates: Optional[int] = None,
                measurer=None) -> Tuple[MemoryPlan, float, int]:
    """The 'proper configuration': measure-verify candidates fastest-first
    until one's measured peak fits. `measure(plan)` returns peak bytes/device.
    Alternatively pass a `core.measure.MemoryMeasurer` — under the compile
    backend each call is a real compile (expensive; exactly the cost WSMC
    avoids), under the simulator the whole search is compile-free.
    Returns (plan, measured_peak, n_measurements)."""
    if measure is None and measurer is None:
        raise TypeError("oracle_plan needs `measure` or `measurer`")
    space = SP.paper_space(cfg, shape)
    res = ST.exhaustive_verified(space, cfg, shape, measurer=measurer,
                                 measure=measure, hw=hw,
                                 max_candidates=max_candidates)
    return res.plan, res.peak_bytes, res.measured


def plan_deployment(cfg: ModelConfig, shape: ShapeConfig,
                    cls: Optional[Classification], *, n_devices: int,
                    strategy: str = "fastest", measurer=None,
                    factors: Optional[dict] = None,
                    hw: HW.HardwareSpec = HW.TPU_V5E):
    """Beyond the paper: plan the MESH too, and promote the decision to a
    runnable `search.execplan.ExecutionPlan` (plan + mesh + EP + runtime
    schedule, with a `build(devices)` that constructs the real mesh). This
    is the `--mesh auto` decision step shared by train/serve/dryrun."""
    from repro.search import execplan as XP
    return XP.plan_execution(cfg, shape, cls, n_devices=n_devices,
                             strategy=strategy, measurer=measurer,
                             factors=factors, hw=hw)
