"""Capacity planner — the online phase's decision step: turn a capacity
prediction into the workload's memory configuration (paper §III-E).

Three policies, mirroring the paper's evaluation (§IV):
  default_plan — the static conservative configuration every workload gets
                 without WSMC: full remat, deep microbatching, factored
                 optimizer, full-HBM capacity request. Always fits; slowest.
                 (The analogue of Spark's static 2 GB executor default.)
  wsmc_plan    — walk the knob lattice fastest-first, pick the first plan
                 whose *predicted* capacity fits the HBM budget.
  oracle_plan  — the paper's manually-found "proper configuration":
                 exhaustive search where each candidate is verified by a
                 real .lower().compile() + memory_analysis().
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

from repro import hw as HW
from repro.configs.base import DECODE, PREFILL, TRAIN, ModelConfig, ShapeConfig
from repro.core.classifier import Classification
from repro.core.predictor import CapacityPrediction, MemoryPlan, predict

REMATS = ("none", "dots", "full")
OPTIMIZERS = ("adamw_f32", "adamw_bf16", "adafactor")


@dataclasses.dataclass(frozen=True)
class PlanDecision:
    plan: MemoryPlan
    prediction: CapacityPrediction
    policy: str
    considered: int


def _kv_default(cfg: ModelConfig, model_size: int = 16) -> str:
    """KV-head sharding only when heads divide the model axis; otherwise the
    ring cache shards its sequence dim (padding/replication would multiply
    the decode-resident cache — see musicgen kv=24 in EXPERIMENTS §Perf)."""
    return "heads" if cfg.n_kv_heads % model_size == 0 else "seq"


def candidate_plans(cfg: ModelConfig, shape: ShapeConfig,
                    model_size: int = 16) -> List[MemoryPlan]:
    """The knob lattice, ordered fastest-first by step_time_penalty."""
    kv = _kv_default(cfg, model_size)
    if shape.kind != TRAIN:
        return [MemoryPlan(remat="none", microbatches=1,
                           optimizer="adamw_f32", kv_shard=kv)]
    micros = [m for m in (1, 2, 4, 8, 16, 32, 64)
              if shape.global_batch % m == 0]
    cands = [MemoryPlan(remat=r, microbatches=m, optimizer=o, kv_shard=kv)
             for r in REMATS for m in micros for o in OPTIMIZERS]
    return sorted(cands, key=lambda p: p.step_time_penalty())


def default_plan(cfg: ModelConfig, shape: ShapeConfig,
                 model_size: int = 16) -> MemoryPlan:
    kv = _kv_default(cfg, model_size)
    if shape.kind != TRAIN:
        return MemoryPlan(remat="none", microbatches=1,
                          optimizer="adamw_f32", kv_shard=kv)
    micros = [m for m in (32, 16, 8, 4, 2, 1) if shape.global_batch % m == 0]
    return MemoryPlan(remat="full", microbatches=micros[0],
                      optimizer="adafactor", kv_shard=kv)


def wsmc_plan(cfg: ModelConfig, shape: ShapeConfig, cls: Classification,
              mesh_shape: dict, mode: str = "paper",
              hw: HW.HardwareSpec = HW.TPU_V5E,
              factors: Optional[dict] = None) -> PlanDecision:
    """Paper §III-E: predict per candidate, take the fastest that fits.
    `factors` is the offline-calibrated Table III (profiler.calibrated_factors)."""
    dp = mesh_shape.get("pod", 1) * mesh_shape.get("data", 1)
    model_size = mesh_shape.get("model", 16)

    def _divisible(p):
        per_micro = shape.global_batch // p.microbatches
        if shape.kind == TRAIN:
            # strict: a per-micro batch below dp replicates compute/memory
            return per_micro % dp == 0
        # serving: bs=1 long-context cells replicate the batch axis benignly
        return per_micro % dp == 0 or per_micro < dp

    all_cands = candidate_plans(cfg, shape, model_size)
    cands = [p for p in all_cands if _divisible(p)] or all_cands[-1:]
    for i, plan in enumerate(cands):
        pred = predict(cfg, shape, plan, cls, mesh_shape, mode, hw, factors)
        if pred.fits:
            return PlanDecision(plan=plan, prediction=pred, policy="wsmc",
                                considered=i + 1)
    # nothing fits: return the safest with its (over-budget) prediction
    plan = cands[-1]
    return PlanDecision(plan=plan,
                        prediction=predict(cfg, shape, plan, cls, mesh_shape,
                                           mode, hw, factors),
                        policy="wsmc_overflow", considered=len(cands))


def oracle_plan(cfg: ModelConfig, shape: ShapeConfig,
                measure: Optional[Callable[[MemoryPlan], float]] = None,
                hw: HW.HardwareSpec = HW.TPU_V5E,
                max_candidates: Optional[int] = None,
                measurer=None) -> Tuple[MemoryPlan, float, int]:
    """The 'proper configuration': measure-verify candidates fastest-first
    until one's measured peak fits. `measure(plan)` returns peak bytes/device.
    Alternatively pass a `core.measure.MemoryMeasurer` — under the compile
    backend each call is a real compile (expensive; exactly the cost WSMC
    avoids), under the simulator the whole search is compile-free.
    Returns (plan, measured_peak, n_measurements)."""
    if measure is None:
        if measurer is None:
            raise TypeError("oracle_plan needs `measure` or `measurer`")
        measure = measurer.peak_fn(cfg, shape)
    cands = candidate_plans(cfg, shape)
    if max_candidates:
        cands = cands[:max_candidates]
    budget = hw.hbm_bytes / HW.CAPACITY_HEADROOM - hw.reserved_bytes
    n = 0
    best = None
    for plan in cands:
        n += 1
        peak = measure(plan)
        if peak <= budget:
            return plan, peak, n
        if best is None or peak < best[1]:
            best = (plan, peak)
    return best[0], best[1], n
