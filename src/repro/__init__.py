"""repro: WSMC-JAX — workload-specific memory capacity planning for a
multi-pod JAX LM framework (reproduction of Liang et al., 2017)."""

__version__ = "1.0.0"
