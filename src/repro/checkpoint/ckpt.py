"""Sharded, atomic, async checkpointing with cross-mesh resharding restore.

Layout:  <dir>/step_<n>/manifest.json + arrays.npz   (tmp dir + rename commit)

- save(): device_get happens synchronously (consistent snapshot), serialization
  runs on a background thread (async=True) so the train loop overlaps I/O.
- restore(): returns numpy or device arrays; when a mesh + spec tree is given,
  leaves are jax.device_put with their NamedSharding — restoring onto a
  *different* mesh than the one that saved is the elastic-restart path
  (tested: 8 -> 4 devices).
- Fault tolerance: latest_step() skips uncommitted (tmp) dirs; a corrupt or
  partial save never shadows the previous good step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

_SEP = "//"

# npz has no bfloat16: store a uint16 view and the true dtype in the manifest.
_VIEW_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _to_storable(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name][1]), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str):
    if dtype_name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[dtype_name][0])
    return arr


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None,
         async_: bool = False) -> threading.Thread | None:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    raw = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
    flat = {}
    dtypes = {}
    for k, v in raw.items():
        flat[k], dtypes[k] = _to_storable(v)
    meta = {
        "step": step,
        "extra": extra or {},
        "leaves": {k: {"shape": list(raw[k].shape), "dtype": dtypes[k]}
                   for k in raw},
    }

    def _write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in flat.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name[len("step_"):]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, target_tree, step: Optional[int] = None,
            mesh=None, spec_tree=None):
    """Restore into the structure of `target_tree` (abstract or concrete).

    With (mesh, spec_tree): leaves are placed sharded — works across mesh
    sizes (elastic resharding).
    Returns (tree, manifest dict).
    """
    from jax.sharding import NamedSharding
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))

    flat_target = _flatten(target_tree)
    flat_specs = _flatten(spec_tree) if spec_tree is not None else {}
    out = {}
    for key, ref in flat_target.items():
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = _from_storable(arrays[key],
                             manifest["leaves"][key]["dtype"])
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != {ref.shape}")
        arr = arr.astype(ref.dtype)
        if mesh is not None and key in flat_specs:
            out[key] = jax.device_put(arr,
                                      NamedSharding(mesh, flat_specs[key]))
        else:
            out[key] = jax.numpy.asarray(arr)

    # unflatten by rebuilding along target structure
    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    keys = [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in paths]
    leaves = [out[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest
