"""Deterministic synthetic LM data pipeline with per-host sharding.

Tokens are generated from a counter-based Philox stream keyed on
(seed, step, global example index) — any host can materialize exactly its
shard for any step with no coordination, which is what makes elastic
restart and straggler exclusion deterministic (runtime/fault.py): after a
re-mesh, host h' of H' regenerates the same global batch partitioned
differently.

Documents: geometric lengths packed into fixed-size sequences with EOS
separators; targets are next-token shifted within documents.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    eos_id: int = 1
    # Learnable structure: with prob `markov_p` the next token follows a fixed
    # affine map of the previous one — gives integration tests a decreasing
    # loss signal while staying fully deterministic.
    markov_p: float = 0.9


class TokenPipeline:
    """Iterator over per-host batches: {"tokens","targets"} int32 arrays."""

    def __init__(self, cfg: DataConfig, n_hosts: int = 1, host_id: int = 0,
                 start_step: int = 0):
        if cfg.global_batch % n_hosts:
            raise ValueError(f"global_batch {cfg.global_batch} not divisible "
                             f"by n_hosts {n_hosts}")
        self.cfg = cfg
        self.n_hosts = n_hosts
        self.host_id = host_id
        self.step = start_step

    def batch_at(self, step: int, n_hosts: Optional[int] = None,
                 host_id: Optional[int] = None) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        n_hosts = self.n_hosts if n_hosts is None else n_hosts
        host_id = self.host_id if host_id is None else host_id
        per_host = cfg.global_batch // n_hosts
        lo = host_id * per_host
        rows = [self._example(step, lo + i) for i in range(per_host)]
        tokens = np.stack([r[0] for r in rows])
        targets = np.stack([r[1] for r in rows])
        return {"tokens": tokens, "targets": targets}

    def _example(self, step: int, index: int):
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=np.array([np.uint64(cfg.seed) ^ np.uint64(0x5D17 << 32),
                          (np.uint64(step) << np.uint64(32))
                          | np.uint64(index)], dtype=np.uint64)))
        s = cfg.seq_len
        noise = rng.integers(2, cfg.vocab_size, size=s + 1, dtype=np.int64)
        follow = rng.random(s + 1) < cfg.markov_p
        toks = np.empty(s + 1, np.int64)
        toks[0] = noise[0]
        vspan = cfg.vocab_size - 2
        for i in range(1, s + 1):
            if follow[i]:
                toks[i] = 2 + (toks[i - 1] * 31 + 7) % vspan
            else:
                toks[i] = noise[i]
        # carve into documents with EOS boundaries (packing)
        pos = 0
        while pos < s + 1:
            dl = int(rng.geometric(1.0 / max(cfg.mean_doc_len, 2)))
            pos += dl
            if pos < s + 1:
                toks[pos] = cfg.eos_id
                pos += 1
        return toks[:s].astype(np.int32), toks[1:s + 1].astype(np.int32)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch
