"""Core layers: norms, dense projections, rotary embeddings, activations,
embedding / LM head, cross-entropy. Pure functions over param pytrees.

Compute convention: params in cfg.param_dtype (bf16), matmuls in bf16 with
f32 accumulation via `preferred_element_type`, norms/softmax/loss in f32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.axes import shard

INIT_STD = 0.02


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * INIT_STD).astype(dtype)


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Projection matmul, output in x.dtype (bf16).

    No preferred_element_type: the MXU still accumulates f32 *within* a
    shard, but the cross-shard TP reduction then travels in bf16 — halving
    the per-layer all-reduce wire (EXPERIMENTS §Perf iter 4). The result was
    cast to bf16 immediately afterwards anyway, so only the K=16 partial-sum
    addition loses precision (standard Megatron fp16/bf16-reduce practice).
    """
    return jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())))


def einsum_f32(spec: str, a: jax.Array, b: jax.Array) -> jax.Array:
    """Einsum with f32 accumulation and f32 output.

    On TPU this is the MXU-native bf16 x bf16 -> f32 contraction
    (preferred_element_type). XLA:CPU's DotThunk rejects some such batched
    dots at execute time, so off-TPU the inputs are upcast instead —
    numerically equivalent, and only test/example paths execute on CPU.
    """
    if jax.default_backend() == "tpu":
        return jnp.einsum(spec, a, b, preferred_element_type=jnp.float32)
    return jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype)   # gemma-style (1 + scale)


def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def groupnorm_heads(scale: jax.Array, x: jax.Array, n_heads: int,
                    eps: float = 1e-6) -> jax.Array:
    """Per-head RMS groupnorm over the trailing dim reshaped to heads."""
    b, s, inner = x.shape
    xf = x.astype(jnp.float32).reshape(b, s, n_heads, inner // n_heads)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = (xf * jax.lax.rsqrt(var + eps)).reshape(b, s, inner)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "squared_relu": squared_relu,
}


def glu_combine(activation: str, gate: jax.Array, up: jax.Array) -> jax.Array:
    if activation == "swiglu":
        return jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up
    if activation == "geglu":
        return jax.nn.gelu(gate.astype(jnp.float32)).astype(up.dtype) * up
    raise ValueError(activation)


def is_glu(activation: str) -> bool:
    return activation in ("swiglu", "geglu")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [b, s, h, hd]; positions: [b, s] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [b, s, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Causal depthwise conv (recurrent blocks) — supports streaming decode
# ---------------------------------------------------------------------------

def conv1d_init(width: int, channels: int, dtype) -> jax.Array:
    return jnp.full((width, channels), 1.0 / width, dtype)


def causal_conv1d(w: jax.Array, x: jax.Array,
                  state: Optional[jax.Array] = None,
                  valid_len: Optional[jax.Array] = None):
    """Depthwise causal conv. x: [b, s, c]; state: [b, width-1, c] history.

    `valid_len` [b] marks rows whose trailing positions are padding
    (serving chunked prefill): the carried history must then end at the
    last VALID input, not at the padded tail. valid_len == s reproduces
    the default carry; valid_len == 0 passes `state` through unchanged.

    Returns (y [b, s, c], new_state [b, width-1, c]).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(width):  # width is tiny (4): unrolled taps
        y = y + w[i].astype(x.dtype) * jax.lax.dynamic_slice_in_dim(
            xp, i, x.shape[1], axis=1)
    if width <= 1:
        new_state = state
    elif valid_len is None:
        new_state = xp[:, -(width - 1):, :]
    else:
        # window of width-1 inputs ending at the last valid position:
        # xp rows are [state (width-1) | x (s)], so that window starts
        # at offset valid_len
        idx = valid_len[:, None] + jnp.arange(width - 1, dtype=jnp.int32)
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    return y, new_state


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def embed_init(key, cfg):
    return {"table": (jax.random.normal(
        key, (cfg.padded_vocab_size, cfg.d_model),
        jnp.float32) * INIT_STD).astype(_dtype(cfg))}


def embed_lookup(params, cfg, tokens: jax.Array,
                 onehot: bool = False) -> jax.Array:
    """Token embedding. onehot=True uses a one-hot matmul instead of gather:
    on a vocab-sharded table the partitioner turns it into a local matmul +
    psum instead of an all-gather of the whole table (the gather path trips
    GSPMD's 'involuntary full rematerialization' — see EXPERIMENTS §Perf)."""
    table = params["table"]
    if onehot:
        oh = jax.nn.one_hot(tokens, table.shape[0], dtype=table.dtype)
        x = jax.lax.dot_general(oh, table, (((oh.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32
                                ).astype(table.dtype)
    else:
        x = jnp.take(table, tokens, axis=0)
    x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)  # gemma-style scale
    return shard(x, "batch", "seq", "embed")


def lm_head(params, cfg, x: jax.Array) -> jax.Array:
    """x: [b, s, d] -> logits [b, s, V] (f32)."""
    table = params["table"]
    logits = jax.lax.dot_general(
        x, table, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return shard(logits, "batch", "seq", "vocab")


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean token NLL. logits [b, s, V] f32, targets [b, s] int32."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
