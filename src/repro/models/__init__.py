from repro.models.model import (  # noqa: F401
    ModelSettings, apply, block_apply, block_cache_init, init_cache,
    init_params,
)
